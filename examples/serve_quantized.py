"""Serving driver: quantize a trained model to PACKED W4A4 (the fused-kernel
format) and serve batched requests through the continuous-batching server.

On CPU the quantized linears run the jnp oracle path; on TPU the same params
route through the fused Pallas kernel (models/common.linear dispatch).

Run: PYTHONPATH=src python examples/serve_quantized.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import QuantSpec
from repro.core.twinquant import quantize_params
from repro.launch.serve import Request, Server
from benchmarks.common import get_trained_model


def main():
    cfg, params, corpus = get_trained_model()
    print("quantizing to packed W4A4 (rank 32, group 128) ...")
    qspec = QuantSpec(mode="w4a4", rank=32)
    qparams = quantize_params(params, cfg, qspec)

    n_quant = sum(1 for p in jax.tree_util.tree_leaves_with_path(qparams)
                  if str(p[0][-1]).endswith("'rp'"))
    pb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params)) / 1e6
    qb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(qparams)) / 1e6
    print(f" {n_quant} linears packed; params {pb:.1f}MB -> {qb:.1f}MB")

    server = Server(cfg, qparams, batch_slots=4, max_len=96)
    prompts = [
        "def main(", "import jax", "class Model", "# TwinQuant",
        "return x +", "for i in",
    ]
    t0 = time.monotonic()
    pending = [Request(jnp.asarray(list(p.encode()), jnp.int32), max_new=12)
               for p in prompts]
    done = []
    while pending or any(server.slots):
        while pending and server.submit(pending[0]):
            done.append(pending.pop(0))
        server.step()
    server.run_until_done()
    dt = time.monotonic() - t0
    total_new = sum(len(r.out) for r in done)
    for p, r in zip(prompts, done):
        txt = bytes(t for t in r.out if t < 256).decode(errors="replace")
        print(f"  {p!r} -> {txt!r}")
    print(f" served {len(done)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s on 1 CPU core, ref path)")
    print("serve_quantized OK")


if __name__ == "__main__":
    main()
