"""Serving driver: quantize a trained model to PACKED W4A4 (the fused-kernel
format) and serve batched requests through the continuous-batching engine —
first the bucketed paged engine, then the unified RAGGED engine
(docs/serving.md): chunked prefill + decode in one launch per step, with a
token-equality check between the two.

On CPU the quantized linears run the jnp oracle path; on TPU the same params
route through the fused Pallas kernel (models/common.linear dispatch).

Run:        PYTHONPATH=src:. python examples/serve_quantized.py
CI smoke:   PYTHONPATH=src:. python examples/serve_quantized.py --smoke
(--smoke serves random-init weights — the serving path is shape-bound, so
admission/paging/ragged behavior and every assertion are identical; it just
skips the minutes of corpus training behind the cached bench model.)
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import QuantSpec
from repro.core.twinquant import fuse_params, quantize_params
from repro.launch.serve import ContinuousBatchingEngine, Request, SamplingParams


def make_requests(cfg):
    """A shared system prompt with mixed tails + mixed per-request sampling."""
    system = "# TwinQuant demo: continue the code\n"  # shared system prompt
    prompts = [
        "def main(", "import jax", "class Model", "# TwinQuant",
        "return x +", "for i in",
    ]
    requests = [
        Request(
            jnp.asarray(list((system + p).encode()), jnp.int32), max_new=12,
            sampling=(SamplingParams() if i % 2 == 0
                      else SamplingParams(temperature=0.8, top_k=40, seed=i)),
        )
        for i, p in enumerate(prompts)
    ]
    return prompts, requests


def main(smoke: bool = False):
    if smoke:
        from benchmarks.common import BENCH_CFG
        from repro.models import dense

        cfg, params = BENCH_CFG, None
        params = dense.init_params(cfg, jax.random.PRNGKey(0))
        print("smoke mode: random-init weights (shape-identical serving path)")
    else:
        from benchmarks.common import get_trained_model

        cfg, params, _ = get_trained_model()
    print("quantizing to packed W4A4 (rank 32, group 128) ...")
    qspec = QuantSpec(mode="w4a4", rank=32)
    qparams = quantize_params(params, cfg, qspec)

    n_quant = sum(1 for p in jax.tree_util.tree_leaves_with_path(qparams)
                  if getattr(p[0][-1], "key", None) == "rp")
    # default serving config: merge sibling packs (q/k/v, gate/up) so each
    # group runs as ONE fused launch (checkpoints stay unfused on disk)
    qparams = fuse_params(qparams)
    pb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params)) / 1e6
    qb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(qparams)) / 1e6
    print(f" {n_quant} linears packed; params {pb:.1f}MB -> {qb:.1f}MB")

    # paged serving runtime (DESIGN.md §14): global page pool + per-slot
    # block tables, admission gated on free pages, shared prompt prefixes
    # served from the prefix cache (paged=False is the dense A/B oracle)
    engine = ContinuousBatchingEngine(cfg, qparams, batch_slots=4, max_len=96,
                                      paged=True, page_size=8)
    prompts, requests = make_requests(cfg)
    t0 = time.monotonic()
    engine.serve(requests)
    dt = time.monotonic() - t0
    for p, r in zip(prompts, requests):
        txt = bytes(t for t in r.out if t < 256).decode(errors="replace")
        mode = "greedy" if r.sampling.temperature <= 0 else "t=0.8/k=40"
        print(f"  [{mode:>10}] {p!r} -> {txt!r}")
    th = engine.throughput()
    total_new = sum(len(r.out) for r in requests)
    print(f" served {len(requests)} requests, {total_new} tokens in {dt:.1f}s: "
          f"decode {th['decode_tok_s']:.1f} tok/s, prefill {th['prefill_tok_s']:.1f} tok/s, "
          f"mean occupancy {th['mean_batch_occupancy']:.2f}/{engine.batch} slots "
          f"(1 CPU core, oracle numerics)")
    # which kernel schedule each quantized linear routed to, per trace:
    # decode steps (M=slots<=8) must hit the decode-shaped schedule, the
    # prompt prefill (M=prompt length) the prefill one
    routes = ", ".join(f"{k}:{v}" for k, v in sorted(th["routing"].items()))
    print(f" dispatch routes: {routes}")
    mem = engine.memory()
    cs = engine.compile_stats()
    print(f" paging: {mem['pages_peak']}/{mem['n_pages']} pages peak "
          f"({mem['peak_cache_bytes'] / 1e3:.0f}kB vs dense "
          f"{mem['dense_cache_bytes'] / 1e3:.0f}kB), "
          f"prefix hits {th['prefix_hits']}/{th['prefix_lookups']} "
          f"({th['prefix_hit_tokens']} prompt tokens served from cache), "
          f"{cs['prefill_traces']} prefill traces for buckets {cs['prefill_buckets']}")
    engine.check_page_invariants()
    assert th["routing"].get("dual/decode", 0) > 0, "decode steps must route decode"
    assert th["routing"].get("dual_fused/decode", 0) > 0, \
        "fused serving must route the fused decode kind (q/k/v, gate/up)"
    assert th["prefix_hits"] > 0, "shared system prompt must hit the prefix cache"

    # --- the unified RAGGED engine (docs/serving.md): every step is ONE
    # launch over a flat token batch — decode rows first, prompt chunks fill
    # the remaining token budget — compiling a single executable instead of
    # the prefill bucket set. Token equality vs the bucketed engine is exact
    # when the two runs split work identically: prefix caching off on BOTH
    # (ragged matches full prefixes, bucketed matches power-of-two lengths)
    # and a budget wide enough that each prompt prefills in one chunk (a
    # chunk boundary reassociates the f32 softmax accumulation — ~1e-7,
    # enough to flip a near-tied argmax; tests/test_ragged_engine.py covers
    # the chunked regime).
    _, oreqs = make_requests(cfg)
    oracle = ContinuousBatchingEngine(cfg, qparams, batch_slots=4, max_len=96,
                                      paged=True, page_size=8,
                                      prefix_caching=False)
    oracle.serve(oreqs)
    ragged = ContinuousBatchingEngine(cfg, qparams, batch_slots=4, max_len=96,
                                      paged=True, page_size=8,
                                      prefix_caching=False,
                                      ragged=True, token_budget=192)
    _, rreqs = make_requests(cfg)
    t0 = time.monotonic()
    ragged.serve(rreqs)
    dt = time.monotonic() - t0
    rth = ragged.throughput()
    rcs = ragged.compile_stats()
    rroutes = ", ".join(f"{k}:{v}" for k, v in sorted(rth["routing"].items())
                        if k.startswith("ragged/"))
    print(f" ragged engine: {sum(len(r.out) for r in rreqs)} tokens in {dt:.1f}s, "
          f"decode {rth['decode_tok_s']:.1f} tok/s; "
          f"{rcs['ragged_traces']} ragged executable(s), "
          f"{rcs['prefill_traces']} prefill buckets; attention routes: {rroutes}")
    ragged.check_page_invariants()
    assert [r.out for r in rreqs] == [r.out for r in oreqs], \
        "ragged serving must be token-identical to the bucketed engine"
    assert rcs["ragged_traces"] == 1 and rcs["prefill_traces"] == 0, rcs
    print("serve_quantized OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="serve random-init weights (CI example-smoke; skips "
                         "the cached trained bench model)")
    main(**vars(ap.parse_args()))
