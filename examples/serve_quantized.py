"""Serving driver: quantize a trained model to PACKED W4A4 (the fused-kernel
format) and serve batched requests through the continuous-batching engine.

On CPU the quantized linears run the jnp oracle path; on TPU the same params
route through the fused Pallas kernel (models/common.linear dispatch).

Run: PYTHONPATH=src python examples/serve_quantized.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import QuantSpec
from repro.core.twinquant import fuse_params, quantize_params
from repro.launch.serve import ContinuousBatchingEngine, Request, SamplingParams
from benchmarks.common import get_trained_model


def main():
    cfg, params, corpus = get_trained_model()
    print("quantizing to packed W4A4 (rank 32, group 128) ...")
    qspec = QuantSpec(mode="w4a4", rank=32)
    qparams = quantize_params(params, cfg, qspec)

    n_quant = sum(1 for p in jax.tree_util.tree_leaves_with_path(qparams)
                  if getattr(p[0][-1], "key", None) == "rp")
    # default serving config: merge sibling packs (q/k/v, gate/up) so each
    # group runs as ONE fused launch (checkpoints stay unfused on disk)
    qparams = fuse_params(qparams)
    pb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params)) / 1e6
    qb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(qparams)) / 1e6
    print(f" {n_quant} linears packed; params {pb:.1f}MB -> {qb:.1f}MB")

    # paged serving runtime (DESIGN.md §14): global page pool + per-slot
    # block tables, admission gated on free pages, shared prompt prefixes
    # served from the prefix cache (paged=False is the dense A/B oracle)
    engine = ContinuousBatchingEngine(cfg, qparams, batch_slots=4, max_len=96,
                                      paged=True, page_size=8)
    system = "# TwinQuant demo: continue the code\n"  # shared system prompt
    prompts = [
        "def main(", "import jax", "class Model", "# TwinQuant",
        "return x +", "for i in",
    ]
    # mixed per-request sampling: half greedy, half temperature+top-k
    requests = [
        Request(
            jnp.asarray(list((system + p).encode()), jnp.int32), max_new=12,
            sampling=(SamplingParams() if i % 2 == 0
                      else SamplingParams(temperature=0.8, top_k=40, seed=i)),
        )
        for i, p in enumerate(prompts)
    ]
    t0 = time.monotonic()
    engine.serve(requests)
    dt = time.monotonic() - t0
    for p, r in zip(prompts, requests):
        txt = bytes(t for t in r.out if t < 256).decode(errors="replace")
        mode = "greedy" if r.sampling.temperature <= 0 else "t=0.8/k=40"
        print(f"  [{mode:>10}] {p!r} -> {txt!r}")
    th = engine.throughput()
    total_new = sum(len(r.out) for r in requests)
    print(f" served {len(requests)} requests, {total_new} tokens in {dt:.1f}s: "
          f"decode {th['decode_tok_s']:.1f} tok/s, prefill {th['prefill_tok_s']:.1f} tok/s, "
          f"mean occupancy {th['mean_batch_occupancy']:.2f}/{engine.batch} slots "
          f"(1 CPU core, oracle numerics)")
    # which kernel schedule each quantized linear routed to, per trace:
    # decode steps (M=slots<=8) must hit the decode-shaped schedule, the
    # prompt prefill (M=prompt length) the prefill one
    routes = ", ".join(f"{k}:{v}" for k, v in sorted(th["routing"].items()))
    print(f" dispatch routes: {routes}")
    mem = engine.memory()
    cs = engine.compile_stats()
    print(f" paging: {mem['pages_peak']}/{mem['n_pages']} pages peak "
          f"({mem['peak_cache_bytes'] / 1e3:.0f}kB vs dense "
          f"{mem['dense_cache_bytes'] / 1e3:.0f}kB), "
          f"prefix hits {th['prefix_hits']}/{th['prefix_lookups']} "
          f"({th['prefix_hit_tokens']} prompt tokens served from cache), "
          f"{cs['prefill_traces']} prefill traces for buckets {cs['prefill_buckets']}")
    engine.check_page_invariants()
    assert th["routing"].get("dual/decode", 0) > 0, "decode steps must route decode"
    assert th["routing"].get("dual_fused/decode", 0) > 0, \
        "fused serving must route the fused decode kind (q/k/v, gate/up)"
    assert th["prefix_hits"] > 0, "shared system prompt must hit the prefix cache"
    print("serve_quantized OK")


if __name__ == "__main__":
    main()
