"""Quickstart: TwinQuant on one linear layer, end to end.

1. Build an outlier-heavy layer (LLM-like statistics).
2. Smooth + SVD-decompose + learn (Q, G) with the three-stage calibration.
3. Show the paper's Table-3 ordering at layer level:
       naive 4-bit  >  +LowRank  >  +Hadamard  >  TwinQuant   (output error)
4. Pack the transformed components to int4 and run them through the ROUTED
   dispatch layer (kernels/dispatch.py) — the production entry point that
   picks a kernel schedule per shape and records it in the dispatch
   counters — then force the Pallas kernel (interpret mode on CPU) and
   verify it matches the jnp oracle bit for bit.

Run:        PYTHONPATH=src python examples/quickstart.py
CI smoke:   PYTHONPATH=src python examples/quickstart.py --smoke
(--smoke shrinks the layer and calibration steps so the example executes in
seconds; same code path, same assertions.)
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core.calibration import CalibConfig, calibrate_layer, layer_quant_configs
from repro.core.errors import total_delta, zeta_gain
from repro.core.quantization import QuantConfig, dequantize, quantize
from repro.core.transforms import hadamard_matrix
from repro.kernels.dispatch import (
    dispatch_counters,
    quant_linear,
    reset_dispatch_counters,
)
from repro.kernels.ref import dual_gemm_ref, pack_twinquant_weights


def main(smoke: bool = False):
    key = jax.random.PRNGKey(0)
    k1, k2, k3, _ = jax.random.split(key, 4)
    if smoke:
        M, N, RANK, SAMPLES = 128, 128, 16, 128
        cal_steps = dict(steps_global=8, steps_invert=8, steps_joint=4)
    else:
        M, N, RANK, SAMPLES = 256, 256, 32, 512
        cal_steps = dict(steps_global=60, steps_invert=60, steps_joint=30)

    # --- an LLM-like layer: a few high-magnitude input channels
    w = jax.random.normal(k1, (M, N)) * 0.05
    outliers = jax.random.choice(k2, M, (8,), replace=False)
    w = w.at[outliers].mul(10.0)
    x = jax.random.normal(k3, (SAMPLES, M))
    x = x.at[:, outliers].mul(6.0)

    print("== TwinQuant quickstart ==")
    cfg = CalibConfig(rank=RANK, **cal_steps)
    res = calibrate_layer(x, w, cfg)
    aq, uq, vq, rq = layer_quant_configs(M, RANK, cfg)
    x_hat = x / res.decomp.lam[None, :]
    U, V, R = res.decomp.U, res.decomp.V, res.decomp.R

    def err(xi, Ui, Vi, Ri):
        return float(total_delta(xi, Ui, Vi, Ri, aq, uq, vq, rq))

    wq4 = QuantConfig(bits=4, group_size=128, axis=0)
    w_hat = w * res.decomp.lam[:, None]  # same smoothed weight the others use
    naive = float(
        jnp.sum(
            (
                dequantize(quantize(x_hat, aq))
                @ dequantize(quantize(w_hat, wq4))
                - x_hat @ w_hat
            )
            ** 2
        )
    )
    H = hadamard_matrix(M)
    e_low = err(x_hat, U, V, R)
    e_had = err(x_hat @ H, H.T @ U, V, H.T @ R)
    e_twin = err(x_hat @ res.Q, res.Q.T @ U @ res.G, res.G_inv @ V, res.Q.T @ R)
    print(f" naive 4-bit output err^2 : {naive:12.2f}")
    print(f" +LowRank (SVD)           : {e_low:12.2f}")
    print(f" +Hadamard                : {e_had:12.2f}")
    print(f" TwinQuant (learned Q,G)  : {e_twin:12.2f}")
    print(f" activation flattening gain zeta(Q) = {float(zeta_gain(x_hat, res.Q)):.2f}")
    assert e_twin <= e_had <= naive

    # --- pack + the routed quantized linear (the serving entry point)
    U2, V2, R2 = res.Q.T @ U @ res.G, res.G_inv @ V, res.Q.T @ R
    pack = pack_twinquant_weights(U2, V2, R2, a_bits=4)
    xq_in = (x_hat @ res.Q).astype(jnp.bfloat16)
    reset_dispatch_counters()
    y_routed = quant_linear(xq_in, pack)  # impl="auto": classify + record
    routes = ", ".join(f"{k}:{v}" for k, v in sorted(dispatch_counters().items()))
    print(f" dispatch routed the pack as: {routes}")
    # force the Pallas kernel (interpret mode on CPU) against the jnp oracle
    y_kernel = quant_linear(xq_in, pack, impl="kernel")
    y_oracle = dual_gemm_ref(xq_in, pack)
    exact = bool(jnp.all(y_kernel == y_oracle))
    print(f" fused dual-component kernel == oracle: {exact}")
    assert exact
    assert y_routed.shape == y_oracle.shape
    y_ref = x_hat @ w_hat  # the layer's true (smoothed) fp32 output
    rel = float(
        jnp.linalg.norm(y_oracle.astype(jnp.float32) - y_ref) / jnp.linalg.norm(y_ref)
    )
    print(f" fused W4A4 output vs fp32: rel err {rel:.4f}")
    assert rel < 0.25, rel
    print("quickstart OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + few calibration steps (CI example-smoke)")
    main(**vars(ap.parse_args()))
