"""Load-test driver: a seeded 2-scenario workload (chat turns behind a shared
system prompt + short bursty queries) streamed through the continuous-batching
engine, with the SLO metrics surface printed at the end (docs/serving.md
"SLO metrics & traffic harness").

Every request streams via ``Request.on_token`` — the per-token callback the
engine fires exactly once per emitted token — so TTFT is observed the moment
the first token lands, not reconstructed afterwards. One extra request is
consumed through the synchronous ``engine.stream()`` iterator to show the
pull-style surface. ``engine.latency()`` then reports TTFT / per-token / e2e
percentiles, goodput under the SLO, queue depth, preemption and prefix-hit
rates.

Run:        PYTHONPATH=src:. python examples/load_test.py
CI smoke:   PYTHONPATH=src:. python examples/load_test.py --smoke
(--smoke shrinks to a tiny random-init model and a handful of requests; the
harness path — arrivals, streaming, metrics — is identical.)
"""

import argparse

import jax
import numpy as np

from repro.launch.metrics import SLO
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.launch.workload import Scenario, make_workload, replay


def two_scenarios(page_size: int) -> list[Scenario]:
    """Chat behind a 2-page shared system prompt, plus top-priority bursts."""
    return [
        Scenario("chat", weight=0.6, prompt_len=(6, 14), max_new=(6, 10),
                 priority=1, shared_prefix_len=2 * page_size),
        Scenario("burst", weight=0.4, prompt_len=(4, 8), max_new=(4, 6),
                 priority=2, deadline_steps=600, burst=3),
    ]


def main(smoke: bool = False):
    if smoke:
        from repro.configs import ModelConfig
        from repro.models import dense

        cfg = ModelConfig(name="tiny-load", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                          d_ff=128, vocab=256, remat=False)
        params = dense.init_params(cfg, jax.random.PRNGKey(0))
        n_requests, page_size = 6, 8
        print("smoke mode: tiny random-init model, 2-scenario workload")
    else:
        from benchmarks.common import BENCH_CFG
        from repro.configs import QuantSpec
        from repro.core.twinquant import fuse_params, quantize_params
        from repro.models import dense

        cfg = BENCH_CFG
        params = fuse_params(
            quantize_params(dense.init_params(cfg, jax.random.PRNGKey(0)),
                            cfg, QuantSpec(mode="w4a4", rank=32)), cfg)
        n_requests, page_size = 16, 8
        print("quantized packed-W4A4 model, 2-scenario workload")

    engine = ContinuousBatchingEngine(
        cfg, params, batch_slots=4, max_len=96, paged=True,
        page_size=page_size, preemption=True, ragged=True, token_budget=32,
    )
    workload = make_workload(
        seed=7, n_requests=n_requests, vocab=cfg.vocab,
        scenarios=two_scenarios(page_size),
    )

    # callback-style streaming: fires at the step that emitted the token
    streamed: dict[str, list[int]] = {}

    def on_token(req, tok):
        streamed.setdefault(req.request_id, []).append(tok)

    for item in workload.items:
        item.request.on_token = on_token
    print(f"replaying {len(workload.items)} requests "
          f"({sum(i.scenario == 'burst' for i in workload.items)} burst, "
          f"{sum(i.scenario == 'chat' for i in workload.items)} chat) ...")
    requests = replay(engine, workload)
    for r in requests:
        assert r.done, f"{r.request_id} not terminal"
        assert streamed.get(r.request_id, []) == r.out, \
            f"{r.request_id}: stream diverged from emitted tokens"
    print(f"all {len(requests)} requests terminal; "
          "callback streams match emitted tokens exactly")

    # pull-style streaming: the iterator yields as the engine emits
    tail = Request(np.arange(1, 9, dtype=np.int32), max_new=5)
    pulled = list(engine.stream(tail))
    assert pulled == tail.out and len(pulled) == 5
    print(f"stream() iterator pulled {len(pulled)} tokens: {pulled}")

    lat = engine.latency(slo=SLO(ttft_s=2.0, tpot_s=0.5))
    for key in ("ttft_ms", "tpot_ms", "goodput_tok_s", "slo_met_rate",
                "preemption_rate", "prefix_hit_rate"):
        assert key in lat, f"latency summary missing {key}"
    t, g = lat["ttft_ms"], lat["tpot_ms"]
    print(f"TTFT ms    p50={t['p50']:.1f} p95={t['p95']:.1f} p99={t['p99']:.1f}")
    print(f"TPOT ms    p50={g['p50']:.1f} p95={g['p95']:.1f} p99={g['p99']:.1f}")
    print(f"goodput    {lat['goodput_tok_s']:.1f} tok/s "
          f"(slo_met_rate={lat['slo_met_rate']:.2f})")
    print(f"queue      mean={lat['queue_depth_mean']:.2f} "
          f"max={lat['queue_depth_max']}")
    print(f"rates      preemption={lat['preemption_rate']:.2f} "
          f"prefix_hit={lat['prefix_hit_rate']:.2f}")
    print("OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny random-init model (CI example-smoke)")
    main(**vars(ap.parse_args()))
