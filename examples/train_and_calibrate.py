"""End-to-end training driver: train a small LM on the in-repo corpus with
the full production stack (data pipeline, AdamW, fault-tolerant TrainLoop
with async checkpoints + straggler monitor), then TwinQuant-calibrate it and
compare held-out perplexity fp16 vs W4A4.

Run: PYTHONPATH=src python examples/train_and_calibrate.py [--steps 300]
"""

import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ModelConfig, QuantSpec
from repro.core.calibration import CalibConfig
from repro.data.pipeline import TokenDataset, load_corpus
from repro.launch.train import StragglerMonitor, TrainLoop, init_train_state, make_train_step
from repro.optim import AdamW


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="artifacts/example_train")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="example-lm", family="dense", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=512, vocab=260, remat=False,
    )
    corpus = load_corpus()
    ds = TokenDataset(corpus, batch=16, seq=128, seed=0)
    opt = AdamW(lr=3e-3, weight_decay=0.01)
    params, opt_state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, opt))
    mgr = CheckpointManager(args.ckpt, keep_n=2)
    mon = StragglerMonitor()
    loop = TrainLoop(cfg, step_fn, mgr, lambda s: ds.iterate(s), ckpt_every=100,
                     monitor=mon)
    print(f"training {cfg.name} for {args.steps} steps ...")
    params, opt_state, losses, end = loop.run(params, opt_state, 0, args.steps)
    print(f" loss: {losses[0]:.3f} -> {losses[-1]:.3f}  (straggler flags: {len(mon.flagged)})")

    # --- quantize + evaluate
    from benchmarks.common import calib_taps, eval_ppl, quantize_variant

    ppl_fp = eval_ppl(cfg, params, corpus)
    taps = calib_taps(cfg, params, corpus)
    cc = CalibConfig(rank=32, steps_global=40, steps_invert=40, steps_joint=20)
    qp = quantize_variant(cfg, params, "twinquant", QuantSpec(mode="w4a4", rank=32),
                          taps=taps, calib_cfg=cc)
    ppl_q = eval_ppl(cfg, qp, corpus)
    print(f" held-out ppl: fp16={ppl_fp:.2f}  TwinQuant-W4A4={ppl_q:.2f}")
    print("train_and_calibrate OK")


if __name__ == "__main__":
    main()
