"""Paged KV-cache serving runtime tests (DESIGN.md §14).

The load-bearing invariant is the same one the dense engine is held to,
under paging: serving a request through the paged engine interleaved with
arbitrary other traffic is token-for-token identical to serving it alone
through the DENSE engine (the A/B oracle). On top of that: the page
allocator leaks nothing and double-maps nothing under churn, a prefix-cache
hit skips the shared part of prefill while producing identical tokens, peak
cache usage tracks live tokens rather than slots x max_len, truncation is
flagged instead of silent, and prompt bucketing keeps the prefill
executable count logarithmic.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitizers import (
    assert_compile_budget,
    guarded_decode,
    page_invariant_checks,
)
from repro.configs import ModelConfig, get_config
from repro.launch.serve import (
    ContinuousBatchingEngine,
    PageAllocator,
    Request,
)
from repro.models import dense

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(
    name="tiny-paged", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, remat=False,
)

MLA_CFG = ModelConfig(
    name="tiny-paged-mla", family="mla_moe", n_layers=2, d_model=64, n_heads=4,
    d_ff=128, vocab=256, remat=False, first_k_dense=1,
    q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, n_experts=4, top_k=2, d_ff_expert=64, n_shared_experts=1,
)


@pytest.fixture(scope="module")
def params():
    return dense.init_params(CFG, jax.random.PRNGKey(0))


def _solo(cfg, params, prompt, max_new=8):
    """Dense-engine solo serving: the correctness oracle."""
    eng = ContinuousBatchingEngine(cfg, params, batch_slots=1, max_len=64)
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=max_new)
    eng.serve([req])
    assert req.done
    return req.out


def _interleaved_paged(cfg, params, a, b, max_new, **engine_kwargs):
    """Admit b while a is mid-generation on a paged engine; return outputs.

    The whole serving loop runs under the page-invariant sanitizer (the
    allocator audit fires after EVERY step, not just at the end), and the
    post-admission decode phase under the transfer-guard sanitizer."""
    eng = ContinuousBatchingEngine(
        cfg, params, batch_slots=2, max_len=64, paged=True, **engine_kwargs
    )
    with page_invariant_checks(eng):
        ra = Request(jnp.asarray(a, jnp.int32), max_new=max_new)
        eng.submit(ra)
        for _ in range(2):
            eng.step()
        rb = Request(jnp.asarray(b, jnp.int32), max_new=max_new)
        eng.submit(rb)
        # all admissions done: any device transfer from here on that is not a
        # marked sync-point is a hidden decode stall and raises
        with guarded_decode():
            eng.run_until_done()
    assert ra.done and rb.done
    return ra.out, rb.out, eng


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------


def test_page_allocator_churn():
    """Random alloc/share/release traffic wraps the free list repeatedly:
    no page leaks, no double-maps, free/used always partition the pool."""
    rng = np.random.default_rng(0)
    al = PageAllocator(13)
    held: list[list[int]] = []
    shared: list[int] = []
    for _ in range(500):
        r = rng.random()
        if held and r < 0.35:
            al.release(held.pop(int(rng.integers(len(held)))))
        elif held and r < 0.5:
            p = held[int(rng.integers(len(held)))][0]
            al.share([p])
            shared.append(p)
        elif shared and r < 0.6:
            al.release([shared.pop()])
        else:
            n = int(rng.integers(1, 5))
            pages = al.alloc(n)
            if pages is None:
                assert al.n_free < n  # refusal only ever for lack of pages
            else:
                assert len(set(pages)) == n
                held.append(pages)
        al.audit()
    for pages in held:
        al.release(pages)
    al.release(shared)
    al.audit()
    assert al.n_free == al.n_pages
    assert al.peak_used <= al.n_pages


def test_page_allocator_refusal_and_double_release():
    al = PageAllocator(4)
    pages = al.alloc(4)
    assert al.alloc(1) is None  # exhausted, not silently over-allocated
    al.release(pages)
    with pytest.raises(AssertionError):
        al.release([pages[0]])  # double release must be loud


# ---------------------------------------------------------------------------
# interleaving invariant under paging (the acceptance bar)
# ---------------------------------------------------------------------------


def test_paged_interleaving_invariant_dense(params):
    a = list(range(10, 22))
    b = list(range(100, 105))
    solo_a = _solo(CFG, params, a)
    solo_b = _solo(CFG, params, b)
    oa, ob, eng = _interleaved_paged(CFG, params, a, b, max_new=8, page_size=16)
    assert oa == solo_a
    assert ob == solo_b
    # decode traced exactly one executable; prefill bucketed
    assert eng.compile_stats()["decode_traces"] == 1


def test_paged_interleaving_invariant_scrambled_pages(params):
    """Small pages + churn before admission scramble the physical page order;
    block-table indirection must keep timelines exact regardless."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True, page_size=8, prefix_caching=False)
    # churn the free list so later admissions get non-contiguous pages
    for k in range(3):
        r = Request(jnp.asarray([7 + k, 8, 9], jnp.int32), max_new=3)
        eng.serve([r])
    a = list(range(30, 47))
    b = list(range(200, 206))
    ra = Request(jnp.asarray(a, jnp.int32), max_new=6)
    eng.submit(ra)
    eng.step()
    rb = Request(jnp.asarray(b, jnp.int32), max_new=6)
    eng.submit(rb)
    eng.run_until_done()
    eng.check_page_invariants()
    assert ra.out == _solo(CFG, params, a, max_new=6)
    assert rb.out == _solo(CFG, params, b, max_new=6)


@pytest.mark.slow
def test_paged_interleaving_invariant_mla():
    from repro.models import deepseek

    params = deepseek.init_params(MLA_CFG, jax.random.PRNGKey(1))
    a = list(range(10, 22))
    b = list(range(100, 105))
    oa, ob, _ = _interleaved_paged(MLA_CFG, params, a, b, max_new=5, page_size=16)
    assert oa == _solo(MLA_CFG, params, a, max_new=5)
    assert ob == _solo(MLA_CFG, params, b, max_new=5)


@pytest.mark.slow
def test_paged_vlm_frontend_rows():
    """VLM prefill prepends n_patches rows to the decoder cache: paged
    admission must reserve and write pages for prompt+patch rows, and the
    patch frontend must bypass the prefix cache (token hashes alone cannot
    identify an image)."""
    cfg = get_config("internvl2-2b", reduced=True).replace(remat=False)
    from repro.models import dense as dense_mod

    params = dense_mod.init_params(cfg, jax.random.PRNGKey(4))
    patches = jax.random.normal(
        jax.random.PRNGKey(5), (1, cfg.n_patches, cfg.d_model), jnp.bfloat16
    )
    prompt = list(range(5, 14))

    def serve_one(**kw):
        eng = ContinuousBatchingEngine(cfg, params, batch_slots=2, max_len=64, **kw)
        r = Request(jnp.asarray(prompt, jnp.int32), max_new=5,
                    frontend={"patches": patches})
        eng.serve([r])
        return r, eng

    r_dense, _ = serve_one()
    r_paged, eng = serve_one(paged=True, page_size=8)
    eng.check_page_invariants()
    assert r_paged.out == r_dense.out
    assert eng.stats["prefix_lookups"] == 0  # frontend requests skip the cache


@pytest.mark.slow
def test_paged_interleaving_invariant_mamba_hybrid():
    """Hybrid stack: the shared-attention K/V pages through the pool while
    the recurrent SSM/conv leaves stay per-slot state."""
    cfg = get_config("zamba2-1.2b", reduced=True).replace(remat=False)
    from repro.models import mamba_hybrid

    params = mamba_hybrid.init_params(cfg, jax.random.PRNGKey(2))
    a = list(range(10, 22))
    b = list(range(100, 105))
    oa, ob, eng = _interleaved_paged(cfg, params, a, b, max_new=5, page_size=16)
    assert "bt" in eng.state and "ssm" in eng.state  # pools + slot state coexist
    assert oa == _solo(cfg, params, a, max_new=5)
    assert ob == _solo(cfg, params, b, max_new=5)


# ---------------------------------------------------------------------------
# prefix cache
# ---------------------------------------------------------------------------


def test_prefix_cache_hit_equivalence(params):
    """A hit must SKIP the shared part of prefill (stats prove it) and still
    produce exactly the cold-miss tokens."""
    pre = list(range(1, 33))  # 4 full pages at page_size=8
    p1 = pre + [40, 41, 42]
    p2 = pre + [50, 51]
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True, page_size=8)
    r1 = Request(jnp.asarray(p1, jnp.int32), max_new=4)
    eng.serve([r1])
    cold_tokens = eng.stats["prefill_tokens"]
    assert cold_tokens == len(p1)
    r2 = Request(jnp.asarray(p2, jnp.int32), max_new=4)
    eng.serve([r2])
    eng.check_page_invariants()
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_hit_tokens"] == 32
    # only the 2-token suffix re-prefilled
    assert eng.stats["prefill_tokens"] - cold_tokens == len(p2) - 32
    # identical output to a cold engine with the prefix cache disabled
    cold = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                    paged=True, page_size=8, prefix_caching=False)
    r2c = Request(jnp.asarray(p2, jnp.int32), max_new=4)
    cold.serve([r2c])
    assert r2.out == r2c.out
    assert r2.out == _solo(CFG, params, p2, max_new=4)


@pytest.mark.slow
@pytest.mark.parametrize("family", ["mla_moe", "moe"])
def test_prefix_cache_hit_equivalence_other_families(family):
    """The suffix-prefill-with-prefix paths are family-specific (expanded
    latents for MLA, MoE FFN blocks for olmoe): hit tokens must equal the
    cold-miss tokens for them too."""
    if family == "mla_moe":
        cfg = MLA_CFG
        from repro.models import deepseek as mod
    else:
        cfg = get_config("olmoe-1b-7b", reduced=True).replace(
            remat=False, capacity_factor=4.0
        )
        from repro.models import olmoe as mod
    params = mod.init_params(cfg, jax.random.PRNGKey(5))
    pre = list(range(1, 25))  # 3 full pages at page_size=8
    p2 = pre + [30, 31]

    def serve_one(prefix_caching):
        eng = ContinuousBatchingEngine(cfg, params, batch_slots=1, max_len=64,
                                       paged=True, page_size=8,
                                       prefix_caching=prefix_caching)
        warm = Request(jnp.asarray(pre + [7], jnp.int32), max_new=3)
        eng.serve([warm])
        r = Request(jnp.asarray(p2, jnp.int32), max_new=4)
        eng.serve([r])
        eng.check_page_invariants()
        return r, eng

    hit, eng = serve_one(True)
    cold, _ = serve_one(False)
    assert eng.stats["prefix_hits"] == 1
    # 3 matched pages bucket down to 2 (power-of-two prefix offsets keep the
    # suffix-prefill executable inventory bounded)
    assert eng.stats["prefix_hit_tokens"] == 16
    assert hit.out == cold.out


def test_prefix_cache_hit_while_owner_live(params):
    """Sharing pages with a STILL-DECODING owner: the owner keeps writing its
    own tail pages, the shared prefix pages stay immutable, both match solo."""
    pre = list(range(60, 76))  # 2 full pages at page_size=8
    p1 = pre + [1, 2]
    p2 = pre + [3]
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True, page_size=8)
    r1 = Request(jnp.asarray(p1, jnp.int32), max_new=10)
    eng.submit(r1)
    eng.step()  # r1 mid-generation, its prompt pages now registered
    r2 = Request(jnp.asarray(p2, jnp.int32), max_new=10)
    eng.submit(r2)
    eng.run_until_done()
    eng.check_page_invariants()
    assert eng.stats["prefix_hits"] == 1
    assert r1.out == _solo(CFG, params, p1, max_new=10)
    assert r2.out == _solo(CFG, params, p2, max_new=10)


def test_prefix_hit_survives_eviction_pressure(params):
    """A matched prefix whose cache entries get evicted mid-admission (page
    pressure) must keep its pages alive through the requester's reference —
    the request either admits correctly or waits, never reads recycled
    pages."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True, page_size=8, n_pages=8)
    p1 = list(range(0, 17))       # prefix A: 2 cached pages
    pb = list(range(100, 117))    # prefix B: 2 cached pages
    for p in (p1, pb):
        eng.serve([Request(jnp.asarray(p, jnp.int32), max_new=3)])
    assert len(eng.prefix_cache) == 4 and eng.allocator.n_free == 4
    # matches A (2 shared), needs 5 own pages > 4 free: admission must evict
    # prefix B's entries while A's matched pages stay pinned by this request
    p2 = p1[:16] + list(range(200, 209))
    r2 = Request(jnp.asarray(p2, jnp.int32), max_new=25)
    eng.serve([r2])
    eng.check_page_invariants()
    assert r2.done
    assert eng.stats["prefix_hits"] == 1
    assert r2.out == _solo(CFG, params, p2, max_new=25)
    # an impossible request (worst-case pages > whole pool) is rejected at
    # submit instead of spinning the serve loop forever
    tiny = ContinuousBatchingEngine(CFG, params, batch_slots=1, max_len=64,
                                    paged=True, page_size=8, n_pages=4)
    with pytest.raises(ValueError, match="pool"):
        tiny.submit(Request(jnp.asarray(list(range(40)), jnp.int32), max_new=16))


def test_prefix_cache_eviction_under_page_pressure(params):
    """When the pool runs dry, LRU prefix entries are evicted to free pages
    and admission proceeds; outputs stay correct throughout."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True, page_size=8, n_pages=10)
    prompts = [list(range(base, base + 17)) for base in (0, 40, 80, 120, 160)]
    for p in prompts:
        r = Request(jnp.asarray(p, jnp.int32), max_new=3)
        eng.serve([r])
        eng.check_page_invariants()
        assert r.out == _solo(CFG, params, p, max_new=3)
    # pool of 10 pages cannot hold 5 prompts' worth of cached prefixes
    assert eng.memory()["pages_in_use"] <= 10


# ---------------------------------------------------------------------------
# engine churn: free list wraps, nothing leaks, page gating admits in order
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_engine_page_churn_no_leak(params):
    """Admit/evict until the free list wraps several times over a pool that
    cannot hold all in-flight requests at once: every request still matches
    solo serving, and the allocator/block-table/refcount invariants hold
    after every drain."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=3, max_len=64,
                                   paged=True, page_size=8, n_pages=9)
    rng = np.random.default_rng(7)
    for round_ in range(6):
        prompts = [
            [int(t) for t in rng.integers(0, CFG.vocab, int(rng.integers(2, 14)))]
            for _ in range(4)
        ]
        reqs = [Request(jnp.asarray(p, jnp.int32), max_new=3) for p in prompts]
        eng.serve(reqs)
        eng.check_page_invariants()
        assert all(r.done for r in reqs)
        for p, r in zip(prompts, reqs):
            assert r.out == _solo(CFG, params, p, max_new=3), (round_, p)
    # after the churn the only held pages are prefix-cache registrations
    mem = eng.memory()
    cached = 0 if eng.prefix_cache is None else len(eng.prefix_cache)
    assert mem["pages_in_use"] == cached
    assert eng.allocator.peak_used <= eng.n_pages


# ---------------------------------------------------------------------------
# memory, truncation, bucketing
# ---------------------------------------------------------------------------


def test_peak_cache_memory_below_dense(params):
    """Short-prompt workload: peak paged cache bytes land well under the
    dense B x S_max footprint the same engine would pin."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=4, max_len=64,
                                   paged=True, page_size=8)
    reqs = [Request(jnp.asarray([i, i + 1, i + 2], jnp.int32), max_new=3)
            for i in range(0, 40, 10)]
    eng.serve(reqs)
    mem = eng.memory()
    assert mem["mode"] == "paged"
    assert mem["peak_cache_bytes"] < mem["dense_cache_bytes"] / 2, mem


def test_truncation_flagged_not_silent(params):
    """prompt_len + max_new > max_len: warned at submit, served to capacity,
    flagged truncated at eviction — in both dense and paged modes."""
    for paged in (False, True):
        eng = ContinuousBatchingEngine(CFG, params, batch_slots=1, max_len=16,
                                       paged=paged, page_size=8)
        req = Request(jnp.asarray(list(range(10)), jnp.int32), max_new=12)
        with pytest.warns(UserWarning, match="truncate"):
            eng.serve([req])
        assert req.done and req.truncated, paged
        assert 0 < len(req.out) < 12, paged
        assert eng.stats["requests_truncated"] == 1
        # an untruncated request must NOT be flagged
        ok = Request(jnp.asarray([1, 2, 3], jnp.int32), max_new=4)
        eng.serve([ok])
        assert ok.done and not ok.truncated


def test_truncation_reject_policy(params):
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=1, max_len=16,
                                   on_truncation="reject")
    with pytest.raises(ValueError, match="truncate"):
        eng.submit(Request(jnp.asarray(list(range(10)), jnp.int32), max_new=12))
    # the bad request never touched queue or slots
    assert not eng.queue and eng.slots == [None]


def test_bucketed_prefill_compile_stats(params):
    """11 distinct prompt lengths collapse into O(log max_len) prefill
    executables, with outputs identical to solo serving."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64)
    prompts = [list(range(1, 2 + n)) for n in range(11)]
    reqs = [Request(jnp.asarray(p, jnp.int32), max_new=3) for p in prompts]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng.serve(reqs)
    cs = eng.compile_stats()
    assert cs["prefill_calls"] == len(prompts)
    assert cs["prefill_traces"] <= 3, cs  # buckets 8 and 16 only
    assert set(cs["prefill_buckets"]) <= {8, 16}
    # the ratchet form of the same bound: O(log max_len) per variant
    assert_compile_budget(eng)
    for p, r in zip(prompts, reqs):
        assert r.out == _solo(CFG, params, p, max_new=3)
