"""quantcheck self-tests: every rule must flag its known-bad fixture, stay
quiet on the idiomatic-good twin, and the full catalog must run clean on the
repo's own src/ tree (the blocking `analyze` CI lane contract)."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import analyze_source, all_rules, render_json
from repro.analysis.core import analyze_paths

REPO = Path(__file__).resolve().parents[1]

HEADER = """
import functools
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
"""


def findings_for(snippet: str, rule: str | None = None):
    out = analyze_source(HEADER + snippet, "fixture.py")
    if rule is not None:
        out = [f for f in out if f.rule == rule]
    return out


# ---------------------------------------------------------------------------
# PK001: index_map arity / block-rank / purity
# ---------------------------------------------------------------------------

GOOD_WRAPPER = """
def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def launch(x, m, n, bm, bn):
    validate_blocks(m, n, bm, bn)
    return pl.pallas_call(
        _kern,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni))],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
    )(x)
"""


def test_good_wrapper_is_clean():
    assert findings_for(GOOD_WRAPPER) == []


def test_pk001_arity_mismatch():
    bad = GOOD_WRAPPER.replace(
        "in_specs=[pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni))]",
        "in_specs=[pl.BlockSpec((bm, bn), lambda mi: (mi, 0))]",
    )
    msgs = [f.message for f in findings_for(bad, "PK001")]
    assert any("grid has rank 2" in m for m in msgs), msgs


def test_pk001_block_rank_mismatch():
    bad = GOOD_WRAPPER.replace(
        "in_specs=[pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni))]",
        "in_specs=[pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni, 0))]",
    )
    msgs = [f.message for f in findings_for(bad, "PK001")]
    assert any("3 block coordinates" in m for m in msgs), msgs


def test_pk001_impure_index_map():
    bad = GOOD_WRAPPER.replace(
        "lambda mi, ni: (mi, ni))]",
        "lambda mi, ni: (mi, int(np.sqrt(ni))))]",
    )
    msgs = [f.message for f in findings_for(bad, "PK001")]
    assert any("impure index_map" in m for m in msgs), msgs


def test_pk001_jnp_where_is_pure():
    good = GOOD_WRAPPER.replace(
        "lambda mi, ni: (mi, ni))]",
        "lambda mi, ni: (mi, jnp.where(ni == 0, ni, 0)))]",
    )
    assert findings_for(good, "PK001") == []


# ---------------------------------------------------------------------------
# PK002: unguarded integer-division block shapes
# ---------------------------------------------------------------------------


def test_pk002_unguarded_division():
    bad = GOOD_WRAPPER.replace("validate_blocks(m, n, bm, bn)\n    ", "").replace(
        "pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni))]",
        "pl.BlockSpec((bm, bn // 2), lambda mi, ni: (mi, ni))]",
    )
    msgs = [f.message for f in findings_for(bad, "PK002")]
    assert any("bn // 2" in m for m in msgs), msgs


def test_pk002_assert_guard_accepted():
    guarded = GOOD_WRAPPER.replace(
        "validate_blocks(m, n, bm, bn)",
        "assert bn % 2 == 0",
    ).replace(
        "pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni))]",
        "pl.BlockSpec((bm, bn // 2), lambda mi, ni: (mi, ni))]",
    )
    # the grid divisions lost their guard with the validate call removed and
    # are still flagged; the asserted `bn // 2` division must NOT be
    msgs = [f.message for f in findings_for(guarded, "PK002")]
    assert not any("bn // 2" in m for m in msgs), msgs
    assert any("m // bm" in m for m in msgs), msgs


def test_pk002_contract_call_accepted():
    # the validate_* call in GOOD_WRAPPER guards ALL divisions in the launch
    guarded = GOOD_WRAPPER.replace(
        "pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni))]",
        "pl.BlockSpec((bm, bn // 2), lambda mi, ni: (mi, ni))]",
    )
    assert findings_for(guarded, "PK002") == []


# ---------------------------------------------------------------------------
# PK003: pinned-panel specs must be constant-zero maps
# ---------------------------------------------------------------------------


def test_pk003_nonzero_pinned_spec():
    bad = GOOD_WRAPPER.replace(
        "in_specs=[pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni))]",
        "in_specs=[pl.BlockSpec((bm, bn), lambda mi, ni: (1, 0))]",
    )
    msgs = [f.message for f in findings_for(bad, "PK003")]
    assert any("must return zeros" in m for m in msgs), msgs


def test_pk003_zero_pinned_spec_ok():
    good = GOOD_WRAPPER.replace(
        "in_specs=[pl.BlockSpec((bm, bn), lambda mi, ni: (mi, ni))]",
        "in_specs=[pl.BlockSpec((bm, bn), lambda mi, ni: (0, 0))]",
    )
    assert findings_for(good, "PK003") == []


# ---------------------------------------------------------------------------
# PK004: kernel-body hygiene
# ---------------------------------------------------------------------------


def test_pk004_host_ops_in_kernel():
    bad = """
def _kern(x_ref, o_ref):
    v = np.sum(x_ref[...])
    v2 = x_ref[...].item()
    o_ref[...] = x_ref[...] * v * v2
"""
    msgs = [f.message for f in findings_for(bad, "PK004")]
    assert any("host numpy op" in m for m in msgs), msgs
    assert any(".item()" in m for m in msgs), msgs


def test_pk004_python_float_accumulation():
    bad = """
def _kern(x_ref, o_ref):
    acc = 0.0
    for g in range(4):
        acc += float(x_ref[0, g])
    o_ref[0, 0] = acc
"""
    msgs = [f.message for f in findings_for(bad, "PK004")]
    assert any("Python-float accumulation" in m for m in msgs), msgs


def test_pk004_resolves_partial_kernels():
    # a kernel bound via functools.partial and launched by name is still seen
    bad = """
def _impl(x_ref, o_ref, *, c):
    bad = np.ones(3)
    o_ref[...] = x_ref[...] * c * bad[0]

def launch(x, m, n):
    kernel = functools.partial(_impl, c=2)
    return pl.pallas_call(
        kernel,
        grid=(m, n),
        in_specs=[pl.BlockSpec((1, 1), lambda mi, ni: (mi, ni))],
        out_specs=pl.BlockSpec((1, 1), lambda mi, ni: (mi, ni)),
        out_shape=None,
    )(x)
"""
    msgs = [f.message for f in findings_for(bad, "PK004")]
    assert any("host numpy op" in m for m in msgs), msgs


# ---------------------------------------------------------------------------
# EN001/EN002: engine step hygiene
# ---------------------------------------------------------------------------

ENGINE_FIXTURE = """
class ToyEngine:
    def step(self):
        tok = np.zeros((4, 1), np.int32)
        pos = np.asarray(self.state["pos"])
        logits = self.decode(tok)
        last = np.asarray(logits)  # sync-point
        return last, pos
"""


def test_en001_unmarked_sync_flagged_marked_allowed():
    found = findings_for(ENGINE_FIXTURE, "EN001")
    # np.zeros is not a sync; the unmarked np.asarray is; the marked one isn't
    assert len(found) == 1, [f.human() for f in found]
    assert "np.asarray" in found[0].message


def test_en002_jit_in_step():
    bad = """
class ToyEngine:
    def step(self):
        f = jax.jit(self._fn)
        return f()
"""
    msgs = [f.message for f in findings_for(bad, "EN002")]
    assert any("jax.jit constructed" in m for m in msgs), msgs


def test_en_rules_ignore_non_engine_classes():
    harmless = ENGINE_FIXTURE.replace("ToyEngine", "ToyDriver")
    assert findings_for(harmless, "EN001") == []


def test_en001_polices_step_variants():
    # the ragged engine's _step_ragged is a per-token hot path like step()
    ragged = ENGINE_FIXTURE.replace("def step(self):", "def _step_ragged(self):")
    found = findings_for(ragged, "EN001")
    assert len(found) == 1 and "_step_ragged" in found[0].message, \
        [f.human() for f in found]


def test_en002_covers_ragged_step_names():
    bad = """
def _step_ragged(self):
    return jax.jit(self._fn)()
"""
    msgs = [f.message for f in findings_for(bad, "EN002")]
    assert any("jax.jit constructed" in m for m in msgs), msgs


def test_en003_alloc_without_release_flagged():
    # known-bad twin: pages allocated, then work that can throw, no handler
    # that hands the reservation back — the leak EN003 exists to catch
    bad = """
class ToyEngine:
    def _admit_one(self, req, i):
        pages = self.allocator.alloc(4)
        if pages is None:
            return False
        last = self._run_prefill(req)
        self.slots[i] = req
        return True
"""
    found = findings_for(bad, "EN003")
    assert len(found) == 1, [f.human() for f in found]
    assert "no try/except/finally" in found[0].message


def test_en003_release_in_handler_passes():
    # known-good twins: an except handler releasing directly, and a finally
    # routing through the eviction helper, both dominate the allocation
    good_except = """
class ToyEngine:
    def _admit_one(self, req, i):
        pages = self.allocator.alloc(4)
        try:
            last = self._run_prefill(req)
        except Exception:
            self.allocator.release(pages)
            raise
        return True
"""
    good_finally = """
class ToyEngine:
    def _admit_one(self, req, i):
        pages = self.allocator.alloc(4)
        ok = False
        try:
            last = self._run_prefill(req)
            ok = True
        finally:
            if not ok:
                self._release_slot(i)
        return True
"""
    assert findings_for(good_except, "EN003") == []
    assert findings_for(good_finally, "EN003") == []


def test_en003_ignores_non_engine_classes():
    harmless = """
class PoolManager:
    def grab(self):
        return self.allocator.alloc(4)
"""
    assert findings_for(harmless, "EN003") == []


# ---------------------------------------------------------------------------
# PK001: scalar-prefetch subscripts in index maps
# ---------------------------------------------------------------------------


PREFETCH_WRAPPER = """
def _kern(bt_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]

def launch(bt, x, b, maxp, page, d):
    validate_blocks(b, maxp, page, d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, maxp),
        in_specs=[
            pl.BlockSpec((1, page, d), lambda bi, ji, bts: (bts[bi, ji], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, page, d), lambda bi, ji, bts: (bi, 0, 0)),
    )
    return pl.pallas_call(
        _kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, page, d), jnp.float32),
    )(bt, x)
"""


def test_pk001_param_subscript_allowed():
    # PrefetchScalarGridSpec appends prefetched refs to the index-map args:
    # subscripting those PARAMETERS (block-table lookups) is the idiom
    assert findings_for(PREFETCH_WRAPPER, "PK001") == []


def test_pk001_free_name_subscript_still_flagged():
    bad = GOOD_WRAPPER.replace(
        "lambda mi, ni: (mi, ni))]",
        "lambda mi, ni: (table[mi], ni))]",
    )
    msgs = [f.message for f in findings_for(bad, "PK001")]
    assert any("subscripts of lambda parameters" in m for m in msgs), msgs


# ---------------------------------------------------------------------------
# DC001: docstring coverage of the documented API surface
# ---------------------------------------------------------------------------

DOCS_FIXTURE = '''
"""Module docstring."""

def public_fn():
    """Documented."""

class PublicClass:
    """Documented class."""

    def documented(self):
        """Documented method."""

    def _private(self):
        pass
'''


def test_dc001_clean_surface_passes():
    out = analyze_source(DOCS_FIXTURE, "src/repro/launch/serve.py")
    assert [f for f in out if f.rule == "DC001"] == []


def test_dc001_flags_missing_docstrings():
    bad = DOCS_FIXTURE.replace('def public_fn():\n    """Documented."""',
                               "def public_fn():\n    pass")
    bad = bad.replace('def documented(self):\n        """Documented method."""',
                      "def documented(self):\n        pass")
    out = [f for f in analyze_source(bad, "src/repro/kernels/dispatch.py")
           if f.rule == "DC001"]
    names = " ".join(f.message for f in out)
    assert "public_fn" in names and "PublicClass.documented" in names
    assert len(out) == 2, [f.human() for f in out]


def test_dc001_ignores_uncovered_paths():
    bad = "def undocumented():\n    pass\n"
    out = analyze_source(bad, "src/repro/models/common.py")
    assert [f for f in out if f.rule == "DC001"] == []


# ---------------------------------------------------------------------------
# catalog / CLI / repo-clean contracts
# ---------------------------------------------------------------------------


def test_rule_catalog_complete():
    assert set(all_rules()) == {
        "PK001", "PK002", "PK003", "PK004", "EN001", "EN002", "EN003", "DC001",
    }


def test_repo_src_is_clean():
    findings, n_files = analyze_paths([str(REPO / "src")])
    assert n_files > 0
    assert findings == [], "\n".join(f.human() for f in findings)


def test_json_report_shape():
    findings, n = analyze_paths([str(REPO / "src" / "repro" / "kernels")])
    doc = json.loads(render_json(findings, n))
    assert doc["schema"] == 1 and doc["files"] == n and doc["findings"] == []


@pytest.mark.parametrize("clean", [True, False])
def test_cli_exit_codes(tmp_path, clean):
    target = tmp_path / "mod.py"
    if clean:
        target.write_text("x = 1\n")
    else:
        target.write_text(HEADER + ENGINE_FIXTURE)
    report = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(target), "--json", str(report)],
        capture_output=True,
        text=True,
        cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert proc.returncode == (0 if clean else 1), proc.stdout + proc.stderr
    doc = json.loads(report.read_text())
    assert (len(doc["findings"]) == 0) == clean


def test_parse_error_is_a_finding():
    bad = "def broken(:\n"
    found = analyze_source(bad, "broken.py")
    assert found and found[0].rule == "PARSE"
