"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle across
shape/dtype/block sweeps (deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade property tests to fixed-seed cases
    from hypothesis_fallback import given, settings, strategies as st

from repro.kernels.ops import (
    pack_twinquant_weights,
    twinquant_matmul,
    w4a16_matmul,
)
from repro.kernels.ref import (
    dual_gemm_ref,
    pack_rows_groupsplit,
    quantize_rows_ref,
    unpack_rows_groupsplit,
    w4a16_gemm_ref,
)
from repro.kernels.twinquant_dual_gemm import dual_gemm
from repro.kernels.w4a16_gemm import w4a16_gemm


def _assert_bf16_close(y_k, y_ref):
    """Interpret-mode Pallas vs jnp oracle: identical math, but f32
    reassociation in the fused epilogue shifts the final bf16 rounding of
    near-zero elements (catastrophic cancellation) by up to 2 ULPs on this
    platform — allow bit-distance <= 2, nothing coarser. A real scale bug
    moves outputs by hundreds of ULPs."""
    a = np.asarray(jnp.asarray(y_k, jnp.bfloat16)).view(np.uint16).astype(np.int32)
    b = np.asarray(jnp.asarray(y_ref, jnp.bfloat16)).view(np.uint16).astype(np.int32)
    # sign-magnitude -> monotonic key so ULP distance is a plain difference
    ka = np.where(a & 0x8000, 0x7FFF - (a & 0x7FFF), 0x8000 + a)
    kb = np.where(b & 0x8000, 0x7FFF - (b & 0x7FFF), 0x8000 + b)
    ulp = np.abs(ka - kb)
    assert ulp.max() <= 2, f"{(ulp > 2).sum()} elements differ by >2 bf16 ULP (max {ulp.max()})"


def _make_layer(key, K, N, r, scale=0.1):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    U = jax.random.normal(k1, (K, r)) * scale
    V = jax.random.normal(k2, (r, N)) * scale
    R = jax.random.normal(k3, (K, N)) * scale * 0.5
    return U, V, R, k4


# ---------------------------------------------------------------------------
# packing invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("group", [16, 64, 128])
def test_pack_unpack_groupsplit(group):
    key = jax.random.PRNGKey(0)
    q = jax.random.randint(key, (256, 96), -7, 8, dtype=jnp.int8)
    p = pack_rows_groupsplit(q, group)
    assert p.shape == (128, 96)
    np.testing.assert_array_equal(np.asarray(unpack_rows_groupsplit(p, group)), np.asarray(q))


def test_pack_block_locality():
    """The property the kernel tiling relies on: a (bk/2) packed row-slice of
    a group-aligned block unpacks to exactly that block's logical rows."""
    key = jax.random.PRNGKey(1)
    G, K, N, bk = 128, 1024, 32, 256
    q = jax.random.randint(key, (K, N), -7, 8, dtype=jnp.int8)
    p = pack_rows_groupsplit(q, G)
    for kb in range(K // bk):
        block = p[kb * bk // 2 : (kb + 1) * bk // 2]
        logical = unpack_rows_groupsplit(block, G)
        np.testing.assert_array_equal(
            np.asarray(logical), np.asarray(q[kb * bk : (kb + 1) * bk])
        )


# ---------------------------------------------------------------------------
# dual-component kernel vs oracle: shape sweep
# ---------------------------------------------------------------------------

SHAPES = [
    # (M, K, N, r, bm, bn, bk)
    (64, 256, 128, 32, 64, 128, 128),
    (128, 512, 256, 64, 128, 128, 256),
    (128, 512, 256, 128, 64, 256, 512),
    (256, 1024, 384, 64, 128, 128, 256),
    (8, 256, 256, 32, 8, 128, 256),  # decode-like tiny M
]


@pytest.mark.parametrize("M,K,N,r,bm,bn,bk", SHAPES)
def test_dual_gemm_matches_ref(M, K, N, r, bm, bn, bk):
    key = jax.random.PRNGKey(hash((M, K, N, r)) % 2**31)
    U, V, R, kx = _make_layer(key, K, N, r)
    x = (jax.random.normal(kx, (M, K)) * 2).astype(jnp.bfloat16)
    w = pack_twinquant_weights(U, V, R, a_bits=4)
    y_ref = dual_gemm_ref(x, w)
    y_k = dual_gemm(x, w, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    _assert_bf16_close(y_k, y_ref)


@pytest.mark.parametrize("a_bits", [4, 8])
def test_dual_gemm_a_bits(a_bits):
    key = jax.random.PRNGKey(7)
    U, V, R, kx = _make_layer(key, 512, 256, 64)
    x = (jax.random.normal(kx, (64, 512)) * 3).astype(jnp.bfloat16)
    w = pack_twinquant_weights(U, V, R, a_bits=a_bits)
    y_ref = dual_gemm_ref(x, w)
    y_k = dual_gemm(x, w, block_m=64, block_n=128, block_k=256, interpret=True)
    _assert_bf16_close(y_k, y_ref)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_dual_gemm_input_dtypes(dtype):
    key = jax.random.PRNGKey(8)
    U, V, R, kx = _make_layer(key, 256, 128, 32)
    x = (jax.random.normal(kx, (32, 256)) * 2).astype(dtype)
    w = pack_twinquant_weights(U, V, R)
    y_ref = dual_gemm_ref(x, w)
    y_k = dual_gemm(x, w, block_m=32, block_n=128, block_k=128, interpret=True)
    _assert_bf16_close(y_k, y_ref)


def test_dual_gemm_accuracy_vs_fp():
    """End-to-end numeric sanity: W4A4 output within a few percent of fp32."""
    key = jax.random.PRNGKey(3)
    U, V, R, kx = _make_layer(key, 1024, 512, 128, scale=0.05)
    x = jax.random.normal(kx, (128, 1024))
    w_full = U @ V + R
    y_fp = x @ w_full
    wq = pack_twinquant_weights(U, V, R, a_bits=4)
    y_q = dual_gemm_ref(x.astype(jnp.bfloat16), wq).astype(jnp.float32)
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    # iid-Gaussian layers are the worst case for 4-bit (no outlier structure
    # for the decomposition to absorb); this is a sanity bound, exactness is
    # covered by the kernel-vs-ref tests
    assert rel < 0.3, rel
    # W4A8 must be strictly more accurate than W4A4
    wq8 = pack_twinquant_weights(U, V, R, a_bits=8)
    y_q8 = dual_gemm_ref(x.astype(jnp.bfloat16), wq8).astype(jnp.float32)
    rel8 = float(jnp.linalg.norm(y_q8 - y_fp) / jnp.linalg.norm(y_fp))
    assert rel8 < rel


# ---------------------------------------------------------------------------
# w4a16 kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (64, 256, 128, 64, 128, 128),
    (128, 1024, 256, 128, 128, 512),
    (8, 512, 384, 8, 128, 256),
])
def test_w4a16_matches_ref(M, K, N, bm, bn, bk):
    key = jax.random.PRNGKey(hash((M, K, N)) % 2**31)
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (K, N)) * 0.1
    x = (jax.random.normal(k2, (M, K))).astype(jnp.bfloat16)
    wq, ws = quantize_rows_ref(w, 128, 4)
    wp = pack_rows_groupsplit(wq, 128)
    y_ref = w4a16_gemm_ref(x, wp, ws, group=128)
    y_k = w4a16_gemm(x, wp, ws, group=128, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_ref, np.float32), rtol=0, atol=0
    )


# ---------------------------------------------------------------------------
# ops.py wrappers: padding, batch dims, bias
# ---------------------------------------------------------------------------


def test_twinquant_matmul_batch_and_pad():
    key = jax.random.PRNGKey(11)
    U, V, R, kx = _make_layer(key, 256, 128, 32)
    w = pack_twinquant_weights(U, V, R)
    x = (jax.random.normal(kx, (3, 5, 256))).astype(jnp.bfloat16)  # M=15, pads
    y = twinquant_matmul(x, w, block_m=8, block_n=128, block_k=128)
    assert y.shape == (3, 5, 128)
    y_ref = dual_gemm_ref(x.reshape(15, 256), w).reshape(3, 5, 128)
    _assert_bf16_close(y, y_ref)


def test_twinquant_matmul_bias():
    key = jax.random.PRNGKey(12)
    U, V, R, kx = _make_layer(key, 256, 128, 32)
    w = pack_twinquant_weights(U, V, R)
    x = (jax.random.normal(kx, (16, 256))).astype(jnp.bfloat16)
    b = jnp.arange(128, dtype=jnp.float32) * 0.01
    y = twinquant_matmul(x, w, b, use_ref=True)
    y0 = twinquant_matmul(x, w, use_ref=True)
    np.testing.assert_allclose(
        np.asarray(y, np.float32),
        np.asarray((y0.astype(jnp.float32) + b).astype(jnp.bfloat16), np.float32),
    )


def test_w4a16_matmul_wrapper():
    key = jax.random.PRNGKey(13)
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (256, 128)) * 0.1
    x = (jax.random.normal(k2, (10, 256))).astype(jnp.bfloat16)
    wq, ws = quantize_rows_ref(w, 128, 4)
    wp = pack_rows_groupsplit(wq, 128)
    y = w4a16_matmul(x, wp, ws, block_m=8, block_n=128, block_k=128)
    y_ref = w4a16_gemm_ref(x, wp, ws)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref, np.float32))


# ---------------------------------------------------------------------------
# property: kernel == ref for random (small) shapes
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.sampled_from([(128, 128, 32), (256, 128, 64), (256, 256, 32)]),
    st.sampled_from([4, 8]),
)
def test_property_dual_gemm_exactness(seed, knr, a_bits):
    K, N, r = knr
    key = jax.random.PRNGKey(seed)
    U, V, R, kx = _make_layer(key, K, N, r, scale=0.2)
    x = (jax.random.normal(kx, (16, K)) * 4).astype(jnp.bfloat16)
    w = pack_twinquant_weights(U, V, R, a_bits=a_bits)
    y_ref = dual_gemm_ref(x, w)
    y_k = dual_gemm(x, w, block_m=16, block_n=128, block_k=128, interpret=True)
    _assert_bf16_close(y_k, y_ref)
