"""Trace-time contract-layer tests: divisibility/grid-coverage violations and
over-budget VMEM launches must raise a readable ContractError (never a bare
assert tuple, a Mosaic error, or a silent ref fallback), malformed packs must
be diagnosed at the dispatch entries, ref fallbacks must record their reason
in the dispatch counters, and a corrupt tune cache must degrade with a
warning instead of crashing or poisoning routing."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.autotune import TuneCache, cache_key, get_blocks, heuristic_blocks
from repro.kernels.contracts import (
    ContractError,
    check_vmem,
    validate_dual_gemm,
    validate_dual_gemm_group,
    validate_dual_gemv,
    validate_dual_gemv_group,
    validate_w4a16,
    vmem_footprint,
)
from repro.kernels.dispatch import (
    dispatch_counters,
    fused_linear,
    quant_linear,
    reset_dispatch_counters,
    w4a16_linear,
)
from repro.kernels.ref import (
    fuse_twinquant_weights,
    pack_rows_groupsplit,
    pack_twinquant_weights,
    quantize_rows_ref,
)
from repro.kernels.twinquant_dual_gemm import dual_gemm
from repro.kernels.twinquant_dual_gemv import dual_gemv


def _make_pack(key, K, N, r, a_bits=4, group=128):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    U = jax.random.normal(k1, (K, r)) * 0.1
    V = jax.random.normal(k2, (r, N)) * 0.1
    R = jax.random.normal(k3, (K, N)) * 0.05
    return pack_twinquant_weights(U, V, R, a_bits=a_bits, group=group), k4


# ---------------------------------------------------------------------------
# divisibility / grid-coverage contracts
# ---------------------------------------------------------------------------


def test_validate_dual_gemm_accepts_canonical_shapes():
    validate_dual_gemm(256, 512, 1024, 64, 128, 32, 128, 256, 512)


@pytest.mark.parametrize("field,args,fragment", [
    # m=200 not a multiple of block_m=128
    ("M", (200, 512, 1024, 64, 128, 32, 128, 256, 512), "M % block_m"),
    # n=500 not a multiple of block_n=256
    ("N", (256, 500, 1024, 64, 128, 32, 128, 256, 512), "N % block_n"),
    # block_k=384 not a multiple of group=256
    ("bk", (256, 512, 1536, 64, 256, 32, 128, 256, 384), "block_k % group"),
    # rank=60 not a multiple of rgroup=32
    ("r", (256, 512, 1024, 60, 128, 32, 128, 256, 512), "rank % rgroup"),
])
def test_validate_dual_gemm_violations_are_readable(field, args, fragment):
    with pytest.raises(ContractError) as ei:
        validate_dual_gemm(*args)
    assert fragment in str(ei.value)
    assert "hint" in str(ei.value)


def test_validate_dual_gemv_decode_bound():
    with pytest.raises(ContractError, match="DECODE_M_MAX"):
        validate_dual_gemv(9, 512, 1024, 64, 128, 32, 256, decode_m_max=8)


def test_validate_group_segment_straddle():
    # block_n=256 does not tile the 128-wide second segment
    with pytest.raises(ContractError, match="segment 1"):
        validate_dual_gemv_group(
            4, 1024, 128, (512, 128), (64, 32), (32, 32), 256, decode_m_max=8
        )
    with pytest.raises(ContractError, match="segment 1"):
        validate_dual_gemm_group(
            256, 1024, 128, (512, 128), (64, 32), (32, 32), 128, 256, 512
        )


def test_validate_w4a16_violation():
    with pytest.raises(ContractError, match="K % block_k"):
        validate_w4a16(128, 256, 700, 128, 128, 256, 512)


def test_kernel_wrapper_raises_contract_error_not_assert(monkeypatch):
    """Deliberately violating a BlockSpec divisibility contract at a kernel
    wrapper produces the readable ContractError (acceptance criterion)."""
    w, key = _make_pack(jax.random.PRNGKey(0), 512, 256, 32)
    x = jax.random.normal(key, (200, 512)).astype(jnp.bfloat16)  # 200 % 128 != 0
    with pytest.raises(ContractError, match="M % block_m"):
        dual_gemm(x, w, block_m=128, block_n=256, block_k=512, interpret=True)
    xb = jax.random.normal(key, (16, 512)).astype(jnp.bfloat16)  # M > decode bound
    with pytest.raises(ContractError, match="DECODE_M_MAX"):
        dual_gemv(xb, w, block_n=256, interpret=True)


# ---------------------------------------------------------------------------
# VMEM footprint estimator
# ---------------------------------------------------------------------------


def test_vmem_footprint_double_buffers_streamed():
    total, breakdown = vmem_footprint([
        ("x", (128, 512), jnp.bfloat16, "streamed"),
        ("u", (256, 64), jnp.int8, "pinned"),
        ("acc", (128, 256), jnp.float32, "scratch"),
    ])
    assert breakdown["x"] == 128 * 512 * 2 * 2  # bf16, double-buffered
    assert breakdown["u"] == 256 * 64           # pinned once
    assert breakdown["acc"] == 128 * 256 * 4
    assert total == sum(breakdown.values())


def test_check_vmem_over_budget_is_readable():
    with pytest.raises(ContractError) as ei:
        check_vmem(
            "dual_gemm",
            [("x", (4096, 4096), jnp.float32, "streamed")],
            budget=16 * 2**20,
        )
    msg = str(ei.value)
    assert "VMEM footprint" in msg and "x" in msg and "MiB" in msg


def test_wrapper_vmem_budget_env(monkeypatch):
    """An otherwise-valid launch is rejected when the budget is tightened —
    a readable contract error, not a Mosaic allocation failure."""
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", str(64 * 1024))
    w, key = _make_pack(jax.random.PRNGKey(1), 512, 256, 32)
    x = jax.random.normal(key, (128, 512)).astype(jnp.bfloat16)
    with pytest.raises(ContractError, match="VMEM footprint"):
        dual_gemm(x, w, block_m=128, block_n=256, block_k=512, interpret=True)


# ---------------------------------------------------------------------------
# pack contracts at the dispatch entries
# ---------------------------------------------------------------------------


def test_malformed_pack_diagnosed_not_silently_ref():
    """A pack whose fields disagree (here: scales for the wrong K) raises a
    ContractError diagnostic instead of silently routing to ref."""
    w, key = _make_pack(jax.random.PRNGKey(2), 512, 256, 32)
    bad = dataclasses.replace(w, us=w.us[:2])  # covers K=256, activation K=512
    x = jax.random.normal(key, (4, 512)).astype(jnp.bfloat16)
    reset_dispatch_counters()
    with pytest.raises(ContractError, match="us"):
        quant_linear(x, bad)
    assert dispatch_counters() == {}  # rejected before any route was recorded


def test_malformed_pack_wrong_dtype():
    w, key = _make_pack(jax.random.PRNGKey(3), 512, 256, 32)
    bad = dataclasses.replace(w, up=w.up.astype(jnp.float32))
    x = jax.random.normal(key, (4, 512)).astype(jnp.bfloat16)
    with pytest.raises(ContractError, match="int8"):
        quant_linear(x, bad)


def test_malformed_group_pack_diagnosed():
    key = jax.random.PRNGKey(4)
    w1, key = _make_pack(key, 512, 256, 32)
    w2, key = _make_pack(key, 512, 128, 32)
    gw = fuse_twinquant_weights([w1, w2])
    bad = dataclasses.replace(gw, rp=gw.rp[:, :256])  # width != sum(seg_n)
    x = jax.random.normal(key, (4, 512)).astype(jnp.bfloat16)
    with pytest.raises(ContractError, match="segment widths"):
        fused_linear(x, bad)


def test_malformed_w4a16_pack_diagnosed():
    key = jax.random.PRNGKey(5)
    wq, ws = quantize_rows_ref(jax.random.normal(key, (512, 256)) * 0.1, 128, 4)
    wp = pack_rows_groupsplit(wq, 128)
    x = jax.random.normal(key, (4, 512)).astype(jnp.bfloat16)
    with pytest.raises(ContractError, match="scale rows"):
        w4a16_linear(x, wp, ws[:2], group=128)


def test_odd_but_consistent_pack_still_routes_ref():
    """Pack contracts check INTERNAL consistency only: an odd-but-coherent
    shape (N=100) remains a routing decision, exactly as before."""
    w, key = _make_pack(jax.random.PRNGKey(6), 512, 100, 32)
    x = jax.random.normal(key, (4, 512)).astype(jnp.bfloat16)
    reset_dispatch_counters()
    y = quant_linear(x, w)  # must not raise
    assert y.shape == (4, 100)
    assert dispatch_counters().get("dual/ref") == 1


# ---------------------------------------------------------------------------
# ref fallback reasons in the dispatch counters
# ---------------------------------------------------------------------------


def test_ref_fallback_reason_counters():
    key = jax.random.PRNGKey(7)
    reset_dispatch_counters()

    w_odd_n, key = _make_pack(key, 512, 100, 32)      # untileable N
    x_dec = jax.random.normal(key, (4, 512)).astype(jnp.bfloat16)
    quant_linear(x_dec, w_odd_n)                      # decode-regime M

    x_pre = jax.random.normal(key, (64, 512)).astype(jnp.bfloat16)
    quant_linear(x_pre, w_odd_n)                      # prefill-regime M

    w_ok, key = _make_pack(key, 512, 256, 32)
    quant_linear(x_dec, w_ok, impl="ref")             # intentional oracle

    c = dispatch_counters()
    assert c["dual/ref"] == 3
    # ...but the reasons are now distinguishable:
    assert c["dual/ref[decode_untileable]"] == 1
    assert c["dual/ref[prefill_untileable]"] == 1
    assert c["dual/ref[forced]"] == 1
    # kernel routes record no reason suffix
    quant_linear(x_dec, w_ok)
    assert dispatch_counters().get("dual/decode") == 1
    assert not any(k.startswith("dual/decode[") for k in dispatch_counters())


def test_ref_reason_keys_never_look_like_decode_launches():
    """compare.py's decode_launches sums keys ending '/decode' — reason keys
    must never match that suffix."""
    reset_dispatch_counters()
    w, key = _make_pack(jax.random.PRNGKey(8), 512, 100, 32)
    x = jax.random.normal(key, (4, 512)).astype(jnp.bfloat16)
    quant_linear(x, w)
    assert not any(k.endswith("/decode") for k in dispatch_counters())


# ---------------------------------------------------------------------------
# TuneCache robustness: corrupt artifacts degrade with a warning
# ---------------------------------------------------------------------------


def _expect_heuristic_with_warning(tmp_path, match):
    with pytest.warns(UserWarning, match=match):
        got = get_blocks("dual_prefill", 256, 512, 1024, 128, 64,
                         cache=TuneCache(tmp_path))
    assert got == heuristic_blocks("dual_prefill", 256, 512, 1024, 128, 64)


def test_corrupt_json_cache_warns_and_degrades(tmp_path):
    (tmp_path / "dual_prefill.json").write_text("{not json at all")
    _expect_heuristic_with_warning(tmp_path, "unreadable tune cache")


def test_wrong_schema_cache_warns_and_degrades(tmp_path):
    (tmp_path / "dual_prefill.json").write_text(
        json.dumps({"schema": 99, "entries": {"dual_prefill/x": {"blocks": [1, 2, 3]}}})
    )
    _expect_heuristic_with_warning(tmp_path, "schema")


def test_non_object_cache_warns_and_degrades(tmp_path):
    (tmp_path / "dual_prefill.json").write_text('["schema", 1]')
    _expect_heuristic_with_warning(tmp_path, "JSON object")


def test_garbage_blocks_entry_warns_and_degrades(tmp_path):
    key = cache_key("dual_prefill", 256, 512, 1024, 128, 64)
    (tmp_path / "dual_prefill.json").write_text(json.dumps({
        "schema": 1,
        "entries": {
            key: {"blocks": ["big", None, {}]},
            "dual_prefill/other": "not even a dict",
        },
    }))
    _expect_heuristic_with_warning(tmp_path, "malformed tune-cache entry")


def test_corrupt_cache_does_not_poison_routing(tmp_path, monkeypatch):
    """End-to-end: with a corrupt cache dir active, dispatch still routes and
    computes correctly (heuristic blocks, no crash)."""
    import warnings as _warnings

    import repro.kernels.autotune as autotune_mod

    (tmp_path / "dual_decode.json").write_text("}{")
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    monkeypatch.setattr(autotune_mod, "_default_cache", None)
    w, key = _make_pack(jax.random.PRNGKey(9), 512, 256, 32)
    x = jax.random.normal(key, (4, 512)).astype(jnp.bfloat16)
    reset_dispatch_counters()
    with _warnings.catch_warnings():
        _warnings.simplefilter("ignore", UserWarning)
        y = quant_linear(x, w)
    assert y.shape == (4, 256)
    assert dispatch_counters().get("dual/decode") == 1
    monkeypatch.setattr(autotune_mod, "_default_cache", None)
