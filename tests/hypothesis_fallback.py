"""Fixed-seed fallback for the `hypothesis` property-testing API.

When hypothesis is installed (dev extra, see requirements-dev.txt) the real
library is used and this module is never imported. Without it, property
tests degrade to a handful of deterministic fixed-seed cases drawn from the
same strategy ranges — weaker coverage, but the invariants still run in
minimal environments and CI stays green.

Usage (at the top of a test module):

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:  # degrade to fixed-seed cases
        from hypothesis_fallback import given, settings, strategies as st
"""

from __future__ import annotations


import numpy as np

_FALLBACK_EXAMPLES = 8  # per-test cap: fixed-seed sweep, not a fuzzer


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(0, len(options)))])


def settings(max_examples=_FALLBACK_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        # NOT functools.wraps: the wrapper must expose a zero-arg signature,
        # or pytest treats the property parameters as fixtures
        def wrapper():
            n = min(getattr(wrapper, "_fallback_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES)
            rng = np.random.default_rng(0)
            for _ in range(n):
                fn(*[s.draw(rng) for s in strats])

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
