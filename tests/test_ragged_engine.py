"""Unified ragged engine step tests (docs/serving.md, DESIGN.md §16).

The correctness bar for ragged mode is the same A/B oracle paging is held
to, one level up: serving a request through the RAGGED engine — its prompt
chunked to the token budget and its rows sharing launches with other
requests' decode tokens — must be token-for-token identical to serving it
alone through the bucketed engine. On top of that: the ragged attention
kernel agrees with its jnp reference on a mixed decode/chunk/pad batch, the
dispatch layer records the ``ragged`` routing kind, a whole serving
lifetime compiles exactly ONE ragged executable (the compile-budget
sanitizer's ≤ 2 bound, vs O(log S_max) prefill buckets), decode throughput
never dips while a long prompt streams in, and the engine is loud (warn /
raise) rather than silently wrong when ragged mode cannot be used.

Chunk-boundary numerics: multi-chunk prompts carry one f32 reassociation
per chunk boundary vs the oracle's single fused dot (see
kernels/ragged_attention.py), so the interleave workloads here are pinned
to seeds/lengths verified token-identical for BOTH families.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitizers import (
    assert_compile_budget,
    guarded_decode,
    no_recompiles,
    page_invariant_checks,
)
from repro.configs import ModelConfig
from repro.kernels import dispatch
from repro.kernels.ragged_attention import (
    ragged_attention_kernel,
    ragged_attention_ref,
)
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.models import dense, olmoe

jax.config.update("jax_platform_name", "cpu")

DCFG = ModelConfig(
    name="tiny-ragged", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, remat=False,
)
# capacity_factor=4.0: ragged pad rows route through experts and consume
# capacity, so the tiny config needs headroom to stay drop-free
MCFG = ModelConfig(
    name="tiny-ragged-moe", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, vocab=256, remat=False,
    n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=4.0,
)


@pytest.fixture(scope="module")
def dparams():
    return dense.init_params(DCFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mparams():
    return olmoe.init_params(MCFG, jax.random.PRNGKey(1))


def _prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 200, size=n).tolist() for n in lens]


def _solo(cfg, params, prompt, max_new=6):
    """Bucketed-engine solo serving: the token-equality oracle."""
    eng = ContinuousBatchingEngine(cfg, params, batch_slots=1, max_len=64)
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=max_new)
    eng.serve([req])
    assert req.done
    return req.out


def _ragged_interleaved(cfg, params, prompts, token_budget=16, max_new=6):
    """Submit-then-step each prompt so chunked prefills overlap live decodes;
    the whole loop runs under the page-invariant sanitizer and the decode
    drain under the transfer guard."""
    eng = ContinuousBatchingEngine(
        cfg, params, batch_slots=3, max_len=64, paged=True,
        ragged=True, token_budget=token_budget,
    )
    reqs = [Request(jnp.asarray(p, jnp.int32), max_new=max_new) for p in prompts]
    with page_invariant_checks(eng):
        for r in reqs:
            eng.submit(r)
            eng.step()
        with guarded_decode():
            eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, reqs


def _mixed_batch(seed=3):
    """A ragged batch with every row species: one decode row, two prompt
    chunks mid-stream (one with cache behind it, one starting cold), pads."""
    rng = np.random.default_rng(seed)
    B, maxp, page, T, KV, H, hd = 3, 4, 8, 16, 2, 4, 16
    P = B * maxp

    def f(*s):
        return jnp.asarray(rng.standard_normal(s), jnp.bfloat16)

    q, kt, vt = f(T, H, hd), f(T, KV, hd), f(T, KV, hd)
    kp, vp = f(P, page, KV, hd), f(P, page, KV, hd)
    ctx = np.array([13, 5, 0], np.int32)
    perm = rng.permutation(P)
    bt = np.full((B, maxp), -1, np.int32)
    for b in range(B):
        n_pg = -(-int(ctx[b]) // page) + 1  # committed pages + one being written
        bt[b, :n_pg] = perm[b * maxp : b * maxp + n_pg]
    slot = np.full(T, B, np.int32)
    pos = np.zeros(T, np.int32)
    slot[0], pos[0] = 0, 13                      # decode row
    slot[1:7], pos[1:7] = 1, np.arange(5, 11)    # chunk continuing past cache
    slot[7:14], pos[7:14] = 2, np.arange(0, 7)   # first chunk of a cold prompt
    args = (q, kp, vp, kt, vt, jnp.asarray(bt), jnp.asarray(slot),
            jnp.asarray(pos), jnp.asarray(ctx))
    return args, slot < B


# ---------------------------------------------------------------------------
# kernel vs reference, dispatch routing
# ---------------------------------------------------------------------------


def test_ragged_kernel_matches_ref_interpret():
    """Pallas kernel (interpret mode) vs jnp oracle on a mixed batch: pad
    rows are excluded (their output is garbage by contract)."""
    args, real = _mixed_batch()
    ref = np.asarray(ragged_attention_ref(*args), np.float32)
    ker = np.asarray(ragged_attention_kernel(*args, interpret=True), np.float32)
    # kernel accumulates fused-f32 while decode-like ref rows round split-bf16,
    # so agreement is to bf16 tolerance, not bitwise
    np.testing.assert_allclose(ker[real], ref[real], atol=0.03, rtol=0.05)


def test_dispatch_records_ragged_kind():
    """The routed entry point classifies under kind ``ragged`` and the
    counters distinguish kernel routes from forced-ref routes."""
    args, _ = _mixed_batch()
    dispatch.reset_dispatch_counters()
    dispatch.ragged_attention(*args)
    dispatch.ragged_attention(*args, impl="ref")
    c = dispatch.dispatch_counters()
    assert c.get("ragged/kernel") == 1, c
    assert c.get("ragged/ref") == 1 and c.get("ragged/ref[forced]") == 1, c


# ---------------------------------------------------------------------------
# chunk-budget edge cases (prompt vs token_budget boundary)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_prompt", [16, 17])
def test_prompt_at_and_over_budget(dparams, n_prompt):
    """A prompt exactly AT the budget prefills in one launch; one token OVER
    spills a 1-token second chunk. Both must match the bucketed oracle, and
    both compile the same single ragged executable."""
    (prompt,) = _prompts([n_prompt])
    eng = ContinuousBatchingEngine(
        DCFG, dparams, batch_slots=3, max_len=64, paged=True,
        ragged=True, token_budget=16,
    )
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=6)
    eng.serve([req])
    assert req.out == _solo(DCFG, dparams, prompt)
    cs = eng.compile_stats()
    assert cs["ragged_traces"] == 1 and cs["prefill_traces"] == 0, cs


# ---------------------------------------------------------------------------
# the acceptance bar: interleaved token equality vs the bucketed oracle
# ---------------------------------------------------------------------------


def test_ragged_interleaved_token_equality_dense(dparams):
    prompts = _prompts((5, 23, 17, 9))
    oracles = [_solo(DCFG, dparams, p) for p in prompts]
    eng, reqs = _ragged_interleaved(DCFG, dparams, prompts)
    for k, (r, o) in enumerate(zip(reqs, oracles)):
        assert r.out == o, (k, r.out, o)
    cs = assert_compile_budget(eng)
    assert cs["ragged_traces"] == 1 and cs["decode_traces"] == 0, cs


def test_ragged_interleaved_token_equality_moe(mparams):
    """Same bar for the routed-expert family: chunk rows and pad rows flow
    through the capacity-bounded MoE FFN without perturbing token outputs."""
    prompts = _prompts((5, 23, 17, 9))
    oracles = [_solo(MCFG, mparams, p) for p in prompts]
    eng, reqs = _ragged_interleaved(MCFG, mparams, prompts)
    for k, (r, o) in enumerate(zip(reqs, oracles)):
        assert r.out == o, (k, r.out, o)
    assert eng.compile_stats()["ragged_traces"] == 1


# ---------------------------------------------------------------------------
# decode latency: admission must not displace decode tokens
# ---------------------------------------------------------------------------


def test_decode_tokens_never_drop_during_admission(dparams):
    """Decode rows are scheduled FIRST, prompt chunks fill what remains: a
    long prompt streaming in over several steps must never cost a live
    decoder its per-step token."""
    eng = ContinuousBatchingEngine(
        DCFG, dparams, batch_slots=3, max_len=64, paged=True,
        ragged=True, token_budget=16,
    )
    steady = [Request(jnp.asarray([7 + k, 11, 13], jnp.int32), max_new=30)
              for k in range(2)]
    for r in steady:
        eng.submit(r)
    eng.step()  # both 3-token prompts prefill inside one budget
    assert all(r._last_logits is not None for r in steady)
    (long_prompt,) = _prompts([40], seed=2)
    burst = Request(jnp.asarray(long_prompt, jnp.int32), max_new=4)
    eng.submit(burst)
    deltas = []
    while burst._last_logits is None:  # burst still prefilling
        before = eng.stats["decode_tokens"]
        eng.step()
        deltas.append(eng.stats["decode_tokens"] - before)
    # 40 prompt tokens through a 16-budget with 2 decode rows reserved:
    # at least 3 admission steps, each still decoding BOTH steady slots
    assert len(deltas) >= 3, deltas
    assert all(d == 2 for d in deltas), deltas


# ---------------------------------------------------------------------------
# compile budget: one executable for the whole lifetime
# ---------------------------------------------------------------------------


def test_ragged_single_trace_no_recompiles(dparams):
    """After the first step's warmup trace, admissions / chunk interleaves /
    evictions all reuse the ONE token-budget-shaped executable — the
    no-recompile sanitizer covers the rest of the lifetime."""
    prompts = _prompts((5, 23, 17, 9))
    eng = ContinuousBatchingEngine(
        DCFG, dparams, batch_slots=3, max_len=64, paged=True,
        ragged=True, token_budget=16,
    )
    reqs = [Request(jnp.asarray(p, jnp.int32), max_new=6) for p in prompts]
    for r in reqs[:2]:
        eng.submit(r)
    eng.step()  # the single warmup trace
    with no_recompiles(eng):
        for r in reqs[2:]:
            eng.submit(r)
        eng.run_until_done()
    cs = assert_compile_budget(eng)
    assert cs["ragged_traces"] == 1, cs
    assert cs["prefill_traces"] == 0 and cs["decode_traces"] == 0, cs


# ---------------------------------------------------------------------------
# loud failure modes
# ---------------------------------------------------------------------------


def test_ragged_without_paged_falls_back_with_warning(dparams):
    with pytest.warns(UserWarning, match="ragged"):
        eng = ContinuousBatchingEngine(
            DCFG, dparams, batch_slots=2, max_len=64, ragged=True
        )
    assert not eng.ragged
    # the fallback engine still serves correctly through the bucketed path
    (prompt,) = _prompts([7])
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=4)
    eng.serve([req])
    assert req.out == _solo(DCFG, dparams, prompt, max_new=4)


def test_ragged_token_budget_validation(dparams):
    """A budget smaller than the slot count cannot even fit one decode row
    per slot: rejected at construction, not wedged at runtime."""
    with pytest.raises(ValueError, match="token_budget"):
        ContinuousBatchingEngine(
            DCFG, dparams, batch_slots=4, max_len=64, paged=True,
            ragged=True, token_budget=2,
        )
