"""Model-level quantization integration: packed serving path, simulation
path, sharding-spec coverage of quantized pytrees, MoE quantized experts."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ModelConfig, QuantSpec, get_config
from repro.core.twinquant import quantize_params, simulate_quantize_params
from repro.models import dense
from repro.models.registry import get_model

CFG = ModelConfig(
    name="qtest", family="dense", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, head_dim=64, d_ff=512, vocab=260, remat=False,
)


@pytest.fixture(scope="module")
def model_and_batch():
    params = dense.init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, CFG.vocab)
    return params, toks


def test_packed_w4a4_serving(model_and_batch):
    params, toks = model_and_batch
    qp = quantize_params(params, CFG, QuantSpec(mode="w4a4", rank=32))
    # eligible linears got packed
    flat = {"/".join(str(getattr(k, "key", k)) for k in p): v
            for p, v in jax.tree_util.tree_leaves_with_path(qp)}
    assert any(k.endswith("rp") for k in flat)
    assert not any("head" in k and k.endswith("rp") for k in flat)
    logits_fp = dense.forward(params, CFG, toks).astype(jnp.float32)
    logits_q = dense.forward(qp, CFG, toks).astype(jnp.float32)
    assert jnp.all(jnp.isfinite(logits_q))
    # untrained random weights are 4-bit's worst case (no outlier structure,
    # near-uniform logits): require strong correlation, not argmax equality
    corr = float(jnp.corrcoef(logits_fp.ravel(), logits_q.ravel())[0, 1])
    assert corr > 0.7, corr


def test_packed_w4a16_serving(model_and_batch):
    params, toks = model_and_batch
    qp = quantize_params(params, CFG, QuantSpec(mode="w4a16"))
    logits_q = dense.forward(qp, CFG, toks).astype(jnp.float32)
    logits_fp = dense.forward(params, CFG, toks).astype(jnp.float32)
    assert jnp.all(jnp.isfinite(logits_q))
    rel = float(jnp.linalg.norm(logits_q - logits_fp) / jnp.linalg.norm(logits_fp))
    # random iid weights are 4-bit's worst case; layer exactness is covered
    # by test_kernels — this is a sanity bound on 2-layer error amplification
    assert rel < 0.6, rel


def test_quantized_decode(model_and_batch):
    params, toks = model_and_batch
    qp = quantize_params(params, CFG, QuantSpec(mode="w4a4", rank=32))
    state = dense.init_decode_state(CFG, 2, 48)
    logits, state = dense.prefill(qp, CFG, toks, state)
    logits, state = dense.decode_step(qp, CFG, state, toks[:, :1])
    assert logits.shape == (2, 1, CFG.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_sim_variants_ordering(model_and_batch):
    """W4A8 beats W4A4; on OUTLIER-structured weights (the paper's setting —
    random flat-spectrum weights are the case where decomposition does NOT
    help, consistent with Observation 1), lowrank beats naive."""
    params, toks = model_and_batch
    # inject heavy input-channel outliers into every block linear

    def spike(tree):
        if isinstance(tree, dict):
            if "w" in tree and tree["w"].ndim == 3 and tree["w"].shape[1] >= 256:
                w = tree["w"]
                rows = jnp.arange(0, w.shape[1], 37)
                return {**tree, "w": w.at[:, rows, :].mul(10.0)}
            return {k: spike(v) for k, v in tree.items()}
        return tree

    sp = spike(params)
    ref = dense.forward(sp, CFG, toks).astype(jnp.float32)

    def fid(method, mode):
        qp = simulate_quantize_params(sp, CFG, QuantSpec(mode=mode, rank=32), method)
        lg = dense.forward(qp, CFG, toks).astype(jnp.float32)
        return float(jnp.linalg.norm(lg - ref))

    e_naive = fid("naive", "w4a4")
    e_low = fid("lowrank", "w4a4")
    e_low8 = fid("lowrank", "w4a8")
    assert e_low < e_naive, (e_low, e_naive)
    assert e_low8 < e_low, (e_low8, e_low)


def test_quantize_params_eval_shape_pure():
    """The dry-run contract: quantize_params works under jax.eval_shape."""
    params_sds = jax.eval_shape(lambda k: dense.init_params(CFG, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
    q_sds = jax.eval_shape(lambda p: quantize_params(p, CFG, QuantSpec(mode="w4a4", rank=32)),
                           params_sds)
    leaves = jax.tree.leaves(q_sds)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    # packed int4 buffers: rp has K/2 rows
    assert q_sds["layers"]["mlp"]["down"]["rp"].shape[-2] == CFG.d_ff // 2


def test_quantized_moe_local_path():
    cfg = get_config("olmoe-1b-7b", reduced=True).replace(
        d_model=256, d_ff_expert=256, n_experts=4, top_k=2, head_dim=64,
        n_heads=4, n_kv_heads=4, remat=False,
    )
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    qp = quantize_params(params, cfg, QuantSpec(mode="w4a4", rank=16))
    # expert packs are stacked over E
    assert qp["layers"]["moe"]["gate"]["rp"].shape[-3] == cfg.n_experts
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, cfg.vocab)
    logits, aux = model.forward(qp, cfg, toks)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_sharding_specs_cover_quantized_tree():
    """Every quantized leaf gets a valid PartitionSpec (dry-run contract)."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import param_specs
    from repro.models.context import MeshContext

    params_sds = jax.eval_shape(lambda k: dense.init_params(CFG, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
    q_sds = jax.eval_shape(lambda p: quantize_params(p, CFG, QuantSpec(mode="w4a4", rank=32)),
                           params_sds)
    ctx = MeshContext(mesh=None, dp_axes=("data",), tp_axis="model",
                      fsdp_axes=("data",))
    specs = param_specs(CFG, q_sds, ctx)
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert isinstance(s, P)
