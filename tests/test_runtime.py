"""Distributed-runtime substrate tests: checkpoint roundtrip + retention +
elastic restore, fault-tolerant train loop (injected failures), straggler
monitor, data determinism/resume, optimizer, gradient compression."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import TokenDataset, calibration_batch, load_corpus
from repro.launch.train import StragglerMonitor, TrainLoop, init_train_state, make_train_step
from repro.optim import AdamW
from repro.optim.grad_compression import compress_grads_int8, decompress_grads_int8


def _tree_allclose(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), rtol=1e-6
        )


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _state(key):
    return {
        "params": {"w": jax.random.normal(key, (16, 8)).astype(jnp.bfloat16),
                   "b": jnp.arange(8.0)},
        "opt": {"mu": jnp.ones((16, 8)), "count": jnp.asarray(3, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    st = _state(jax.random.PRNGKey(0))
    mgr.save(100, st)
    step, restored = mgr.restore_latest(like=st)
    assert step == 100
    _tree_allclose(st, restored)
    assert restored["params"]["w"].dtype == jnp.bfloat16


def test_checkpoint_retention_and_atomicity(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2, async_save=False)
    st = _state(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4):
        mgr.save(s, st)
    assert mgr.list_steps() == [3, 4]
    assert not list(tmp_path.glob("*.tmp"))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    st = _state(jax.random.PRNGKey(2))
    mgr.save(7, st)
    mgr.wait()
    assert mgr.list_steps() == [7]


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto explicit shardings (elastic restart path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(tmp_path, async_save=False)
    st = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, st)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P(None, None))}
    step, restored = mgr.restore_latest(like=st, shardings=shardings)
    _tree_allclose(st, restored)


# ---------------------------------------------------------------------------
# fault-tolerant train loop
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("qwen2-1.5b", reduced=True).replace(remat=False)
    opt = AdamW(lr=1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt))
    params, opt_state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    tokens = np.random.default_rng(0).integers(0, cfg.vocab, 40_000).astype(np.uint16)
    ds = TokenDataset(tokens, batch=2, seq=32)
    return cfg, step_fn, params, opt_state, ds


@pytest.mark.slow
def test_train_loop_runs_and_checkpoints(tmp_path, tiny_setup):
    cfg, step_fn, params, opt_state, ds = tiny_setup
    mgr = CheckpointManager(tmp_path / "a", async_save=False)
    loop = TrainLoop(cfg, step_fn, mgr, lambda s: ds.iterate(s), ckpt_every=5)
    p, o, losses, end = loop.run(params, opt_state, 0, 12)
    assert end == 12
    assert len(losses) == 12
    assert all(np.isfinite(losses))
    assert mgr.list_steps()[-1] == 12


@pytest.mark.slow
def test_train_loop_recovers_from_failure(tmp_path, tiny_setup):
    cfg, step_fn, params, opt_state, ds = tiny_setup
    mgr = CheckpointManager(tmp_path / "b", async_save=False)
    fails = {"armed": True}

    def injector(step):
        if step == 8 and fails["armed"]:
            fails["armed"] = False
            raise RuntimeError("simulated node failure")

    loop = TrainLoop(cfg, step_fn, mgr, lambda s: ds.iterate(s), ckpt_every=5)
    p, o, losses, end = loop.run(params, opt_state, 0, 12, fail_injector=injector)
    assert end == 12
    assert loop.restarts == 1
    # restarted from step 5's checkpoint: steps 5..7 re-run
    assert len(losses) == 12 + 3


def test_straggler_monitor():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0)
    for _ in range(5):
        assert not mon.observe(0, 0.10)
    assert mon.observe(5, 0.50)  # 5x slower than EWMA -> flagged
    assert mon.flagged and mon.flagged[0][0] == 5


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_resume():
    tokens = np.arange(10_000).astype(np.uint16) % 251
    ds = TokenDataset(tokens, batch=4, seq=16, seed=3)
    direct = ds.batch_at(7)
    it = ds.iterate(7)
    np.testing.assert_array_equal(next(it)["tokens"], direct["tokens"])
    # two iterators at the same step agree; consecutive steps differ
    assert not np.array_equal(ds.batch_at(7)["tokens"], ds.batch_at(8)["tokens"])


def test_data_host_sharding():
    tokens = (np.arange(50_000) % 250).astype(np.uint16)
    full = TokenDataset(tokens, batch=4, seq=8, seed=1)
    h0 = TokenDataset(tokens, batch=4, seq=8, seed=1, host_id=0, n_hosts=2)
    h1 = TokenDataset(tokens, batch=4, seq=8, seed=1, host_id=1, n_hosts=2)
    f = full.batch_at(5)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0.batch_at(5)["tokens"],
                                                  h1.batch_at(5)["tokens"]]), f)


def test_load_corpus_and_calibration():
    tokens = load_corpus()
    assert len(tokens) > 100_000
    assert int(tokens.max()) <= 258
    calib = calibration_batch(tokens, n_samples=4, seq=128)
    assert calib.shape == (4, 128)


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------


def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.05, weight_decay=0.0)
    params = {"w": jnp.full((4,), 5.0)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.2


def test_grad_compression_error_feedback():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (256,))}
    q, s, ef = compress_grads_int8(g)
    deq = decompress_grads_int8(q, s)
    rel = float(jnp.linalg.norm(deq["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02  # int8 per-tensor
    # error feedback: residual + dequantized == original
    np.testing.assert_allclose(
        np.asarray(deq["w"] + ef["w"]), np.asarray(g["w"]), rtol=1e-5, atol=1e-6
    )
