"""Integration tests for the three-stage TwinQuant calibration.

These check the *paper's ablation ordering* (Table 3) at layer level:
naive 4-bit > +LowRank > +Hadamard > TwinQuant in reconstruction error.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibration import CalibConfig, calibrate_layer, layer_quant_configs
from repro.core.decomposition import decompose, search_alpha, svd_decompose
from repro.core.errors import total_delta, zeta_gain
from repro.core.quantization import QuantConfig
from repro.core.transforms import hadamard_matrix, orthogonality_error


M, N, RANK, SAMPLES = 128, 96, 16, 256


@pytest.fixture(scope="module")
def layer():
    """A synthetic heavy-tailed layer: a few outlier channels (LLM-like)."""
    key = jax.random.PRNGKey(42)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w = jax.random.normal(k1, (M, N)) * 0.05
    # outlier input channels (rows of W / columns of X)
    idx = jax.random.choice(k2, M, (6,), replace=False)
    w = w.at[idx].mul(12.0)
    x = jax.random.normal(k3, (SAMPLES, M))
    x = x.at[:, idx].mul(8.0)
    # heavy tail on activations
    x = x * (1 + jnp.abs(jax.random.t(k4, df=3.0, shape=(SAMPLES, M))))
    return x, w


def _err(x, w, U, V, R, cfg: CalibConfig):
    aq, uq, vq, rq = layer_quant_configs(x.shape[1], U.shape[1], cfg)
    return float(total_delta(x, U, V, R, aq, uq, vq, rq))


def test_calibration_improves_over_svd_and_hadamard(layer):
    x, w = layer
    cfg = CalibConfig(
        rank=RANK, steps_global=80, steps_invert=80, steps_joint=40,
        lr=5e-3,
    )
    res = calibrate_layer(x, w, cfg)

    # baseline: plain smoothed SVD, no transforms
    x_hat = x / res.decomp.lam[None, :]
    U, V, R = res.decomp.U, res.decomp.V, res.decomp.R
    err_svd = _err(x_hat, w, U, V, R, cfg)

    # +Hadamard fixed rotation baseline
    H = hadamard_matrix(M)
    err_had = _err(x_hat @ H, w, H.T @ U, V, H.T @ R, cfg)

    # TwinQuant learned transforms
    Q, G, Gi = res.Q, res.G, res.G_inv
    err_twin = _err(x_hat @ Q, w, Q.T @ U @ G, Gi @ V, Q.T @ R, cfg)

    assert err_twin < err_svd, (err_twin, err_svd)
    assert err_twin < err_had, (err_twin, err_had)
    # the optimizer must have actually reduced the objective beyond its
    # Hadamard starting point (paper Table 3: TwinQuant > +Hadamard)
    assert res.final_loss < res.init_loss * 0.97


def test_calibrated_q_is_orthogonal(layer):
    x, w = layer
    cfg = CalibConfig(rank=RANK, steps_global=30, steps_invert=10, steps_joint=10, lr=2e-3)
    res = calibrate_layer(x, w, cfg)
    assert float(orthogonality_error(res.Q)) < 1e-3
    # G invertibility: G @ G_inv == I
    np.testing.assert_allclose(
        np.asarray(res.G @ res.G_inv), np.eye(RANK), atol=1e-3
    )


def test_fold_offline_equivalence(layer):
    """Algebraic identity: the transformed decomposition reproduces X W_hat
    exactly in full precision (fold-offline correctness)."""
    x, w = layer
    cfg = CalibConfig(rank=RANK, steps_global=8, steps_invert=8, steps_joint=4, lr=2e-3)
    res = calibrate_layer(x, w, cfg)
    x_hat = x / res.decomp.lam[None, :]
    U, V, R = res.decomp.U, res.decomp.V, res.decomp.R
    y_ref = x_hat @ (U @ V + R)
    Q, G, Gi = res.Q, res.G, res.G_inv
    y_tr = (x_hat @ Q) @ ((Q.T @ U @ G) @ (Gi @ V) + (Q.T @ R))
    rel = float(jnp.linalg.norm(y_tr - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 2e-3, rel


def test_decomposition_reconstructs_exactly():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 48))
    d = decompose(w, rank=8)
    np.testing.assert_allclose(
        np.asarray(d.reconstruct()), np.asarray(w * d.lam[:, None]), atol=1e-4
    )


def test_svd_rank_reduces_residual_energy():
    """Observation 2 direction: higher rank -> smaller residual energy."""
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (256, 128))
    energies = []
    for r in (8, 32, 64):
        _, _, R = svd_decompose(w, r)
        energies.append(float(jnp.sum(R**2)))
    assert energies[0] > energies[1] > energies[2]


def test_alpha_search_returns_valid(layer):
    x, w = layer
    wq = QuantConfig(bits=4, group_size=64, axis=0)
    aq = QuantConfig(bits=4, group_size=64, axis=-1)
    alpha, lam = search_alpha(x, w, RANK, wq, aq, alphas=(0.0, 0.5, 1.0))
    assert alpha in (0.0, 0.5, 1.0)
    assert lam.shape == (M,)
    assert bool(jnp.all(lam > 0))


def test_zeta_gain_hadamard_on_outliers():
    """Flattening an outlier-heavy activation with a rotation gives zeta > 1
    (Thm 4.1 direction)."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (512, 128))
    x = x.at[:, 3].mul(50.0)
    z = float(zeta_gain(x, hadamard_matrix(128)))
    assert z > 2.0
