import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade property tests to fixed-seed cases
    from hypothesis_fallback import given, settings, strategies as st

from repro.core.quantization import (
    QuantConfig,
    QTensor,
    dequantize,
    fake_quant,
    pack_int4,
    quantize,
    unpack_int4,
)

jax.config.update("jax_enable_x64", False)


def test_quantize_roundtrip_error_bound():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 256))
    cfg = QuantConfig(bits=4, group_size=128, axis=-1)
    qt = quantize(x, cfg)
    xr = dequantize(qt)
    # max error per element <= scale/2 within the group
    s = qt.scale[..., None]
    err = jnp.abs((xr - x).reshape(64, 2, 128))
    assert bool(jnp.all(err <= s / 2 + 1e-6))


def test_quantize_values_in_range():
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 128)) * 10
    for bits in (4, 8):
        qt = quantize(x, QuantConfig(bits=bits, group_size=64, axis=-1))
        qmax = 2 ** (bits - 1) - 1
        assert int(jnp.max(qt.q)) <= qmax
        assert int(jnp.min(qt.q)) >= -qmax


def test_quantize_axis0_groups():
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 48))
    cfg = QuantConfig(bits=4, group_size=128, axis=0)
    qt = quantize(x, cfg)
    assert qt.scale.shape == (2, 48)
    xr = dequantize(qt)
    assert xr.shape == x.shape
    # relative frobenius error should be small-ish for 4-bit
    rel = float(jnp.linalg.norm(xr - x) / jnp.linalg.norm(x))
    assert rel < 0.15


def test_zero_group_is_safe():
    x = jnp.zeros((4, 128))
    qt = quantize(x, QuantConfig(bits=4, group_size=128))
    assert bool(jnp.all(qt.q == 0))
    assert bool(jnp.all(jnp.isfinite(dequantize(qt))))


def test_int8_much_better_than_int4():
    x = jax.random.normal(jax.random.PRNGKey(3), (128, 512))
    e4 = jnp.linalg.norm(x - dequantize(quantize(x, QuantConfig(bits=4))))
    e8 = jnp.linalg.norm(x - dequantize(quantize(x, QuantConfig(bits=8))))
    assert float(e8) < float(e4) / 8


def test_fake_quant_matches_quant_dequant():
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 256))
    cfg = QuantConfig(bits=4, group_size=128)
    np.testing.assert_allclose(
        np.asarray(fake_quant(x, cfg)),
        np.asarray(dequantize(quantize(x, cfg), dtype=x.dtype)),
        rtol=0, atol=0,
    )


def test_fake_quant_ste_gradient():
    cfg = QuantConfig(bits=4, group_size=8)
    x = jnp.linspace(-1.0, 1.0, 8)[None, :]
    g = jax.grad(lambda x: jnp.sum(fake_quant(x, cfg)))(x)
    # interior values get identity gradient
    assert bool(jnp.all(g >= 0))
    assert float(jnp.max(g)) == 1.0


def test_pack_unpack_int4_roundtrip():
    q = jnp.arange(-7, 8, dtype=jnp.int8)
    q = jnp.tile(q, 16)[: 16 * 14].reshape(16, 14)
    p = pack_int4(q)
    assert p.shape == (16, 7)
    u = unpack_int4(p)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.sampled_from([4, 8]),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_dequant_error_half_scale(rows, bits, seed):
    """Property: |x - dq(q(x))| <= scale/2 element-wise, any shape/bits."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, 64)) * (seed % 7 + 1)
    cfg = QuantConfig(bits=bits, group_size=32, axis=-1)
    qt = quantize(x, cfg)
    xr = dequantize(qt)
    s = jnp.repeat(qt.scale, 32, axis=-1)
    assert bool(jnp.all(jnp.abs(xr - x) <= s / 2 + 1e-6))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_property_pack_unpack_identity(seed):
    key = jax.random.PRNGKey(seed)
    q = jax.random.randint(key, (8, 32), -7, 8, dtype=jnp.int8)
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(q))), np.asarray(q))


def test_qtensor_is_pytree():
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 128))
    qt = quantize(x, QuantConfig(bits=4, group_size=128))
    leaves = jax.tree.leaves(qt)
    assert len(leaves) == 2
    qt2 = jax.tree.map(lambda a: a, qt)
    assert isinstance(qt2, QTensor)
