"""Horizontally fused projection groups: pack fusion round-trips,
fused-vs-unfused bit-exactness (both kernel schedules and the oracle, across
segment-boundary shapes), fused routing/counters, and model-level adoption
(linear_group + fuse_params forward parity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ModelConfig, QuantSpec
from repro.core.twinquant import fuse_params, quantize_params
from repro.kernels.dispatch import (
    DECODE_M_MAX,
    QuantLinear,
    QuantLinearGroup,
    classify_dual_group,
    dispatch_counters,
    fused_linear,
    quant_linear,
    reset_dispatch_counters,
    set_fusion,
)
from repro.kernels.ref import (
    dual_gemm_group_ref,
    dual_gemm_ref,
    fuse_twinquant_weights,
    pack_twinquant_weights,
)
from repro.kernels.twinquant_dual_gemm import dual_gemm
from repro.kernels.twinquant_dual_gemv import dual_gemv


def _make_pack(seed, K, N, r, a_bits=4, group=128):
    k1, k2, k3, _ = jax.random.split(jax.random.PRNGKey(seed), 4)
    U = jax.random.normal(k1, (K, r)) * 0.1
    V = jax.random.normal(k2, (r, N)) * 0.1
    R = jax.random.normal(k3, (K, N)) * 0.05
    return pack_twinquant_weights(U, V, R, a_bits=a_bits, group=group)


# uneven N segments with per-segment ranks (and so per-segment rgroups):
# the segment-boundary geometry the fused kernels must keep bit-exact
K = 512
SEGS = ((256, 64), (128, 32), (128, 32))


def _make_group(a_bits=4):
    ws = [_make_pack(10 + j, K, n, r, a_bits) for j, (n, r) in enumerate(SEGS)]
    return ws, fuse_twinquant_weights(ws)


def _assert_bf16_close(y_k, y_ref, max_ulp=2):
    a = np.asarray(jnp.asarray(y_k, jnp.bfloat16)).view(np.uint16).astype(np.int32)
    b = np.asarray(jnp.asarray(y_ref, jnp.bfloat16)).view(np.uint16).astype(np.int32)
    ka = np.where(a & 0x8000, 0x7FFF - (a & 0x7FFF), 0x8000 + a)
    kb = np.where(b & 0x8000, 0x7FFF - (b & 0x7FFF), 0x8000 + b)
    ulp = np.abs(ka - kb)
    assert ulp.max() <= max_ulp, f"{(ulp > max_ulp).sum()} elements differ (max {ulp.max()})"


# ---------------------------------------------------------------------------
# pack fusion round-trip + fused oracle
# ---------------------------------------------------------------------------


def test_fuse_segment_roundtrip():
    ws, gw = _make_group()
    assert gw.seg_n == tuple(n for n, _ in SEGS)
    assert gw.seg_r == tuple(r for _, r in SEGS)
    assert gw.rgroups == tuple(min(128, r) for _, r in SEGS)
    assert gw.ndim_out == sum(n for n, _ in SEGS)
    assert gw.rank == sum(r for _, r in SEGS)
    for j, w in enumerate(ws):
        seg = gw.segment(j)
        for f in ("up", "us", "vp", "vs", "rp", "rs"):
            np.testing.assert_array_equal(
                np.asarray(getattr(seg, f)), np.asarray(getattr(w, f))
            )
        assert (seg.group, seg.rgroup, seg.a_bits) == (w.group, w.rgroup, w.a_bits)


@pytest.mark.parametrize("m", [1, 8, 48])
def test_group_oracle_bitexact_vs_per_segment_oracle(m):
    """The fused oracle shares Xq across segments but must reproduce each
    unfused segment oracle bit for bit (column-independent ops, same order)."""
    ws, gw = _make_group()
    x = (jax.random.normal(jax.random.PRNGKey(m), (m, K)) * 2).astype(jnp.bfloat16)
    y = dual_gemm_group_ref(x, gw)
    for j, w in enumerate(ws):
        np.testing.assert_array_equal(
            np.asarray(gw.split(y)[j], np.float32),
            np.asarray(dual_gemm_ref(x, w), np.float32),
        )


# ---------------------------------------------------------------------------
# fused kernels vs unfused, through the dispatcher (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", list(range(1, DECODE_M_MAX + 1)))
def test_fused_decode_kernel_bitexact(m):
    """Decode M=1..8: the fused gemv must equal BOTH the per-segment unfused
    kernel and the oracle exactly (the decode schedule matches the oracle's
    accumulation order)."""
    ws, gw = _make_group()
    x = (jax.random.normal(jax.random.PRNGKey(m), (m, K)) * 2).astype(jnp.bfloat16)
    assert classify_dual_group(m, K, 128, gw.seg_n, gw.seg_r, gw.rgroups).path == "decode"
    ys = fused_linear(x, gw, impl="kernel", interpret=True)
    for j, w in enumerate(ws):
        y_unfused = dual_gemv(x, w, block_n=128, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(ys[j], np.float32), np.asarray(y_unfused, np.float32)
        )
        np.testing.assert_array_equal(
            np.asarray(ys[j], np.float32),
            np.asarray(dual_gemm_ref(x, w), np.float32),
        )


@pytest.mark.parametrize("a_bits", [4, 8])
def test_fused_prefill_kernel_bitexact_vs_unfused_kernel(a_bits):
    """Prefill M=256: the fused gemm must equal the unfused kernel run per
    segment at the same blocks bit for bit, and stay within f32-reassociation
    ULPs of the oracle (the unfused kernel's own tolerance)."""
    ws, gw = _make_group(a_bits)
    m = 256
    x = (jax.random.normal(jax.random.PRNGKey(a_bits), (m, K)) * 2).astype(jnp.bfloat16)
    route = classify_dual_group(m, K, 128, gw.seg_n, gw.seg_r, gw.rgroups)
    assert route.path == "prefill"
    bm, bn, bk = route.blocks
    ys = fused_linear(x, gw, impl="kernel", interpret=True)
    for j, w in enumerate(ws):
        y_unfused = dual_gemm(x, w, block_m=bm, block_n=bn, block_k=bk, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(ys[j], np.float32), np.asarray(y_unfused, np.float32)
        )
        _assert_bf16_close(ys[j], dual_gemm_ref(x, w))


def test_fused_bias_and_batch_dims():
    ws, gw = _make_group()
    b0 = jnp.arange(gw.seg_n[0], dtype=jnp.float32) * 0.01
    x = (jax.random.normal(jax.random.PRNGKey(3), (2, 3, K))).astype(jnp.bfloat16)
    ys = fused_linear(x, gw, biases=[b0, None, None], impl="kernel", interpret=True)
    assert [y.shape for y in ys] == [(2, 3, n) for n in gw.seg_n]
    y_ref = dual_gemm_ref(x.reshape(6, K), ws[0]).reshape(2, 3, -1)
    y_ref = (y_ref.astype(jnp.float32) + b0).astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(ys[0], np.float32), np.asarray(y_ref, np.float32))


# ---------------------------------------------------------------------------
# routing + counters
# ---------------------------------------------------------------------------


def test_classify_dual_group_regimes():
    sn, sr, gr = (256, 128, 128), (64, 32, 32), (64, 32, 32)
    assert classify_dual_group(1, 512, 128, sn, sr, gr).path == "decode"
    assert classify_dual_group(8, 512, 128, sn, sr, gr).path == "decode"
    assert classify_dual_group(9, 512, 128, sn, sr, gr).path == "prefill"
    # block_n must tile EVERY segment: one odd segment -> ref
    assert classify_dual_group(4, 512, 128, (256, 100), (64, 32), (64, 32)).path == "ref"
    # K not a group multiple -> ref
    assert classify_dual_group(4, 300, 128, sn, sr, gr).path == "ref"
    # a segment rank not tileable by its rgroup -> ref
    assert classify_dual_group(4, 512, 128, sn, (64, 30, 32), (64, 4, 32)).path == "ref"
    blocks = classify_dual_group(4, 512, 128, sn, sr, gr).blocks
    assert blocks is not None and all(n % blocks[1] == 0 for n in sn)


def test_fused_ref_route_odd_segments_no_assert():
    """An untileable group must run the per-segment oracle, not assert."""
    ws = [_make_pack(31, K, 100, 32), _make_pack(32, K, 128, 32)]
    x = (jax.random.normal(jax.random.PRNGKey(5), (4, K)) * 2).astype(jnp.bfloat16)
    ys = fused_linear(x, ws, impl="kernel", interpret=True)  # impl hint ignored on ref
    for y, w in zip(ys, ws):
        np.testing.assert_array_equal(
            np.asarray(y, np.float32), np.asarray(dual_gemm_ref(x, w), np.float32)
        )


def test_fused_dispatch_counters():
    ws, gw = _make_group()
    reset_dispatch_counters()
    fused_linear(jnp.ones((4, K), jnp.bfloat16), gw)
    fused_linear(jnp.ones((4, K), jnp.bfloat16), gw)
    fused_linear(jnp.ones((64, K), jnp.bfloat16), gw)
    c = dispatch_counters()
    assert c["dual_fused/decode"] == 2
    assert c["dual_fused/prefill"] == 1
    reset_dispatch_counters()


def test_quantlineargroup_route_matches_execution():
    ws, gw = _make_group()
    layer = QuantLinearGroup(ws)
    assert layer.route_for((4, K)).path == "decode"
    assert layer.route_for((2, 3, K)).path == "decode"  # M = 6 flattened
    assert layer.route_for((2, 64, K)).path == "prefill"
    x = (jax.random.normal(jax.random.PRNGKey(7), (4, K)) * 2).astype(jnp.bfloat16)
    ys = layer(x)
    for j, w in enumerate(ws):
        np.testing.assert_array_equal(
            np.asarray(ys[j], np.float32), np.asarray(dual_gemm_ref(x, w), np.float32)
        )


def test_quantlinear_route_for_shares_flatten_m():
    """route_for must flatten leading dims exactly like quant_linear does
    (the execution path), including the empty-leading-dims case M=1."""
    w = _make_pack(40, 256, 128, 32)
    layer = QuantLinear(w)
    assert layer.route_for((256,)).path == "decode"  # M=1, not M=0
    for shape in ((256,), (4, 256), (2, 3, 256), (2, 64, 256)):
        x = jnp.ones(shape, jnp.bfloat16)
        reset_dispatch_counters()
        quant_linear(x, w)
        (executed,) = [k.split("/")[1] for k in dispatch_counters()]
        assert layer.route_for(shape).path == executed, shape
    reset_dispatch_counters()


# ---------------------------------------------------------------------------
# model-level adoption: linear_group + fuse_params
# ---------------------------------------------------------------------------

CFG = ModelConfig(
    name="fuse-t", family="dense", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, head_dim=64, d_ff=512, vocab=64, rope_theta=1e4, remat=False,
)


def _dense_qparams():
    from repro.models import dense

    params = dense.init_params(CFG, jax.random.PRNGKey(0))
    return params, quantize_params(params, CFG, QuantSpec(mode="w4a4", rank=32))


def test_fuse_params_merges_sibling_packs():
    _, qp = _dense_qparams()
    fqp = fuse_params(qp)
    attn = fqp["layers"]["attn"]
    assert "qkv" in attn and not any(k in attn for k in ("q", "k", "v"))
    assert "o" in attn  # o has its own input (attention output): never fused
    mlp = fqp["layers"]["mlp"]
    assert "gate_up" in mlp and "down" in mlp
    # stacked (per-layer) leaves: concat along the trailing N axis
    assert attn["qkv"]["rp"].shape == (CFG.n_layers, 128, 256 + 128 + 128)
    assert attn["qkv"]["vp0"].shape[0] == CFG.n_layers


def test_fuse_params_leaves_bf16_and_w4a16_alone():
    params, _ = _dense_qparams()
    fused = fuse_params(params)  # bf16 tree: structurally unchanged
    assert jax.tree_util.tree_structure(fused) == jax.tree_util.tree_structure(params)
    qp16 = quantize_params(params, CFG, QuantSpec(mode="w4a16"))
    f16 = fuse_params(qp16)
    assert "q" in f16["layers"]["attn"] and "qkv" not in f16["layers"]["attn"]


def test_dense_forward_parity_fused_vs_unfused():
    """Prefill + decode logits must be IDENTICAL across: unfused (fusion
    off), trace-time fusion, and pre-merged fuse_params packs — the fused
    route is the default and provably lossless on the ref path."""
    from repro.models import dense

    _, qp = _dense_qparams()
    fqp = fuse_params(qp)
    toks = jnp.arange(16, dtype=jnp.int32)[None, :].repeat(2, 0) % CFG.vocab
    state0 = dense.init_decode_state(CFG, 2, 32)
    step = jnp.array([[1], [2]], jnp.int32)

    def run(p, flag):
        prev = set_fusion(flag)
        try:
            lg, st = dense.prefill(p, CFG, toks, state0)
            dl, _ = dense.decode_step(p, CFG, st, step)
            return np.asarray(lg, np.float32), np.asarray(dl, np.float32)
        finally:
            set_fusion(prev)

    base = run(qp, False)
    reset_dispatch_counters()
    trace_fused = run(qp, True)
    c = dispatch_counters()
    assert c.get("dual_fused/decode", 0) > 0 and c.get("dual_fused/prefill", 0) > 0
    pre_merged = run(fqp, True)
    for a, b in zip(base, trace_fused):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(base, pre_merged):
        np.testing.assert_array_equal(a, b)
    reset_dispatch_counters()


def test_set_fusion_disables_group_launches():
    from repro.models import common as C

    _, qp = _dense_qparams()
    lp = jax.tree.map(lambda a: a[0], qp["layers"])  # one layer's packs
    x = jnp.ones((4, CFG.d_model), jnp.bfloat16)
    reset_dispatch_counters()
    prev = set_fusion(False)
    try:
        C.linear_group(lp["attn"], ("q", "k", "v"), "qkv", x)
    finally:
        set_fusion(prev)
    c = dispatch_counters()
    assert c.get("dual_fused/decode", 0) == 0 and c.get("dual/decode", 0) == 3
    reset_dispatch_counters()
    C.linear_group(lp["attn"], ("q", "k", "v"), "qkv", x)
    assert dispatch_counters().get("dual_fused/decode", 0) == 1
    reset_dispatch_counters()


def test_linear_group_falls_back_for_bf16_and_mixed():
    from repro.models import common as C

    params, qp = _dense_qparams()
    lp_bf16 = jax.tree.map(lambda a: a[0], params["layers"])
    x = jnp.ones((4, CFG.d_model), jnp.bfloat16)
    q, k, v = C.linear_group(lp_bf16["attn"], ("q", "k", "v"), "qkv", x)
    assert q.shape[-1] == CFG.n_heads * CFG.head_dim
    # mixed precision siblings (one bf16, two packed): per-sibling fallback
    lp_q = jax.tree.map(lambda a: a[0], qp["layers"])
    mixed = {"q": lp_bf16["attn"]["q"], "k": lp_q["attn"]["k"], "v": lp_q["attn"]["v"]}
    reset_dispatch_counters()
    q2, k2, v2 = C.linear_group(mixed, ("q", "k", "v"), "qkv", x)
    assert dispatch_counters().get("dual_fused/decode", 0) == 0
    np.testing.assert_array_equal(
        np.asarray(k2, np.float32), np.asarray(C.linear(mixed["k"], x), np.float32)
    )
    reset_dispatch_counters()


def test_set_fusion_false_forces_premerged_pack_per_segment():
    """The A/B toggle must be honest for BOTH layouts: a fuse_params-merged
    tree with fusion off executes one launch per segment, identical values."""
    from repro.models import common as C

    _, qp = _dense_qparams()
    lp = jax.tree.map(lambda a: a[0], fuse_params(qp)["layers"])
    x = (jax.random.normal(jax.random.PRNGKey(8), (4, CFG.d_model)) * 2).astype(jnp.bfloat16)
    fused = C.linear_group(lp["attn"], ("q", "k", "v"), "qkv", x)
    reset_dispatch_counters()
    prev = set_fusion(False)
    try:
        unfused = C.linear_group(lp["attn"], ("q", "k", "v"), "qkv", x)
    finally:
        set_fusion(prev)
    c = dispatch_counters()
    assert c.get("dual_fused/decode", 0) == 0 and c.get("dual/decode", 0) == 3
    for a, b in zip(fused, unfused):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    reset_dispatch_counters()


def test_engine_premerges_sibling_packs():
    """The serving engine pre-merges unfused packs at construction (so fused
    launches never pay per-step pack concatenation) and its decode traces
    route the fused kind."""
    from repro.launch.serve import ContinuousBatchingEngine, Request

    _, qp = _dense_qparams()
    eng = ContinuousBatchingEngine(CFG, qp, batch_slots=2, max_len=24)
    attn = eng.params["layers"]["attn"]
    assert "qkv" in attn and "q" not in attn
    eng.serve([Request(jnp.arange(6, dtype=jnp.int32), max_new=3)])
    routes = eng.routing()
    assert routes.get("dual_fused/decode", 0) > 0, routes


def test_mamba_hybrid_shared_attn_mlp_fuses():
    """fuse_params merges the hybrid stack's shared-attention MLP gate/up;
    the forward pass must consume the merged pack (no KeyError) with values
    identical to the unfused tree."""
    from repro.configs import get_config
    from repro.models.registry import get_model

    cfg = get_config("zamba2-1.2b", reduced=True)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(params, cfg, QuantSpec(mode="w4a4", rank=16))
    fqp = fuse_params(qp)
    toks = jnp.arange(8, dtype=jnp.int32)[None, :] % cfg.vocab
    y_unfused = np.asarray(model.forward(qp, cfg, toks), np.float32)
    y_fused = np.asarray(model.forward(fqp, cfg, toks), np.float32)
    np.testing.assert_array_equal(y_unfused, y_fused)


def test_fuse_params_excludes_encdec_cross_attention():
    """xattn q projects the decoder stream, k/v the encoder states: no shared
    activation, so fuse_params must leave xattn unfused (only dicts named
    'attn' merge q/k/v)."""
    xattn_like = {
        "layers": {
            "xattn": {
                "q": _pack_dict(1), "k": _pack_dict(2), "v": _pack_dict(3),
            },
            "attn": {
                "q": _pack_dict(4), "k": _pack_dict(5), "v": _pack_dict(6),
            },
        }
    }
    fused = fuse_params(xattn_like)
    assert set(fused["layers"]["xattn"]) == {"q", "k", "v"}
    assert set(fused["layers"]["attn"]) == {"qkv"}


def _pack_dict(seed, K=256, N=128, r=32):
    w = _make_pack(seed, K, N, r)
    return {
        "up": w.up, "us": w.us, "vp": w.vp, "vs": w.vs, "rp": w.rp, "rs": w.rs,
        "abits": jnp.zeros((w.a_bits,), jnp.int8),
    }
