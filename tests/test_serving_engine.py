"""Continuous-batching engine tests.

The load-bearing one is slot isolation: two requests admitted mid-flight of
each other must produce token-for-token what each produces served alone.
The seed ``Server`` shared ONE scalar cache position across every batch slot
(and prefilled token-by-token through the batched decode step), so admitting
a request while another was live corrupted both timelines — this test fails
against it by construction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ModelConfig, get_config
from repro.launch.serve import ContinuousBatchingEngine, Request, SamplingParams
from repro.models import common as C
from repro.models import dense

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(
    name="tiny-serve", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, remat=False,
)


@pytest.fixture(scope="module")
def params():
    return dense.init_params(CFG, jax.random.PRNGKey(0))


def _solo(params, prompt, max_new=8, cfg=CFG):
    eng = ContinuousBatchingEngine(cfg, params, batch_slots=1, max_len=64)
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=max_new)
    eng.serve([req])
    assert req.done
    return req.out


def test_slot_isolation_interleaved(params):
    """Interleaved admission == solo serving, token for token."""
    a = list(range(10, 22))
    b = list(range(100, 105))
    solo_a = _solo(params, a)
    solo_b = _solo(params, b)

    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64)
    ra = Request(jnp.asarray(a, jnp.int32), max_new=8)
    eng.submit(ra)
    for _ in range(3):  # A is mid-generation when B arrives
        eng.step()
    rb = Request(jnp.asarray(b, jnp.int32), max_new=8)
    eng.submit(rb)
    eng.run_until_done()

    assert ra.done and rb.done
    assert ra.out == solo_a
    assert rb.out == solo_b


def test_slot_reuse_after_eviction(params):
    """A freed slot admits a new request with zero contamination from the
    previous occupant's cache rows or position."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=1, max_len=64)
    r1 = Request(jnp.asarray([1, 2, 3], jnp.int32), max_new=4)
    r2 = Request(jnp.asarray([7, 8, 9, 10], jnp.int32), max_new=5)
    eng.serve([r1, r2])
    assert r1.done and r2.done
    assert r2.out == _solo(params, [7, 8, 9, 10], max_new=5)


def test_queue_longer_than_slots(params):
    """8 requests through 2 slots: continuous admission keeps every answer
    identical to solo serving, and the accounting sees the turnover."""
    prompts = [[i, i + 1, i + 2] for i in range(0, 80, 10)]
    reqs = [Request(jnp.asarray(p, jnp.int32), max_new=4) for p in prompts]
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64)
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    for p, r in zip(prompts, reqs):
        assert r.out == _solo(params, p, max_new=4)
    th = eng.throughput()
    assert th["requests_done"] == len(prompts)
    assert th["decode_tokens"] >= sum(len(r.out) for r in reqs) - len(reqs)
    assert 1.0 <= th["mean_batch_occupancy"] <= 2.0


def test_per_request_sampling(params):
    """Sampling params are per-request: same seed reproduces, greedy and
    temperature coexist in one batch."""
    prompt = jnp.asarray([5, 6, 7, 8], jnp.int32)

    def run(sampling):
        eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64)
        greedy = Request(prompt, max_new=6)
        sampled = Request(prompt, max_new=6, sampling=sampling)
        eng.serve([greedy, sampled])
        return greedy.out, sampled.out

    g1, s1 = run(SamplingParams(temperature=1.0, top_k=20, seed=42))
    g2, s2 = run(SamplingParams(temperature=1.0, top_k=20, seed=42))
    assert g1 == g2 == _solo(params, [5, 6, 7, 8], max_new=6)
    assert s1 == s2  # same seed -> same draw
    assert all(0 <= t < CFG.vocab for t in s1)


def test_attention_decode_ro_per_slot_mask():
    """Per-slot pos masking: a batched decode with pos=(3, 9) must equal the
    two batch-1 decodes at pos 3 and pos 9."""
    cfg = CFG
    key = jax.random.PRNGKey(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = C.attn_init(k1, cfg)
    b, s_max = 2, 16
    kc = (jax.random.normal(k2, (b, s_max, cfg.n_kv_heads, cfg.head_dim)) * 0.5).astype(C.DTYPE)
    vc = (jax.random.normal(k3, (b, s_max, cfg.n_kv_heads, cfg.head_dim)) * 0.5).astype(C.DTYPE)
    x = (jax.random.normal(k4, (b, 1, cfg.d_model)) * 0.5).astype(C.DTYPE)
    pos = jnp.asarray([3, 9], jnp.int32)

    out, kt, vt = C.attention_decode_ro(p, x, cfg, kc, vc, pos)
    for i in range(b):
        oi, kti, vti = C.attention_decode_ro(
            p, x[i : i + 1], cfg, kc[i : i + 1], vc[i : i + 1], pos[i : i + 1]
        )
        np.testing.assert_allclose(
            np.asarray(out[i], np.float32), np.asarray(oi[0], np.float32),
            rtol=1e-2, atol=1e-3,
        )
        np.testing.assert_allclose(
            np.asarray(kt[i], np.float32), np.asarray(kti[0], np.float32),
            rtol=1e-2, atol=1e-3,
        )


def test_per_slot_cache_scatter():
    """update_cache_slot writes each slot at its own offset and drops
    out-of-range positions instead of clamping into row S-1."""
    cache = jnp.zeros((3, 8, 2), jnp.float32)
    t = jnp.ones((3, 1, 2), jnp.float32) * jnp.asarray([1.0, 2.0, 3.0])[:, None, None]
    pos = jnp.asarray([0, 5, 99], jnp.int32)  # slot 2 overflows -> dropped
    out = C.update_cache_slot(cache, t, pos)
    assert float(out[0, 0, 0]) == 1.0
    assert float(out[1, 5, 0]) == 2.0
    assert float(jnp.abs(out[2]).sum()) == 0.0
    assert float(jnp.abs(out[0, 1:]).sum()) == 0.0


@pytest.mark.slow
def test_engine_recurrent_family():
    """The generic slot splice (batch-axis inference) must also serve a
    recurrent-state family — xLSTM decode state has no sequence axis at all."""
    cfg = get_config("xlstm-1.3b", reduced=True).replace(remat=False)
    from repro.models import xlstm

    params = xlstm.init_params(cfg, jax.random.PRNGKey(1))
    a, b = [3, 4, 5, 6], [9, 8, 7]
    solo_a = _solo(params, a, max_new=3, cfg=cfg)
    solo_b = _solo(params, b, max_new=3, cfg=cfg)
    eng = ContinuousBatchingEngine(cfg, params, batch_slots=2, max_len=64)
    ra = Request(jnp.asarray(a, jnp.int32), max_new=3)
    eng.submit(ra)
    eng.step()
    rb = Request(jnp.asarray(b, jnp.int32), max_new=3)
    eng.submit(rb)
    eng.run_until_done()
    assert ra.out == solo_a
    assert rb.out == solo_b


def test_decode_loop_sanitized(params):
    """The steady-state decode loop passes the hot-path sanitizers: no device
    transfers outside the marked sync-points, no recompiles after warmup, and
    the lifetime prefill trace count inside the bucket ratchet."""
    from repro.analysis.sanitizers import (
        SanitizerError,
        assert_compile_budget,
        guarded_decode,
        no_recompiles,
    )

    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64)
    ra = Request(jnp.asarray(list(range(10, 18)), jnp.int32), max_new=6)
    rb = Request(jnp.asarray([3, 4, 5], jnp.int32), max_new=6)
    eng.submit(ra)
    eng.submit(rb)
    eng.step()  # warmup: traces the decode executable
    with guarded_decode(), no_recompiles(eng):
        eng.run_until_done()
    assert ra.done and rb.done
    assert_compile_budget(eng)

    # the recompile sanitizer actually bites: a NEW bucket inside the guarded
    # region (a 33-token prompt forces the 64 bucket) must raise
    eng2 = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64)
    eng2.submit(Request(jnp.asarray([1, 2, 3], jnp.int32), max_new=2))
    eng2.step()
    with pytest.raises(SanitizerError, match="prefill_traces"):
        with no_recompiles(eng2):
            eng2.submit(
                Request(jnp.asarray(list(range(1, 34)), jnp.int32), max_new=2)
            )
            eng2.run_until_done()
