import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade property tests to fixed-seed cases
    from hypothesis_fallback import given, settings, strategies as st

from repro.core.manifold import HybridOpt, cayley_step
from repro.core.transforms import (
    GLParams,
    gl_init,
    gl_inverse,
    gl_materialize,
    hadamard_matrix,
    orthogonal_init,
    orthogonality_error,
    random_orthogonal,
)


def test_hadamard_orthogonal_pow2():
    for n in (2, 8, 64, 128):
        h = hadamard_matrix(n)
        assert float(orthogonality_error(h)) < 1e-5


def test_hadamard_orthogonal_non_pow2():
    # the dims that appear in assigned archs
    for n in (1536, 3072, 5120):
        h = hadamard_matrix(n)
        assert float(orthogonality_error(h)) < 1e-4


def test_random_orthogonal():
    q = random_orthogonal(jax.random.PRNGKey(0), 96)
    assert float(orthogonality_error(q)) < 1e-5


def test_gl_identity_at_init():
    p = gl_init(32)
    g = gl_materialize(p)
    np.testing.assert_allclose(np.asarray(g), np.eye(32), atol=1e-5)
    gi = gl_inverse(p)
    np.testing.assert_allclose(np.asarray(gi), np.eye(32), atol=1e-5)


def test_gl_inverse_consistency_after_perturbation():
    key = jax.random.PRNGKey(1)
    p = gl_init(24)
    p = GLParams(
        P=random_orthogonal(key, 24),
        L=p.L + 0.05 * jax.random.normal(jax.random.PRNGKey(2), (24, 24)),
        gamma=jnp.asarray(0.3),
    )
    g = gl_materialize(p)
    gi = gl_inverse(p)
    np.testing.assert_allclose(np.asarray(g @ gi), np.eye(24), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gi @ g), np.eye(24), atol=1e-4)


def test_cayley_step_preserves_orthogonality():
    key = jax.random.PRNGKey(3)
    q = random_orthogonal(key, 48)
    a = jax.random.normal(jax.random.PRNGKey(4), (48, 48))
    skew = a - a.T
    q2 = cayley_step(q, skew, 0.1)
    assert float(orthogonality_error(q2)) < 1e-4


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1), st.floats(min_value=1e-4, max_value=0.5))
def test_property_cayley_always_on_manifold(seed, lr):
    """Property: Cayley retraction keeps Q orthogonal for any skew/lr."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = random_orthogonal(k1, 16)
    a = jax.random.normal(k2, (16, 16))
    q2 = cayley_step(q, a - a.T, lr)
    assert float(orthogonality_error(q2)) < 1e-4


def test_hybrid_opt_descends_and_stays_on_manifold():
    """Minimize ||X Q - Y||^2 over orthogonal Q: must descend and stay on M."""
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (64, 32))
    q_true = random_orthogonal(k2, 32)
    y = x @ q_true

    params = {"Q": orthogonal_init(32, "random", key=k3), "b": jnp.zeros((32,))}
    mask = {"Q": True, "b": False}
    opt = HybridOpt(lr=0.05, momentum=0.9)
    state = opt.init(params)

    def loss(p):
        return jnp.mean((x @ p["Q"] + p["b"] - y) ** 2)

    l0 = float(loss(params))
    step = jax.jit(lambda p, s: opt.update(jax.grad(loss)(p), s, p, mask))
    for _ in range(200):
        params, state = step(params, state)
    l1 = float(loss(params))
    assert l1 < l0 * 0.05
    assert float(orthogonality_error(params["Q"])) < 1e-3


def test_hybrid_opt_lr_scale_freezes_leaves():
    key = jax.random.PRNGKey(6)
    params = {"Q": orthogonal_init(16, "random", key=key), "b": jnp.ones((16,))}
    mask = {"Q": True, "b": False}
    opt = HybridOpt(lr=0.1)
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["Q"] ** 2) + jnp.sum(p["b"] ** 2)

    scales = {"Q": 0.0, "b": 1.0}
    new_params, _ = opt.update(jax.grad(loss)(params), state, params, mask, scales)
    np.testing.assert_array_equal(np.asarray(new_params["Q"]), np.asarray(params["Q"]))
    assert not np.allclose(np.asarray(new_params["b"]), np.asarray(params["b"]))
