"""Chaos suite: the fault-tolerance contract under seeded injection.

Every test drives the engine through a deterministic fault —
allocator exhaustion, forced ref dispatch, a tampered TwinQuant pack, NaN
logits in one slot, deadlines, cancellation, preemption — and asserts the
recovery INVARIANTS, not just survival:

* unaffected requests produce tokens bit-identical to a fault-free run;
* ``allocator.audit()`` / ``check_page_invariants()`` stay green after
  every step (the page-invariant sanitizer runs inside the loop);
* every request ends in a terminal state with its machine-readable reason
  code (the lifecycle sanitizer audits the state machine each step);
* a preempted-then-resumed greedy request matches its uninterrupted oracle
  token for token.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitizers import (
    guarded_decode,
    lifecycle_checks,
    page_invariant_checks,
)
from repro.configs import ModelConfig, QuantSpec
from repro.core.twinquant import quantize_params
from repro.launch.faults import FaultInjector
from repro.launch.serve import (
    AllocatorError,
    ContinuousBatchingEngine,
    EngineStalledError,
    PageAllocator,
    Request,
    RequestState,
)
from repro.models import dense, olmoe

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(
    name="tiny-chaos", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, remat=False,
)

# capacity_factor headroom: ragged/interleaved MoE rows must stay drop-free
MCFG = ModelConfig(
    name="tiny-chaos-moe", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, vocab=256, remat=False,
    n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=4.0,
)

# wide enough to pack (scale groups divide d_model): the quantized-engine
# chaos tests (forced ref routes, tampered packs) need real TwinQuant packs
QCFG = ModelConfig(
    name="tiny-chaos-quant", family="dense", n_layers=2, d_model=256,
    n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512, vocab=260, remat=False,
)


@pytest.fixture(scope="module")
def params():
    return dense.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mparams():
    return olmoe.init_params(MCFG, jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def qparams():
    p = dense.init_params(QCFG, jax.random.PRNGKey(2))
    return quantize_params(p, QCFG, QuantSpec(mode="w4a4", rank=32))


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, 200, size=n).tolist()


def _solo(cfg, params, prompt, max_new=8):
    """Dense-engine solo serving: the correctness oracle."""
    eng = ContinuousBatchingEngine(cfg, params, batch_slots=1, max_len=64)
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=max_new)
    eng.serve([req])
    assert req.done
    return req.out


# ---------------------------------------------------------------------------
# lifecycle state machine
# ---------------------------------------------------------------------------


def test_lifecycle_states_and_cancel(params):
    """QUEUED -> PREFILL -> DECODE -> DONE for a served request; cancel()
    works both queued and mid-decode, releases pages, and leaves survivors'
    tokens equal to the solo oracle."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True)
    a = Request(jnp.asarray(_prompt(12, 1), jnp.int32), max_new=8)
    b = Request(jnp.asarray(_prompt(12, 2), jnp.int32), max_new=8)
    c = Request(jnp.asarray(_prompt(12, 3), jnp.int32), max_new=8)
    assert a.status == RequestState.NEW
    with lifecycle_checks(eng), page_invariant_checks(eng):
        for r in (a, b, c):
            eng.submit(r)
        assert c.status == RequestState.QUEUED  # only 2 slots
        eng.step()
        assert a.status == RequestState.DECODE
        # cancel c while still queued, b while mid-decode
        assert eng.cancel(c.request_id)
        assert eng.cancel(b)
        assert not eng.cancel(b)  # already terminal: no-op
        eng.run_until_done()
    assert a.status == RequestState.DONE and a.done
    assert b.status == RequestState.CANCELLED and b.error is None
    assert c.status == RequestState.CANCELLED
    assert eng.stats["requests_cancelled"] == 2
    assert a.out == _solo(CFG, params, _prompt(12, 1))
    # every page came back (the prefix cache may retain registrations)
    eng.check_page_invariants()


def test_deadline_steps_timeout(params):
    """A request with an exhausted step budget is TIMED_OUT with its reason
    code, pages come back, and the surviving request matches the oracle."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True)
    a = Request(jnp.asarray(_prompt(12, 1), jnp.int32), max_new=8)
    b = Request(jnp.asarray(_prompt(12, 4), jnp.int32), max_new=32,
                deadline_steps=3)
    with lifecycle_checks(eng), page_invariant_checks(eng):
        eng.submit(a)
        eng.submit(b)
        eng.run_until_done()
    assert a.status == RequestState.DONE
    assert b.status == RequestState.TIMED_OUT and b.done
    assert b.error == "deadline_steps"
    assert eng.stats["requests_timed_out"] == 1
    assert 0 < len(b.out) < 32  # partial output survives the timeout
    assert a.out == _solo(CFG, params, _prompt(12, 1))


def test_run_until_done_exhaustion_surfaces(params):
    """Exhausting max_steps raises EngineStalledError instead of silently
    returning: stranded requests are TIMED_OUT (engine_stalled), their pages
    released, and the allocator audit stays green."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=1, max_len=64,
                                   paged=True)
    r = Request(jnp.asarray(_prompt(12, 5), jnp.int32), max_new=16)
    eng.submit(r)
    with pytest.raises(EngineStalledError, match="engine stalled"):
        eng.run_until_done(max_steps=3)
    assert r.status == RequestState.TIMED_OUT and r.done
    assert r.error == "engine_stalled"
    # only prefix-cache registrations may still hold pages — the slot's own
    # references all came back through the common exit path
    assert eng.allocator.n_used == len(eng.prefix_cache.entries)
    eng.check_page_invariants()


def test_submit_rejects_out_of_vocab(params):
    """Garbage token ids fail at the API boundary with a clear message, not
    as an XLA gather deep inside prefill."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=1, max_len=64)
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request(jnp.asarray([3, 999, 5], jnp.int32), max_new=4))
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request(jnp.asarray([-1, 2], jnp.int32), max_new=4))
    with pytest.raises(ValueError, match="integer"):
        eng.submit(Request(jnp.asarray([0.5, 2.0], jnp.float32), max_new=4))
    assert not eng.queue and all(s is None for s in eng.slots)


# ---------------------------------------------------------------------------
# preemption + requeue
# ---------------------------------------------------------------------------


def test_preempt_resume_matches_uninterrupted_oracle(params):
    """Page pressure preempts the low-priority request; on readmission the
    prefix cache restores its written pages copy-free and the resumed greedy
    output is token-for-token the uninterrupted solo run."""
    # pool of 3 pages; each request reserves 2, so admitting the second
    # request REQUIRES preempting the first
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True, page_size=16, n_pages=3,
                                   preemption=True)
    low = Request(jnp.asarray(_prompt(20, 6), jnp.int32), max_new=8, priority=0)
    with lifecycle_checks(eng), page_invariant_checks(eng):
        eng.submit(low)
        for _ in range(3):  # let `low` make real decode progress first
            eng.step()
        assert len(low.out) >= 2
        high = Request(jnp.asarray(_prompt(20, 7), jnp.int32), max_new=8,
                       priority=1)
        eng.submit(high)
        eng.run_until_done()
    assert eng.stats["requests_preempted"] >= 1
    assert low._preemptions >= 1
    assert low.status == RequestState.DONE
    assert high.status == RequestState.DONE
    # copy-free resume: readmission matched the preempt-time registration
    assert eng.stats["prefix_hits"] >= 1
    assert low.out == _solo(CFG, params, _prompt(20, 6))
    assert high.out == _solo(CFG, params, _prompt(20, 7))


def test_preempt_resume_ragged(params):
    """Same preempt/resume bar through the unified ragged step."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True, ragged=True, page_size=16,
                                   n_pages=3, preemption=True)
    low = Request(jnp.asarray(_prompt(20, 6), jnp.int32), max_new=8, priority=0)
    with lifecycle_checks(eng), page_invariant_checks(eng):
        eng.submit(low)
        for _ in range(4):
            eng.step()
        assert len(low.out) >= 1
        high = Request(jnp.asarray(_prompt(20, 7), jnp.int32), max_new=8,
                       priority=1)
        eng.submit(high)
        eng.run_until_done()
    assert eng.stats["requests_preempted"] >= 1
    assert low.status == RequestState.DONE
    assert high.status == RequestState.DONE
    assert low.out == _solo(CFG, params, _prompt(20, 6))
    assert high.out == _solo(CFG, params, _prompt(20, 7))


# ---------------------------------------------------------------------------
# injected faults
# ---------------------------------------------------------------------------


def test_nan_logits_quarantines_only_offending_slot(params):
    """NaN injected into one slot's decode logits: that request FAILS with
    reason nan_logits; the other slot's tokens are bit-identical to the
    fault-free interleaved run."""
    def interleaved(inject):
        eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                       paged=True)
        a = Request(jnp.asarray(_prompt(12, 8), jnp.int32), max_new=8)
        b = Request(jnp.asarray(_prompt(12, 9), jnp.int32), max_new=8)
        with FaultInjector(seed=0) as fi:
            if inject:
                fi.corrupt_logits(slot=1, at_call=3, tag="decode")
            with lifecycle_checks(eng), page_invariant_checks(eng):
                eng.submit(a)
                eng.submit(b)
                eng.run_until_done()
        return a, b, eng
    a0, b0, _ = interleaved(inject=False)
    a1, b1, eng = interleaved(inject=True)
    assert b1.status == RequestState.FAILED and b1.done
    assert b1.error == "nan_logits"
    assert eng.stats["requests_failed"] == 1
    assert a1.status == RequestState.DONE
    assert a1.out == a0.out  # unaffected slot: bit-identical
    assert b1.out == b0.out[: len(b1.out)]  # victim kept its pre-fault tokens


def test_nan_prefill_logits_fail_at_admission(params):
    """NaN in the prefill logits fails the request at admission (nan_logits)
    without touching the other slot or leaking its reservation."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True)
    a = Request(jnp.asarray(_prompt(12, 8), jnp.int32), max_new=8)
    b = Request(jnp.asarray(_prompt(13, 9), jnp.int32), max_new=8)
    with FaultInjector(seed=0) as fi:
        with lifecycle_checks(eng), page_invariant_checks(eng):
            eng.submit(a)
            eng.step()  # a admitted cleanly
            fi.corrupt_logits(slot=0, at_call=1, tag="prefill")
            eng.submit(b)
            eng.run_until_done()
    assert b.status == RequestState.FAILED and b.error == "nan_logits"
    assert a.status == RequestState.DONE
    assert a.out == _solo(CFG, params, _prompt(12, 8))


def test_alloc_denial_backpressure(params):
    """A transient allocator outage delays admission but loses nothing: all
    requests finish with tokens equal to their solo oracles and the audit
    stays green throughout."""
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64,
                                   paged=True)
    reqs = [Request(jnp.asarray(_prompt(12, 10 + k), jnp.int32), max_new=6)
            for k in range(3)]
    with FaultInjector(seed=0) as fi:
        fi.deny_alloc(eng, at_call=2, count=3)
        with lifecycle_checks(eng), page_invariant_checks(eng):
            for r in reqs:
                eng.submit(r)
            eng.run_until_done()
    assert [d["kind"] for d in fi.log].count("deny_alloc") >= 1
    for k, r in enumerate(reqs):
        assert r.status == RequestState.DONE
        assert r.out == _solo(CFG, params, _prompt(12, 10 + k), max_new=6)


def test_forced_ref_dispatch_degrades_gracefully(qparams):
    """With every dispatch entry forced onto its reference path, the
    quantized engine still serves byte-identical tokens, and the routing
    table shows the machine-readable ref[forced] code."""
    def run(force):
        with FaultInjector(seed=0) as fi:
            if force:
                fi.force_ref_dispatch()
            eng = ContinuousBatchingEngine(QCFG, qparams, batch_slots=2,
                                           max_len=64, paged=True)
            reqs = [Request(jnp.asarray(_prompt(12, 20 + k), jnp.int32),
                            max_new=4) for k in range(2)]
            eng.serve(reqs)
            return [r.out for r in reqs], eng.routing()
    out_ref, routes_ref = run(force=True)
    out_base, _ = run(force=False)
    assert out_ref == out_base
    forced = {k: v for k, v in routes_ref.items() if k.endswith("[forced]")}
    assert forced, f"no ref[forced] routes recorded: {routes_ref}"


def test_tampered_pack_is_quarantined(qparams):
    """A pack corrupted in flight raises a ContractError inside prefill; the
    engine quarantines the request (FAILED, prefill_exception), releases its
    reservation, and keeps serving — the EN003 exception path, live."""
    fi = FaultInjector(seed=0)
    bad_params = fi.tamper_pack(qparams)
    assert fi.log[-1]["kind"] == "tamper_pack"
    # the contract layer rejects the malformed pack eagerly at dispatch
    eng = ContinuousBatchingEngine(QCFG, bad_params, batch_slots=2,
                                   max_len=64, paged=True)
    r = Request(jnp.asarray(_prompt(12, 30), jnp.int32), max_new=4)
    with lifecycle_checks(eng), page_invariant_checks(eng):
        eng.submit(r)
        eng.run_until_done()
    assert r.status == RequestState.FAILED and r.done
    assert r.error == "prefill_exception"
    # the captured detail is the dispatch layer's spelled-out ContractError
    assert "ContractError" in r.error_detail
    assert eng.allocator.n_used == 0


# ---------------------------------------------------------------------------
# allocator hardening
# ---------------------------------------------------------------------------


def test_allocator_rejects_unknown_and_unreferenced_pages():
    """Double release, unknown ids, and sharing a free page all raise a
    spelled-out AllocatorError naming the page and refcount."""
    al = PageAllocator(4)
    pages = al.alloc(2)
    al.release(pages)
    with pytest.raises(AllocatorError, match="double release"):
        al.release([pages[0]])
    with pytest.raises(AllocatorError, match="unknown page"):
        al.release([99])
    with pytest.raises(AllocatorError, match="unknown page"):
        al.share([-3])
    with pytest.raises(AllocatorError, match="unreferenced page"):
        al.share([pages[0]])
    al.audit()  # failed ops corrupted nothing


# ---------------------------------------------------------------------------
# randomized interleaved schedule (seeded)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg_name", ["dense", "moe"])
def test_randomized_cancel_timeout_preempt_schedule(cfg_name, params, mparams):
    """A seeded random schedule of submits, cancels, and deadline expiries
    under page pressure (preemption on), against the dense and MoE families:
    every request terminates in a sane state, survivors' tokens equal the
    solo oracle, and no pages leak."""
    cfg, p = (CFG, params) if cfg_name == "dense" else (MCFG, mparams)
    rng = np.random.default_rng(42)
    eng = ContinuousBatchingEngine(cfg, p, batch_slots=2, max_len=64,
                                   paged=True, page_size=16, n_pages=6,
                                   preemption=True)
    prompts = {k: _prompt(int(rng.integers(8, 20)), 100 + k) for k in range(6)}
    reqs = {k: Request(jnp.asarray(v, jnp.int32), max_new=6,
                       priority=int(rng.integers(0, 3)),
                       deadline_steps=(None if rng.random() < 0.7
                                       else int(rng.integers(2, 30))))
            for k, v in prompts.items()}
    pending = list(reqs)
    with lifecycle_checks(eng), page_invariant_checks(eng):
        for step in range(200):
            if pending and rng.random() < 0.4:
                eng.submit(reqs[pending.pop(0)])
            if rng.random() < 0.1:
                victim = reqs[int(rng.integers(6))]
                eng.cancel(victim)  # may be a no-op; must never corrupt
            if eng.step() == 0 and not eng.queue and not pending:
                break
    assert not pending
    leaked = eng.allocator.n_used
    if eng.prefix_cache is not None:
        leaked -= sum(1 for _ in eng.prefix_cache.entries)
    assert leaked <= 0, f"{leaked} pages leaked past cache registrations"
    for k, r in reqs.items():
        assert r.status in RequestState.TERMINAL, (k, r.status)
        if r.status == RequestState.DONE and not r.truncated:
            assert r.out == _solo(cfg, p, prompts[k], max_new=6), k
    eng.check_page_invariants()


@pytest.mark.parametrize("cfg_name", ["dense", "moe"])
def test_randomized_schedule_with_speculation(cfg_name, params, mparams):
    """The same seeded random schedule with speculative decoding on: every
    launch stacks spec_k candidate rows per slot and rolls the rejected tail
    back by rewinding pos. Cancels, deadline expiries, and preemptions land
    between (and during) those rollbacks, so this is the adversarial case
    for the rewind bookkeeping — survivors must still match the
    NON-speculative solo oracle token for token, and the rolled-back page
    writes must leak nothing past the prefix-cache registrations."""
    from repro.analysis.sanitizers import assert_compile_budget

    cfg, p = (CFG, params) if cfg_name == "dense" else (MCFG, mparams)
    rng = np.random.default_rng(42)
    eng = ContinuousBatchingEngine(cfg, p, batch_slots=2, max_len=64,
                                   paged=True, page_size=16, n_pages=6,
                                   preemption=True, speculation=True,
                                   spec_k=4)
    assert eng.speculation
    prompts = {k: _prompt(int(rng.integers(8, 20)), 100 + k) for k in range(6)}
    reqs = {k: Request(jnp.asarray(v, jnp.int32), max_new=6,
                       priority=int(rng.integers(0, 3)),
                       deadline_steps=(None if rng.random() < 0.7
                                       else int(rng.integers(2, 30))))
            for k, v in prompts.items()}
    pending = list(reqs)
    with lifecycle_checks(eng), page_invariant_checks(eng):
        for step in range(200):
            if pending and rng.random() < 0.4:
                eng.submit(reqs[pending.pop(0)])
            if rng.random() < 0.1:
                victim = reqs[int(rng.integers(6))]
                eng.cancel(victim)  # may be a no-op; must never corrupt
            if eng.step() == 0 and not eng.queue and not pending:
                break
    assert not pending
    leaked = eng.allocator.n_used
    if eng.prefix_cache is not None:
        leaked -= sum(1 for _ in eng.prefix_cache.entries)
    assert leaked <= 0, f"{leaked} pages leaked past speculative rollbacks"
    for k, r in reqs.items():
        assert r.status in RequestState.TERMINAL, (k, r.status)
        if r.status == RequestState.DONE and not r.truncated:
            assert r.out == _solo(cfg, p, prompts[k], max_new=6), k
    eng.check_page_invariants()
    # the whole chaotic lifetime still compiled ONE speculative executable
    assert assert_compile_budget(eng)["spec_traces"] <= 1
