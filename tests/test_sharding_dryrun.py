"""Sharding + dry-run machinery tests on a small forced-device mesh.

These exercise the exact code paths the 512-device production dry-run uses
(param specs, batch specs, decode-state specs, lower+compile with shardings,
HLO cost model) at 4-device scale so they run in CI time.
"""

import os

import pytest

# must precede jax import in this test process
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.hlo_cost import analyze_hlo  # noqa: E402
from repro.launch.mesh import use_mesh  # noqa: E402
from repro.launch.sharding import (  # noqa: E402
    batch_specs,
    decode_state_specs,
    make_shardings,
    param_specs,
)
from repro.launch.train import make_train_step  # noqa: E402
from repro.models.context import MeshContext, set_mesh_context  # noqa: E402
from repro.models.registry import get_model  # noqa: E402
from repro.optim import AdamW  # noqa: E402


@pytest.fixture()
def mesh_ctx():
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 forced host devices")
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ctx = MeshContext(mesh=mesh, dp_axes=("data",), tp_axis="model",
                      ep_axis="model", fsdp_axes=("data",))
    set_mesh_context(ctx)
    yield mesh, ctx
    set_mesh_context(MeshContext())


def _params_sds(cfg, model):
    return jax.eval_shape(lambda k: model.init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "olmoe-1b-7b", "zamba2-1.2b"])
def test_param_specs_divide(mesh_ctx, arch):
    """Specs must map every leaf and only use axis sizes that divide dims."""
    mesh, ctx = mesh_ctx
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    sds = _params_sds(cfg, model)
    specs = param_specs(cfg, sds, ctx)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_p = jax.tree.leaves(sds)
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, (leaf.shape, spec)


def test_train_step_compiles_sharded(mesh_ctx):
    mesh, ctx = mesh_ctx
    cfg = get_config("qwen2-1.5b", reduced=True).replace(d_model=256, d_ff=512, vocab=512)
    model = get_model(cfg)
    sds = _params_sds(cfg, model)
    pspecs = param_specs(cfg, sds, ctx)
    pshard = make_shardings(mesh, pspecs)
    opt = AdamW()
    osds = jax.eval_shape(opt.init, sds)
    oshard = make_shardings(mesh, type(osds)(mu=pspecs, nu=pspecs, count=P()))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
    bshard = make_shardings(mesh, batch_specs(cfg, batch, ctx))
    step = make_train_step(cfg, opt)
    with use_mesh(mesh):
        compiled = jax.jit(
            step, in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
        ).lower(sds, osds, batch).compile()
    r = analyze_hlo(compiled.as_text())
    assert r["flops"] > 0
    assert r["coll_bytes"] > 0  # DP gradient reduction must be present


def test_decode_state_specs_long_context(mesh_ctx):
    """long_500k rule: batch=1 can't use dp -> sequence dim is sharded."""
    mesh, ctx = mesh_ctx
    cfg = get_config("zamba2-1.2b", reduced=True)
    model = get_model(cfg)
    state = jax.eval_shape(lambda: model.init_decode_state(cfg, 1, 4096))
    specs = decode_state_specs(cfg, state, ctx, seq_shard=True)
    k_spec = specs["shared_k"]
    assert any(a is not None for a in tuple(k_spec)), k_spec
    # the seq dim (index 2) carries the sharding
    assert tuple(k_spec)[2] is not None


def test_ep_moe_collectives_present(mesh_ctx):
    """The EP path must lower to all-to-all over the expert axis."""
    mesh, ctx = mesh_ctx
    cfg = get_config("olmoe-1b-7b", reduced=True).replace(remat=False)
    model = get_model(cfg)
    sds = _params_sds(cfg, model)
    pshard = make_shardings(mesh, param_specs(cfg, sds, ctx))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
    bshard = make_shardings(mesh, batch_specs(cfg, batch, ctx))
    with use_mesh(mesh):
        compiled = jax.jit(
            lambda p, b: model.loss_fn(p, cfg, b),
            in_shardings=(pshard, bshard),
        ).lower(sds, batch).compile()
    r = analyze_hlo(compiled.as_text())
    assert r["coll_detail"].get("all-to-all", 0) > 0, r["coll_detail"]


def test_hlo_cost_scan_multiplier():
    """The cost model must multiply scan bodies by trip count."""
    def scan_fn(w, x):
        def body(x, wl):
            return x @ wl, None
        y, _ = jax.lax.scan(body, x, w)
        return jnp.sum(y)

    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)
    compiled = jax.jit(scan_fn).lower(w, x).compile()
    r = analyze_hlo(compiled.as_text())
    expect = 8 * 2 * 16 * 128 * 128
    assert 0.8 * expect < r["flops"] < 2.0 * expect, (r["flops"], expect)
