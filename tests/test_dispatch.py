"""Dispatch-layer tests: shape-regime routing, kernel/oracle agreement on all
three paths (prefill kernel / decode kernel / jnp ref), autotuner cache
round-trips, and dispatch-counter accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.autotune import (
    TuneCache,
    autotune_blocks,
    cache_key,
    candidate_blocks,
    get_blocks,
    heuristic_blocks,
)
from repro.kernels.dispatch import (
    DECODE_M_MAX,
    QuantLinear,
    classify_dual,
    classify_w4a16,
    dispatch_counters,
    quant_linear,
    reset_dispatch_counters,
    w4a16_linear,
)
from repro.kernels.ops import pick_blocks
from repro.kernels.ref import (
    dual_gemm_ref,
    pack_rows_groupsplit,
    pack_twinquant_weights,
    quantize_rows_ref,
    w4a16_gemm_ref,
)


def _make_pack(key, K, N, r, a_bits=4, group=128):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    U = jax.random.normal(k1, (K, r)) * 0.1
    V = jax.random.normal(k2, (r, N)) * 0.1
    R = jax.random.normal(k3, (K, N)) * 0.05
    return pack_twinquant_weights(U, V, R, a_bits=a_bits, group=group), k4


def _assert_bf16_close(y_k, y_ref, max_ulp=2):
    """<=2 bf16 ULP: identical math modulo f32 reassociation (test_kernels)."""
    a = np.asarray(jnp.asarray(y_k, jnp.bfloat16)).view(np.uint16).astype(np.int32)
    b = np.asarray(jnp.asarray(y_ref, jnp.bfloat16)).view(np.uint16).astype(np.int32)
    ka = np.where(a & 0x8000, 0x7FFF - (a & 0x7FFF), 0x8000 + a)
    kb = np.where(b & 0x8000, 0x7FFF - (b & 0x7FFF), 0x8000 + b)
    ulp = np.abs(ka - kb)
    assert ulp.max() <= max_ulp, f"{(ulp > max_ulp).sum()} elements differ (max {ulp.max()})"


# ---------------------------------------------------------------------------
# routing classification
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,k,expected", [
    (1, 256, 512, "decode"),
    (3, 384, 512, "decode"),
    (8, 256, 512, "decode"),
    (DECODE_M_MAX, 128, 256, "decode"),
    (DECODE_M_MAX + 1, 256, 512, "prefill"),
    (64, 256, 512, "prefill"),
    (1024, 384, 1024, "prefill"),
    (3, 100, 512, "ref"),      # N not 128-aligned
    (64, 100, 512, "ref"),
    (4, 256, 300, "ref"),      # K not a group multiple
    (64, 384, 192, "ref"),     # old pick_blocks bk bug: 192 % 128 != 0
])
def test_classify_dual_regimes(m, n, k, expected):
    route = classify_dual(m, n, k, group=128, rgroup=32, rank=32)
    assert route.path == expected, route
    if expected == "ref":
        assert route.blocks is None
    else:
        bm, bn, bk = route.blocks
        assert n % bn == 0
        if expected == "prefill":
            assert k % bk == 0 and bk % 128 == 0


def test_classify_w4a16_regimes():
    assert classify_w4a16(16, 256, 512, 128).path == "prefill"
    assert classify_w4a16(16, 100, 512, 128).path == "ref"
    assert classify_w4a16(16, 256, 300, 128).path == "ref"


def test_pick_blocks_untileable_returns_none():
    """The two old fallback bugs must now surface as None (-> ref route)."""
    assert pick_blocks(64, 100, 512, 128) is None  # was bn = n = 100
    assert pick_blocks(64, 384, 300, 128) is None  # was bk = max(300, 128)
    assert pick_blocks(64, 384, 192, 128) is None  # 192 % 128 != 0
    blocks = pick_blocks(64, 384, 512, 128)
    assert blocks is not None and 384 % blocks[1] == 0 and 512 % blocks[2] == 0


# ---------------------------------------------------------------------------
# kernel/oracle agreement through the dispatcher (all three paths)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 3, 8])
@pytest.mark.parametrize("a_bits", [4, 8])
def test_decode_path_matches_oracle(m, a_bits):
    w, kx = _make_pack(jax.random.PRNGKey(m * 10 + a_bits), 512, 256, 64, a_bits)
    x = (jax.random.normal(kx, (m, 512)) * 2).astype(jnp.bfloat16)
    assert classify_dual(m, 256, 512, 128, w.rgroup, w.rank).path == "decode"
    y = quant_linear(x, w, impl="kernel", interpret=True)
    y_ref = dual_gemm_ref(x, w)
    # the decode schedule reproduces the oracle's accumulation order exactly
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32)
    )


def test_prefill_path_matches_oracle():
    w, kx = _make_pack(jax.random.PRNGKey(5), 512, 256, 64)
    x = (jax.random.normal(kx, (48, 512)) * 2).astype(jnp.bfloat16)  # pads to bm
    assert classify_dual(48, 256, 512, 128, w.rgroup, w.rank).path == "prefill"
    y = quant_linear(x, w, impl="kernel", interpret=True)
    _assert_bf16_close(y, dual_gemm_ref(x, w))


@pytest.mark.parametrize("m,n,k", [
    (1, 100, 512),   # odd N -> ref
    (3, 96, 256),    # N < 128 -> ref
    (8, 100, 512),
    (33, 100, 512),  # odd N in the prefill regime -> ref
])
def test_ref_path_odd_shapes_no_assert(m, n, k):
    """Untileable shapes must route to the oracle, not trip kernel asserts."""
    w, kx = _make_pack(jax.random.PRNGKey(m + n), k, n, 32)
    x = (jax.random.normal(kx, (m, k)) * 2).astype(jnp.bfloat16)
    assert classify_dual(m, n, k, 128, w.rgroup, w.rank).path == "ref"
    y = quant_linear(x, w, impl="kernel", interpret=True)  # impl hint ignored on ref
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(dual_gemm_ref(x, w), np.float32)
    )


def test_batch_dims_and_bias_through_dispatch():
    w, kx = _make_pack(jax.random.PRNGKey(9), 256, 128, 32)
    x = (jax.random.normal(kx, (2, 3, 256))).astype(jnp.bfloat16)  # M=6 -> decode
    b = jnp.arange(128, dtype=jnp.float32) * 0.01
    y = quant_linear(x, w, b, impl="kernel", interpret=True)
    assert y.shape == (2, 3, 128)
    y_ref = dual_gemm_ref(x.reshape(6, 256), w).reshape(2, 3, 128)
    y_ref = (y_ref.astype(jnp.float32) + b).astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(y, np.float32), np.asarray(y_ref, np.float32))


def test_w4a16_ref_fallback_matches_oracle():
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    wq, ws = quantize_rows_ref(jax.random.normal(k1, (256, 100)) * 0.1, 128, 4)
    wp = pack_rows_groupsplit(wq, 128)
    x = (jax.random.normal(k2, (5, 256))).astype(jnp.bfloat16)
    assert classify_w4a16(5, 100, 256, 128).path == "ref"
    y = w4a16_linear(x, wp, ws, group=128)
    np.testing.assert_array_equal(
        np.asarray(y, np.float32),
        np.asarray(w4a16_gemm_ref(x, wp, ws, group=128), np.float32),
    )


def test_quantlinear_entrypoint():
    w, kx = _make_pack(jax.random.PRNGKey(11), 256, 128, 32)
    layer = QuantLinear(w)
    assert layer.route_for((4, 256)).path == "decode"
    assert layer.route_for((2, 64, 256)).path == "prefill"
    x = (jax.random.normal(kx, (4, 256)) * 2).astype(jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(layer(x), np.float32),
        np.asarray(dual_gemm_ref(x, w), np.float32),
    )


# ---------------------------------------------------------------------------
# dispatch counters
# ---------------------------------------------------------------------------


def test_dispatch_counters_record_paths():
    w, kx = _make_pack(jax.random.PRNGKey(21), 512, 256, 64)
    w_odd, _ = _make_pack(jax.random.PRNGKey(22), 512, 100, 32)
    x_dec = jnp.ones((4, 512), jnp.bfloat16)
    x_pre = jnp.ones((64, 512), jnp.bfloat16)
    reset_dispatch_counters()
    quant_linear(x_dec, w)
    quant_linear(x_dec, w)
    quant_linear(x_pre, w)
    quant_linear(x_dec, w_odd)
    c = dispatch_counters()
    assert c["dual/decode"] == 2
    assert c["dual/prefill"] == 1
    assert c["dual/ref"] == 1
    reset_dispatch_counters()
    assert dispatch_counters() == {}


# ---------------------------------------------------------------------------
# autotuner: heuristic determinism + persisted cache round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,k,group", [
    (4, 4096, 4096, 128), (256, 14336, 4096, 128), (64, 384, 768, 128),
    (8, 1024, 14336, 128), (1, 128, 256, 64),
])
def test_heuristic_blocks_valid_and_deterministic(m, n, k, group):
    kind = "dual_decode" if m <= DECODE_M_MAX else "dual_prefill"
    a = heuristic_blocks(kind, m, n, k, group)
    b = heuristic_blocks(kind, m, n, k, group)
    assert a == b and a is not None
    bm, bn, bk = a
    assert n % bn == 0 and bn % 128 == 0
    assert k % bk == 0 and bk % group == 0


def test_cache_key_uses_regime_not_exact_m():
    assert cache_key("dual", 1, 512, 256, 128, 32) == cache_key("dual", 8, 512, 256, 128, 32)
    assert cache_key("dual", 8, 512, 256, 128, 32) != cache_key("dual", 9, 512, 256, 128, 32)


def test_tune_cache_roundtrip(tmp_path):
    cache = TuneCache(tmp_path)
    key = cache_key("dual_prefill", 256, 512, 1024, 128, 64)
    cache.store(key, (64, 128, 256), best_us=12.5, candidates=9)
    # a fresh instance must read back the identical decision from disk
    fresh = TuneCache(tmp_path)
    assert fresh.lookup(key) == (64, 128, 256)
    # and the persisted winner takes precedence over the heuristic
    tuned = get_blocks("dual_prefill", 256, 512, 1024, 128, 64, cache=fresh)
    assert tuned == (64, 128, 256)
    assert tuned != heuristic_blocks("dual_prefill", 256, 512, 1024, 128, 64)
    # unknown shapes fall back to the deterministic heuristic
    assert get_blocks("dual_prefill", 256, 512, 2048, 128, 64, cache=fresh) == \
        heuristic_blocks("dual_prefill", 256, 512, 2048, 128, 64)


def test_stale_cache_entry_degrades_to_heuristic(tmp_path):
    """A cache entry that violates the tiling contract (stale/foreign/hand-
    edited) must fall back to the heuristic, never reach a kernel assert."""
    cache = TuneCache(tmp_path)
    key = cache_key("dual_prefill", 256, 512, 1024, 128, 64)
    cache.store(key, (128, 384, 768))  # 512 % 384 != 0, 1024 % 768 != 0
    fresh = TuneCache(tmp_path)
    assert fresh.lookup(key) == (128, 384, 768)  # raw lookup returns it
    assert get_blocks("dual_prefill", 256, 512, 1024, 128, 64, cache=fresh) == \
        heuristic_blocks("dual_prefill", 256, 512, 1024, 128, 64)


def test_tune_cache_file_is_schema1_json(tmp_path):
    import json

    cache = TuneCache(tmp_path)
    key = cache_key("dual_decode", 4, 256, 512, 128, 32)
    cache.store(key, (8, 256, 512))
    doc = json.loads((tmp_path / "dual_decode.json").read_text())
    assert doc["schema"] == 1
    assert doc["entries"][key]["blocks"] == [8, 256, 512]


def test_autotune_measured_sweep_persists(tmp_path):
    cache = TuneCache(tmp_path)
    calls = []

    def make_call(blocks):
        def run():
            calls.append(blocks)
            return jnp.zeros(())

        return run

    best = autotune_blocks("dual_prefill", make_call, 256, 512, 1024, 128, 64,
                           cache=cache, iters=1)
    cands = candidate_blocks("dual_prefill", 256, 512, 1024, 128, 64)
    assert best in cands
    assert set(calls) == set(cands)  # every candidate was measured
    assert TuneCache(tmp_path).lookup(
        cache_key("dual_prefill", 256, 512, 1024, 128, 64)
    ) == best


def test_autotune_untileable_returns_none(tmp_path):
    cache = TuneCache(tmp_path)
    assert autotune_blocks("dual_prefill", lambda b: lambda: jnp.zeros(()),
                           64, 100, 512, 128, cache=cache) is None
    assert not (tmp_path / "dual_prefill.json").exists()
