"""Per-arch smoke tests (deliverable (f)): instantiate the REDUCED config of
each assigned architecture, run one forward/train step + a prefill/decode
step on CPU, assert output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import get_model

jax.config.update("jax_platform_name", "cpu")

# full-arch sweeps take minutes on CPU; excluded from the fast CI lane
pytestmark = pytest.mark.slow


def _batch_for(cfg, key, b=2, s=32):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(key, (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(cfg, key)
    batch = _batch_for(cfg, key)
    loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, cfg, batch))(params)
    assert jnp.isfinite(loss), (arch, float(loss))
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)), arch
    assert float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init_params(cfg, key)
    b, s, max_len = 2, 16, 48
    batch = _batch_for(cfg, key, b, s)
    state = model.init_decode_state(cfg, b, max_len)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patches"] = batch["patches"]
    if cfg.family == "encdec":
        kwargs["frames"] = batch["frames"]
    logits, state = model.prefill(params, cfg, batch["tokens"], state, **kwargs)
    assert logits.shape[-1] == cfg.padded_vocab, arch
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32) % cfg.vocab
    for _ in range(2):
        logits, state = model.decode_step(params, cfg, state, tok)
        assert logits.shape == (b, 1, cfg.padded_vocab), arch
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32) % cfg.vocab


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_bucketed_prefill_parity(arch):
    """Bucket-padded prefill (serve.py's prompt bucketing) must match the
    unpadded prefill: same last-token logits, same ``pos``, and the state it
    leaves behind decodes identically for the next steps — for EVERY family,
    including the recurrent ones that gate pad steps out of their state."""
    cfg = get_config(arch, reduced=True).replace(remat=False)
    if cfg.n_experts:
        # MoE expert capacity is shape-derived (it scales with the PADDED
        # token count), so parity is exact only when neither run drops
        # tokens to capacity — overflow is lossy no matter the padding
        # (DESIGN.md §14). Give both runs headroom so the comparison tests
        # the padding/masking math, not the drop set.
        cfg = cfg.replace(capacity_factor=4.0)
    model = get_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init_params(cfg, key)
    b, s, pad, max_len = 2, 11, 5, 48
    batch = _batch_for(cfg, key, b, s)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patches"] = batch["patches"]
    if cfg.family == "encdec":
        kwargs["frames"] = batch["frames"]

    state_a = model.init_decode_state(cfg, b, max_len)
    lg_a, state_a = model.prefill(params, cfg, batch["tokens"], state_a, **kwargs)

    padded = jnp.concatenate(
        [batch["tokens"], jnp.zeros((b, pad), jnp.int32)], axis=1
    )
    state_b = model.init_decode_state(cfg, b, max_len)
    lg_b, state_b = model.prefill(
        params, cfg, padded, state_b, length=jnp.full((b,), s, jnp.int32), **kwargs
    )

    assert np.array_equal(np.asarray(state_a["pos"]), np.asarray(state_b["pos"])), arch

    def close(x, y, what):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        err = np.max(np.abs(x - y))
        scale = np.max(np.abs(x)) + 1e-6
        assert err / scale < 0.02, (arch, what, float(err), float(scale))

    close(lg_a, lg_b, "prefill logits")
    tok = jnp.argmax(lg_a[:, -1:], axis=-1).astype(jnp.int32) % cfg.vocab
    for t in range(2):
        lg_a, state_a = model.decode_step(params, cfg, state_a, tok)
        lg_b, state_b = model.decode_step(params, cfg, state_b, tok)
        close(lg_a, lg_b, f"decode step {t}")
        tok = jnp.argmax(lg_a, axis=-1).astype(jnp.int32) % cfg.vocab


@pytest.mark.parametrize(
    "arch", ["qwen2-1.5b", "xlstm-1.3b", "zamba2-1.2b", "whisper-base"]
)
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits must match the parallel forward pass —
    the cache/state machinery is exact, not approximate."""
    cfg = get_config(arch, reduced=True).replace(remat=False)
    model = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init_params(cfg, key)
    b, s = 1, 8
    batch = _batch_for(cfg, key, b, s)
    kwargs = {}
    if cfg.family == "encdec":
        full = model.forward(params, cfg, batch["tokens"], batch["frames"])
        kwargs["frames"] = batch["frames"]
    else:
        full = model.forward(params, cfg, batch["tokens"])
    if isinstance(full, tuple):
        full = full[0]
    # prefill the first token, then teacher-force the rest through decode_step
    state = model.init_decode_state(cfg, b, 2 * s)
    lg0, state = model.prefill(params, cfg, batch["tokens"][:, :1], state, **kwargs)
    logits_steps = [lg0[:, -1]]
    for t in range(1, s):
        lg, state = model.decode_step(params, cfg, state, batch["tokens"][:, t : t + 1])
        logits_steps.append(lg[:, 0])
    stepwise = jnp.stack(logits_steps, axis=1).astype(jnp.float32)
    ref = full.astype(jnp.float32)
    err = jnp.max(jnp.abs(stepwise - ref))
    scale = jnp.max(jnp.abs(ref)) + 1e-6
    assert float(err / scale) < 0.05, (arch, float(err), float(scale))
