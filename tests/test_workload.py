"""Traffic-harness contract: workload determinism, the stream API, and the
SLO metrics surface (docs/serving.md "SLO metrics & traffic harness").

The load harness is only usable as a CI gate if it is *reproducible*: the
same seed must yield the same arrival trace, the same request mix, and —
driven through the engine — the same token streams, in bucketed and ragged
mode alike. The stream tests pin the emission contract the harness measures
through: ``on_token`` fires exactly once per emitted token (preemption and
resume never re-fire), and the ``stream()`` iterator yields the same tokens
the request accumulates.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitizers import lifecycle_checks, page_invariant_checks
from repro.configs import ModelConfig
from repro.launch.metrics import SLO, meets_slo, percentiles, summarize
from repro.launch.serve import ContinuousBatchingEngine, Request, RequestState
from repro.launch.workload import (
    Scenario,
    default_scenarios,
    make_workload,
    poisson_arrivals,
    replay,
)
from repro.models import dense

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(
    name="tiny-wl", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, remat=False,
)


@pytest.fixture(scope="module")
def params():
    return dense.init_params(CFG, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# workload determinism (no engine)
# ---------------------------------------------------------------------------


def test_same_seed_same_workload():
    a = make_workload(11, n_requests=8)
    b = make_workload(11, n_requests=8)
    assert [it.at for it in a.items] == [it.at for it in b.items]
    assert [it.scenario for it in a.items] == [it.scenario for it in b.items]
    for x, y in zip(a.items, b.items):
        assert np.array_equal(x.request.prompt, y.request.prompt)
        assert x.request.max_new == y.request.max_new
        assert x.request.priority == y.request.priority
        assert x.request.deadline_steps == y.request.deadline_steps


def test_different_seed_different_workload():
    a = make_workload(11, n_requests=8)
    b = make_workload(12, n_requests=8)
    assert any(
        not np.array_equal(x.request.prompt, y.request.prompt)
        for x, y in zip(a.items, b.items)
    )


def test_poisson_arrivals_sorted_and_seeded():
    rng = np.random.default_rng(3)
    at = poisson_arrivals(rng, 20, 4.0)
    assert at[0] == 0 and at == sorted(at) and len(at) == 20
    assert poisson_arrivals(np.random.default_rng(3), 20, 4.0) == at
    assert poisson_arrivals(rng, 0, 4.0) == []


def test_trace_replay_arrivals_verbatim():
    trace = [0, 0, 5, 9, 40]
    wl = make_workload(5, n_requests=10, trace=trace,
                       scenarios=[Scenario("s", 1.0, (4, 6), (2, 3))])
    assert [it.at for it in wl.items] == trace  # trace caps the count too


def test_shared_prefix_shared_within_scenario():
    wl = make_workload(2, n_requests=12)
    chat = [it.request for it in wl.items if it.scenario == "chat"]
    pre = default_scenarios()[0].shared_prefix_len
    assert len(chat) >= 2, "chat is half the mix; 12 draws must hit it"
    first = np.asarray(chat[0].prompt[:pre])
    assert all(np.array_equal(np.asarray(r.prompt[:pre]), first) for r in chat)


def test_workload_exercises_lifecycle_knobs():
    wl = make_workload(4, n_requests=16)
    assert {it.request.priority for it in wl.items} == {0, 1, 2}
    assert any(it.request.deadline_steps is not None for it in wl.items)
    by_at = {}
    for it in wl.items:
        by_at.setdefault(it.at, []).append(it.scenario)
    assert any(v.count("burst") >= 3 for v in by_at.values()), \
        "burst scenario must cluster arrivals on one step"


# ---------------------------------------------------------------------------
# replay determinism through the engine
# ---------------------------------------------------------------------------


def _replayed(params, *, seed, ragged):
    eng = ContinuousBatchingEngine(
        CFG, params, batch_slots=3, max_len=96, paged=True, page_size=8,
        preemption=True, ragged=ragged, token_budget=16,
    )
    wl = make_workload(seed, n_requests=5)
    with lifecycle_checks(eng), page_invariant_checks(eng):
        reqs = replay(eng, wl)
    assert all(r.done for r in reqs)
    return eng, reqs


@pytest.mark.parametrize("ragged", [False, True], ids=["bucketed", "ragged"])
def test_replay_same_seed_same_token_streams(params, ragged):
    _, a = _replayed(params, seed=21, ragged=ragged)
    _, b = _replayed(params, seed=21, ragged=ragged)
    assert [r.out for r in a] == [r.out for r in b]
    assert [r.status for r in a] == [r.status for r in b]


def test_replay_records_latency_surface(params):
    eng, reqs = _replayed(params, seed=21, ragged=True)
    lat = eng.latency(slo=SLO(ttft_s=120.0, tpot_s=120.0))
    assert lat["n_requests"] == len(reqs)
    assert lat["n_done"] == sum(r.status == RequestState.DONE for r in reqs)
    for key in ("ttft_ms", "tpot_ms", "e2e_ms"):
        p = lat[key]
        assert p["n"] > 0 and 0 <= p["p50"] <= p["p95"] <= p["p99"] <= p["max"]
    assert lat["queue_depth_max"] >= lat["queue_depth_mean"] >= 0.0
    assert 0.0 <= lat["slo_met_rate"] <= 1.0
    assert lat["prefix_hit_rate"] > 0.0, "chat scenario shares a paged prefix"
    # every request produced a first token, so TTFT is measured for all
    assert lat["ttft_ms"]["n"] == len(reqs)


# ---------------------------------------------------------------------------
# stream API: exactly-once callbacks, iterator contract
# ---------------------------------------------------------------------------


def test_stream_iterator_yields_emitted_tokens(params):
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64)
    req = Request(jnp.arange(1, 9, dtype=jnp.int32), max_new=6)
    got = []
    for tok in eng.stream(req):
        got.append(tok)
        assert req.t_first_token is not None, "TTFT stamped by first yield"
    assert got == req.out and len(got) == 6 and req.done
    assert len(req.token_times) == 6


def test_stream_callbacks_exactly_once_under_chaos(params):
    """A preemption-heavy randomized schedule (tiny page pool, priority mix,
    one mid-flight cancel) where every request streams via ``on_token``:
    each callback fires exactly once per emitted token, in emission order —
    preempt + resume must not replay the already-emitted half."""
    # 3-page pool, ~2 pages per request: admitting a higher-priority arrival
    # REQUIRES preempting the low-priority resident (test_chaos recipe)
    eng = ContinuousBatchingEngine(
        CFG, params, batch_slots=2, max_len=64, paged=True, page_size=16,
        n_pages=3, preemption=True, ragged=True, token_budget=16,
    )
    rng = np.random.default_rng(9)
    seen: dict[str, list[int]] = {}

    def on_token(req, tok):
        seen.setdefault(req.request_id, []).append(tok)

    reqs = [
        Request(
            rng.integers(1, 200, size=int(rng.integers(16, 24)), dtype=np.int32),
            max_new=int(rng.integers(3, 8)),
            priority=i % 3,  # arrival order ramps priority: preempt pressure
            request_id=f"r{i}",
            on_token=on_token,
        )
        for i in range(8)
    ]
    cancelled = reqs[5]
    with lifecycle_checks(eng), page_invariant_checks(eng):
        eng.submit(reqs[0])
        for _ in range(4):  # let the low-priority resident make progress
            eng.step()
        for r in reqs[1:]:
            eng.submit(r)
            if rng.random() < 0.5:
                eng.step()
        eng.cancel(cancelled)
        eng.run_until_done()
    assert any(r._preemptions > 0 for r in reqs), "schedule must preempt"
    for r in reqs:
        assert seen.get(r.request_id, []) == r.out, \
            f"{r.request_id}: callback trace diverged from emitted tokens"
        assert len(r.token_times) == len(r.out)


def test_raising_callback_detached_not_fatal(params):
    eng = ContinuousBatchingEngine(CFG, params, batch_slots=2, max_len=64)
    calls = []

    def bad(req, tok):
        calls.append(tok)
        raise RuntimeError("hostile consumer")

    req = Request(jnp.arange(1, 7, dtype=jnp.int32), max_new=5, on_token=bad)
    with pytest.warns(UserWarning, match="callback detached"):
        eng.serve([req])
    assert req.done and req.status == RequestState.DONE
    assert len(req.out) == 5 and calls == req.out[:1]
    assert req.on_token is None


# ---------------------------------------------------------------------------
# metrics unit surface (no engine)
# ---------------------------------------------------------------------------


def test_percentiles_empty_is_zero_shaped():
    p = percentiles([])
    assert p == {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0, "n": 0}


def _stamped(status=RequestState.DONE, ttft=0.1, gaps=(0.01, 0.01)):
    req = Request(np.arange(4, dtype=np.int32), status=status, done=True)
    req.t_submit = 100.0
    req.t_first_token = 100.0 + ttft
    req.token_times = list(100.0 + ttft + np.cumsum((0.0,) + tuple(gaps)))
    req.out = [1] * len(req.token_times)
    req.t_done = req.token_times[-1]
    return req


def test_meets_slo_bounds():
    slo = SLO(ttft_s=0.5, tpot_s=0.05)
    assert meets_slo(_stamped(), slo)
    assert not meets_slo(_stamped(ttft=0.9), slo), "TTFT over budget"
    assert not meets_slo(_stamped(gaps=(0.2, 0.2)), slo), "TPOT over budget"
    assert not meets_slo(_stamped(status=RequestState.FAILED), slo), \
        "a failed request never meets the SLO"


def test_summarize_goodput_counts_only_slo_met_tokens():
    fast, slow = _stamped(), _stamped(ttft=0.9)
    out = summarize([fast, slow], slo=SLO(ttft_s=0.5, tpot_s=0.05),
                    queue_depths=[0, 2, 1], stats={"requests_preempted": 1})
    assert out["n_done"] == 2 and out["n_slo_met"] == 1
    assert out["slo_met_rate"] == 0.5 and out["preemption_rate"] == 0.5
    span = max(fast.t_done, slow.t_done) - 100.0
    assert out["goodput_tok_s"] == pytest.approx(len(fast.out) / span)
    assert out["queue_depth_mean"] == 1.0 and out["queue_depth_max"] == 2
    # slo=None keeps the shape but degenerates to completion throughput
    raw = summarize([fast, slow])
    assert raw["slo"] is None and raw["n_slo_met"] == 2
