"""In-kernel paged decode attention + speculative verification tests.

Three layers, mirroring tests/test_ragged_engine.py:

* **kernel** — the block-table paged decode kernel (interpret mode) agrees
  with its jnp oracle on a mixed-occupancy batch, the fused tail-page
  commit writes the pools bit-identically to the reference scatter, and a
  stacked draft panel is row-for-row bit-identical to running the same
  rows sequentially (the property that makes greedy acceptance exact);
* **dispatch** — kind ``paged_decode`` is recorded with kernel / ref /
  ref[forced] counters and the documented ref reason codes (``rows``,
  ``hd_unaligned``);
* **engine** — a speculative serving engine (every decode launch stacks
  ``spec_k`` candidate rows per slot) is token-for-token identical to the
  non-speculative solo oracle for greedy, sampled, and cache-truncated
  requests; the whole lifetime compiles exactly ONE (batch, spec_k)-shaped
  decode executable; and misconfiguration warns or raises instead of
  silently serving wrong.

Plus the ``max_chunk_share`` decode-priority knob for the ragged engine: a
long-prompt flood capped to a fraction of the token budget must stretch
admission over more steps without costing steady decoders their cadence.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.sanitizers import (
    assert_compile_budget,
    no_recompiles,
    page_invariant_checks,
)
from repro.configs import ModelConfig
from repro.kernels import dispatch
from repro.kernels.paged_attention import paged_decode_kernel, paged_decode_ref
from repro.launch.serve import (
    ContinuousBatchingEngine,
    Request,
    SamplingParams,
    _ngram_draft,
)
from repro.models import dense, olmoe

jax.config.update("jax_platform_name", "cpu")

DCFG = ModelConfig(
    name="tiny-paged", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, remat=False,
)
MCFG = ModelConfig(
    name="tiny-paged-moe", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, vocab=256, remat=False,
    n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=4.0,
)


@pytest.fixture(scope="module")
def dparams():
    return dense.init_params(DCFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mparams():
    return olmoe.init_params(MCFG, jax.random.PRNGKey(1))


def _prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 200, size=n).tolist() for n in lens]


def _solo(cfg, params, prompt, max_new=6, max_len=64):
    """Non-speculative bucketed solo serving: the token-equality oracle."""
    eng = ContinuousBatchingEngine(cfg, params, batch_slots=1, max_len=max_len)
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=max_new)
    eng.serve([req])
    assert req.done
    return req


def _spec_engine(cfg, params, max_len=64, **kw):
    return ContinuousBatchingEngine(
        cfg, params, batch_slots=2, max_len=max_len, paged=True, page_size=8,
        n_pages=24, speculation=True, spec_k=4, **kw,
    )


def _spec_batch(seed=3, sq=4, hd=16):
    """A mixed-occupancy speculative launch: slot 0 mid-sequence with its
    draft span straddling a page boundary, slot 1 early, slot 2 cold (pos 0,
    all rows in the first page). Every slot's tail pages are mapped — the
    commit-mode contract."""
    rng = np.random.default_rng(seed)
    B, maxp, page, KV, H = 3, 4, 8, 2, 4
    P = B * maxp

    def f(*s):
        return jnp.asarray(rng.standard_normal(s), jnp.bfloat16)

    q, kt, vt = f(B, sq, H, hd), f(B, sq, KV, hd), f(B, sq, KV, hd)
    kp, vp = f(P, page, KV, hd), f(P, page, KV, hd)
    pos = np.array([13, 5, 0], np.int32)
    perm = rng.permutation(P)
    bt = np.full((B, maxp), -1, np.int32)
    for b in range(B):
        n_pg = (int(pos[b]) + sq - 1) // page + 1  # prefix + draft span
        bt[b, :n_pg] = perm[b * maxp : b * maxp + n_pg]
    return (q, kp, vp, kt, vt, jnp.asarray(bt), jnp.asarray(pos))


def _f32(x):
    return np.asarray(x, np.float32)


# ---------------------------------------------------------------------------
# kernel vs reference
# ---------------------------------------------------------------------------


def test_paged_kernel_matches_ref_interpret():
    """Pallas kernel (interpret mode) vs jnp oracle, attention output AND
    the fused tail-page commit. The kernel accumulates fused-f32 while the
    ref rounds split-bf16 per row, so the output agrees to bf16 tolerance —
    but the committed pool rows are plain bf16 casts both ways, so the
    pools must match bit for bit."""
    args = _spec_batch()
    out_r, kp_r, vp_r = paged_decode_ref(*args, commit=True)
    out_k, kp_k, vp_k = paged_decode_kernel(*args, commit=True, interpret=True)
    np.testing.assert_allclose(_f32(out_k), _f32(out_r), atol=0.03, rtol=0.05)
    np.testing.assert_array_equal(_f32(kp_k), _f32(kp_r))
    np.testing.assert_array_equal(_f32(vp_k), _f32(vp_r))


def test_paged_kernel_matches_ref_no_commit():
    args = _spec_batch(seed=5)
    out_r = paged_decode_ref(*args, commit=False)
    out_k = paged_decode_kernel(*args, commit=False, interpret=True)
    np.testing.assert_allclose(_f32(out_k), _f32(out_r), atol=0.03, rtol=0.05)


def test_stacked_rows_bit_identical_to_sequential():
    """Row ``i`` of a stacked draft launch must equal the output a
    sequential engine would produce at position ``pos + i`` — bitwise. This
    is the property that makes greedy speculative acceptance exact: the
    verification logits ARE the sequential logits, not an approximation."""
    q, kp, vp, kt, vt, bt, pos = _spec_batch(seed=7)
    stacked = paged_decode_ref(q, kp, vp, kt, vt, bt, pos, commit=False)
    kp_s, vp_s, outs = kp, vp, []
    for i in range(q.shape[1]):
        o, kp_s, vp_s = paged_decode_ref(
            q[:, i : i + 1], kp_s, vp_s, kt[:, i : i + 1], vt[:, i : i + 1],
            bt, pos + i, commit=True,
        )
        outs.append(o)
    np.testing.assert_array_equal(
        _f32(stacked), _f32(jnp.concatenate(outs, axis=1))
    )


# ---------------------------------------------------------------------------
# dispatch routing
# ---------------------------------------------------------------------------


def test_dispatch_records_paged_decode_kind():
    args = _spec_batch()
    dispatch.reset_dispatch_counters()
    dispatch.paged_decode(*args, commit=False)
    dispatch.paged_decode(*args, commit=False, impl="ref")
    c = dispatch.dispatch_counters()
    assert c.get("paged_decode/kernel") == 1, c
    assert c.get("paged_decode/ref") == 1 and c.get("paged_decode/ref[forced]") == 1, c


def test_dispatch_ref_reason_codes():
    """Unroutable shapes fall back loudly with the documented reason codes:
    a draft stack past DECODE_M_MAX routes ``ref[rows]``, a lane-untileable
    head dim routes ``ref[hd_unaligned]`` — and both still execute (the jnp
    oracle has no shape restrictions)."""
    from repro.kernels.autotune import DECODE_M_MAX

    dispatch.reset_dispatch_counters()
    deep = _spec_batch(sq=DECODE_M_MAX + 1)
    dispatch.paged_decode(*deep, commit=False)
    odd = _spec_batch(hd=12)
    dispatch.paged_decode(*odd, commit=False)
    c = dispatch.dispatch_counters()
    assert c.get("paged_decode/ref[rows]") == 1, c
    assert c.get("paged_decode/ref[hd_unaligned]") == 1, c


# ---------------------------------------------------------------------------
# n-gram self-draft
# ---------------------------------------------------------------------------


def test_ngram_draft_continues_repeats():
    # history ends in a loop: the draft replays the continuation of the
    # previous occurrence of the trailing trigram
    hist = [5, 6, 7, 8, 5, 6, 7]
    assert _ngram_draft(hist, 3) == [8, 5, 6]
    # no structure: repeat the last token; empty history: zeros
    assert _ngram_draft([9], 2) == [9, 9]
    assert _ngram_draft([], 2) == [0, 0]


# ---------------------------------------------------------------------------
# the acceptance bar: speculative serving == non-speculative oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_speculative_greedy_token_equality(family, dparams, mparams):
    """Greedy speculative decoding must be bit-identical to solo serving:
    drafts only ever shortcut steps the oracle would have taken anyway. The
    lifetime compiles ONE (batch, spec_k)-shaped decode executable, routes
    the paged_decode kind, and leaks no pages across rollbacks."""
    cfg, params = (DCFG, dparams) if family == "dense" else (MCFG, mparams)
    prompts = _prompts((5, 23, 17, 9), seed=1)
    oracles = [_solo(cfg, params, p, max_new=24).out for p in prompts]
    eng = _spec_engine(cfg, params)
    reqs = [Request(jnp.asarray(p, jnp.int32), max_new=24) for p in prompts]
    with page_invariant_checks(eng):
        eng.serve(reqs)
    for k, (r, o) in enumerate(zip(reqs, oracles)):
        assert r.out == o, (k, r.out, o)
    th = eng.throughput()
    # the decode path is the in-kernel block-table route, not a dense view
    assert th["routing"].get("paged_decode/kernel", 0) >= 1, th["routing"]
    assert 0.0 <= th["acceptance_rate"] <= 1.0
    assert th["tokens_per_step"] >= 1.0
    cs = assert_compile_budget(eng)
    assert cs["spec_traces"] == 1 and cs["decode_traces"] == 0, cs


def test_speculative_sampled_slots_keep_rng_stream(dparams):
    """temperature > 0 slots commit only the sampled token per launch, so
    their random streams — and therefore their outputs — are exactly the
    non-speculative ones, even sharing launches with greedy slots."""
    prompts = _prompts((7, 12), seed=4)
    sp = SamplingParams(temperature=1.0, top_k=20, seed=42)
    oracle_g = _solo(DCFG, dparams, prompts[0], max_new=12).out
    eng1 = ContinuousBatchingEngine(DCFG, dparams, batch_slots=1, max_len=64)
    oracle_s = Request(jnp.asarray(prompts[1], jnp.int32), max_new=12, sampling=sp)
    eng1.serve([oracle_s])
    eng = _spec_engine(DCFG, dparams)
    greedy = Request(jnp.asarray(prompts[0], jnp.int32), max_new=12)
    sampled = Request(jnp.asarray(prompts[1], jnp.int32), max_new=12, sampling=sp)
    eng.serve([greedy, sampled])
    assert greedy.out == oracle_g
    assert sampled.out == oracle_s.out


def test_speculative_truncation_matches_oracle(dparams):
    """A request that hits cache capacity mid-draft exits with the same
    tokens and the same ``truncated`` flag as the oracle: acceptance is
    capped at the cache rows left, and the final past-capacity token is
    still sampled before the exit (the non-speculative order)."""
    (prompt,) = _prompts([24], seed=6)
    oracle = _solo(DCFG, dparams, prompt, max_new=20, max_len=32)
    assert oracle.truncated  # the workload must actually exercise the cap
    eng = _spec_engine(DCFG, dparams, max_len=32)
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=20)
    eng.serve([req])
    assert req.out == oracle.out
    assert req.truncated == oracle.truncated


def test_spec_single_trace_no_recompiles(dparams):
    """After the first speculative launch traces, every later admission mix
    reuses the one (batch, spec_k)-shaped executable."""
    eng = _spec_engine(DCFG, dparams)
    eng.serve([Request(jnp.asarray(p, jnp.int32), max_new=8)
               for p in _prompts((5, 9), seed=7)])
    with no_recompiles(eng):
        eng.serve([Request(jnp.asarray(p, jnp.int32), max_new=8)
                   for p in _prompts((11, 4), seed=8)])
    assert assert_compile_budget(eng)["spec_traces"] == 1


def test_draft_fn_hook_cannot_crash_the_engine(dparams):
    """An installed draft hook's proposals are clamped into the vocab: a
    sloppy draft model can only lower the acceptance rate, never poison the
    embed gather or the outputs."""
    (prompt,) = _prompts([9], seed=9)
    oracle = _solo(DCFG, dparams, prompt, max_new=10).out
    eng = ContinuousBatchingEngine(
        DCFG, dparams, batch_slots=2, max_len=64, paged=True, page_size=8,
        n_pages=24, speculation=True, spec_k=4,
        draft_fn=lambda req, k: [10**9, -5, 3],
    )
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=10)
    eng.serve([req])
    assert req.out == oracle


# ---------------------------------------------------------------------------
# loud failure modes
# ---------------------------------------------------------------------------


def test_speculation_without_paged_falls_back_with_warning(dparams):
    with pytest.warns(UserWarning, match="speculation"):
        eng = ContinuousBatchingEngine(
            DCFG, dparams, batch_slots=2, max_len=64, speculation=True
        )
    assert not eng.speculation
    (prompt,) = _prompts([7])
    req = Request(jnp.asarray(prompt, jnp.int32), max_new=4)
    eng.serve([req])
    assert req.out == _solo(DCFG, dparams, prompt, max_new=4).out


def test_spec_k_validation(dparams):
    """spec_k outside [2, DECODE_M_MAX] is a constructor error — the kernel
    cannot verify more rows than its panel bound, and k=1 is non-spec."""
    for bad_k in (1, 99):
        with pytest.raises(ValueError, match="spec_k"):
            ContinuousBatchingEngine(
                DCFG, dparams, batch_slots=2, max_len=64, paged=True,
                page_size=8, n_pages=24, speculation=True, spec_k=bad_k,
            )


def test_max_chunk_share_validation(dparams):
    for bad in (0.0, 1.5):
        with pytest.raises(ValueError, match="max_chunk_share"):
            ContinuousBatchingEngine(
                DCFG, dparams, batch_slots=2, max_len=64, paged=True,
                ragged=True, token_budget=16, max_chunk_share=bad,
            )


# ---------------------------------------------------------------------------
# max_chunk_share: decode cadence under a capped long-prompt flood
# ---------------------------------------------------------------------------


def test_max_chunk_share_keeps_decode_cadence(dparams):
    """Cap prompt chunks at a quarter of the budget: the 40-token flood now
    takes ~10 admission steps instead of ~3, but every step still decodes
    BOTH steady slots, no step schedules more chunk rows than the cap, and
    the flooding request's output is still oracle-identical."""
    eng = ContinuousBatchingEngine(
        DCFG, dparams, batch_slots=3, max_len=64, paged=True,
        ragged=True, token_budget=16, max_chunk_share=0.25,
    )
    cap = max(1, int(16 * 0.25))
    steady = [Request(jnp.asarray([7 + k, 11, 13], jnp.int32), max_new=30)
              for k in range(2)]
    for r in steady:
        eng.submit(r)
    for _ in range(4):  # 6 steady prompt tokens through a 4-token cap
        if all(r._last_logits is not None for r in steady):
            break
        eng.step()
    assert all(r._last_logits is not None for r in steady)
    (long_prompt,) = _prompts([40], seed=2)
    burst = Request(jnp.asarray(long_prompt, jnp.int32), max_new=4)
    eng.submit(burst)
    deltas, chunk_rows = [], []
    while burst._last_logits is None:
        before_d = eng.stats["decode_tokens"]
        before_p = eng.stats["prefill_tokens"]
        eng.step()
        deltas.append(eng.stats["decode_tokens"] - before_d)
        chunk_rows.append(eng.stats["prefill_tokens"] - before_p)
    assert len(deltas) >= 10, deltas  # 40 tokens / 4-token cap
    assert all(d == 2 for d in deltas), deltas
    assert all(c <= cap for c in chunk_rows), chunk_rows
    eng.run_until_done()
    assert burst.out == _solo(DCFG, dparams, long_prompt, max_new=4).out
