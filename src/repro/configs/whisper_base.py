"""Whisper-base — enc-dec, 6+6L d=512 8H d_ff=2048 vocab=51865.
[arXiv:2212.04356]

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (n_frames=1500, d_model) for the
encoder. The decoder is a standard causal transformer with cross-attention.
"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,  # decoder layers
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab=51865,
    n_frames=1500,
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
)

REDUCED = FULL.replace(
    n_layers=2, n_enc_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=256, vocab=512, n_frames=32,
)

register(FULL, REDUCED)
