"""Zamba2-1.2B — 38 Mamba2 blocks d=2048 (ssm_state=64) + a shared full
attention/MLP block (32H, d_ff=8192) invoked periodically with the Zamba
concat re-injection. [arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B]

Hybrid: runs the long_500k shape (SSM state is O(1); the shared attention
blocks use a KV-sequence-sharded cache at 500k decode).
"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="zamba2-1.2b",
    family="mamba_hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,  # shared attention block: 2*d_model concat input, 64-dim heads
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    shared_attn_every=6,
)

REDUCED = FULL.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=64, d_ff=256,
    vocab=512, ssm_state=16, ssm_head_dim=32, shared_attn_every=2,
)

register(FULL, REDUCED)
