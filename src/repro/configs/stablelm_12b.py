"""StableLM-2-12B — 40L d=5120 32H (kv=8) d_ff=13824 vocab=100352, partial RoPE.
[hf:stabilityai/stablelm-2-12b]"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    rope_fraction=0.25,  # stablelm-2 rotary_percent
    rope_theta=10000.0,
)

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=512
)

register(FULL, REDUCED)
