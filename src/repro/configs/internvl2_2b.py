"""InternVL2-2B — InternLM2-1.8B language backbone + InternViT frontend.
[arXiv:2404.16821; hf:OpenGVLab/InternVL2-2B]

Per the assignment, the ViT frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (n_patches, d_model) that the backbone prepends
to the token embeddings. vocab=92553 (padded to 92672 at the head).
"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    n_patches=256,  # 448x448 image, patch 28 -> 256 patch embeddings
    rope_theta=1000000.0,
)

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
    vocab=512, n_patches=16,
)

register(FULL, REDUCED)
