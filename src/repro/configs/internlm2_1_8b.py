"""InternLM2-1.8B — 24L d=2048 16H (kv=8) d_ff=8192 vocab=92544, GQA.
[arXiv:2403.17297; hf:internlm/internlm2-1_8b]"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92544,
    rope_theta=1000000.0,
)

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=512
)

register(FULL, REDUCED)
