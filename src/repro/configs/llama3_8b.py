"""LLaMA3-8B — the paper's primary evaluation model (Table 1, 6, 7).
32L d=4096 32H (kv=8) d_ff=14336 vocab=128256."""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    rope_theta=500000.0,
)

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=512
)

register(FULL, REDUCED)
