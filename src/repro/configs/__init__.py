"""Model/arch configuration system.

One ``<arch>.py`` per assigned architecture registers a :class:`ModelConfig`
via :func:`register`. ``get_config(name)`` returns the full-scale config;
``get_config(name, reduced=True)`` returns the family-preserving smoke-test
reduction (small width/depth/experts, same code paths).

Quantization is a first-class config: ``quant`` selects the serving
precision (the paper's W4A4/W4A8 TwinQuant modes, W4A16, or bf16) and its
rank/group hyper-parameters.
"""

from __future__ import annotations

import dataclasses
import importlib

__all__ = ["ModelConfig", "QuantSpec", "register", "get_config", "list_configs", "ARCH_IDS"]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Serving-precision selection (paper §5 settings)."""

    mode: str = "bf16"  # bf16 | w4a16 | w4a8 | w4a4
    rank: int = 128  # low-rank branch rank r (paper default)
    group_size: int = 128  # quantization group (paper default)

    @property
    def a_bits(self) -> int:
        return {"bf16": 16, "w4a16": 16, "w4a8": 8, "w4a4": 4}[self.mode]

    @property
    def w_bits(self) -> int:
        return 16 if self.mode == "bf16" else 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = ""
    family: str = "dense"  # dense | moe | mla_moe | encdec | xlstm | mamba_hybrid | vlm
    # transformer core
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # fraction of head_dim that is rotated
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0  # leading dense layers (DeepSeek-V3 style)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # MLA (DeepSeek-V3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False  # multi-token-prediction aux layer+head
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    n_frames: int = 0  # encoder frontend stub: precomputed frame embeddings
    # VLM (internvl2): frontend stub provides patch embeddings
    n_patches: int = 0
    # xLSTM
    slstm_every: int = 0  # every k-th block is sLSTM (0 = pure mLSTM)
    xlstm_proj_factor: float = 2.0
    # Mamba2 / hybrid (zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    shared_attn_every: int = 0  # zamba2: shared attention block cadence
    # quantization / serving
    quant: QuantSpec = QuantSpec()
    # training
    dtype: str = "bfloat16"
    remat: bool = True

    # ---------------- derived ----------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (TP- and kernel-friendly)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k context? (assignment's long_500k rule)"""
        return self.family in ("xlstm", "mamba_hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # no encoder-only archs in the assignment

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def active_params(self) -> int:
        """Dense-equivalent active parameter count (for MODEL_FLOPS=6·N_active·D)."""
        from repro.models.registry import count_active_params

        return count_active_params(self)

    def total_params(self) -> int:
        from repro.models.registry import count_total_params

        return count_total_params(self)


_REGISTRY: dict[str, ModelConfig] = {}
_REDUCED: dict[str, ModelConfig] = {}

ARCH_IDS = [
    "olmoe-1b-7b",
    "deepseek-v3-671b",
    "qwen2-1.5b",
    "stablelm-12b",
    "phi4-mini-3.8b",
    "internlm2-1.8b",
    "internvl2-2b",
    "whisper-base",
    "xlstm-1.3b",
    "zamba2-1.2b",
    # the paper's own evaluation models
    "llama3-8b",
    "qwen3-8b",
]

_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen2-1.5b": "qwen2_1_5b",
    "stablelm-12b": "stablelm_12b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "internlm2-1.8b": "internlm2_1_8b",
    "internvl2-2b": "internvl2_2b",
    "whisper-base": "whisper_base",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "llama3-8b": "llama3_8b",
    "qwen3-8b": "qwen3_8b",
}


def register(full: ModelConfig, reduced: ModelConfig) -> None:
    _REGISTRY[full.name] = full
    _REDUCED[full.name] = reduced


def get_config(name: str, reduced: bool = False, **overrides) -> ModelConfig:
    if name not in _REGISTRY:
        if name not in _MODULES:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
        importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = (_REDUCED if reduced else _REGISTRY)[name]
    return cfg.replace(**overrides) if overrides else cfg


def list_configs() -> list[str]:
    return list(ARCH_IDS)
