"""xLSTM-1.3B — 48 blocks d=2048, mLSTM (4 heads) with periodic sLSTM blocks.
[arXiv:2405.04517]

d_ff=0 per the assignment: blocks carry their own up/down projections
(proj_factor 2 for mLSTM). Sub-quadratic: runs the long_500k shape.
"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,  # d_inner(4096) / heads(4) after proj_factor 2 — per-block
    d_ff=0,
    vocab=50304,
    slstm_every=8,  # every 8th block is sLSTM (7:1 mLSTM:sLSTM, paper's ratio)
    xlstm_proj_factor=2.0,
)

REDUCED = FULL.replace(
    n_layers=4, d_model=128, n_heads=2, n_kv_heads=2, head_dim=128,
    vocab=512, slstm_every=2,
)

register(FULL, REDUCED)
