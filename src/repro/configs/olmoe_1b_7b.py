"""OLMoE-1B-7B — 16L d=2048 16H (kv=16) MoE 64 experts top-8, d_ff_expert=1024.
[arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924]"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,  # unused for MoE layers; kept for reporting
    d_ff_expert=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    rope_theta=10000.0,
)

REDUCED = FULL.replace(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=64,
    d_ff_expert=64,
    vocab=512,
    n_experts=8,
    top_k=2,
)

register(FULL, REDUCED)
