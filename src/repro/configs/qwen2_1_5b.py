"""Qwen2-1.5B — 28L d=1536 12H (kv=2) d_ff=8960 vocab=151936, GQA + QKV bias.
[arXiv:2407.10671; hf:Qwen/Qwen2-1.5B]"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
)

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=512
)

register(FULL, REDUCED)
