"""Qwen3-8B — the paper's second evaluation family (Table 1).
36L d=4096 32H (kv=8) d_ff=12288 vocab=151936."""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab=151936,
    rope_theta=1000000.0,
)

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=512
)

register(FULL, REDUCED)
