"""DeepSeek-V3-671B — 61L d=7168, MLA (128 heads), 1 shared + 256 routed
experts top-8, d_ff_expert=2048, MTP. [arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3]

Faithful structural details kept: first 3 layers dense (d_ff=18432), MLA with
q_lora=1536 / kv_lora=512 / qk_nope=128 / qk_rope=64 / v=128, MTP flag.
"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="deepseek-v3-671b",
    family="mla_moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,  # v head dim; attention q/k use nope+rope dims below
    d_ff=18432,  # dense layers (first_k_dense)
    d_ff_expert=2048,
    vocab=129280,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    first_k_dense=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp=True,
    rope_theta=10000.0,
    capacity_factor=1.25,
)

REDUCED = FULL.replace(
    n_layers=3,  # 1 dense + 2 MoE (first_k_dense=1)
    first_k_dense=1,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=512,
    d_ff_expert=128,
    vocab=512,
    n_experts=8,
    top_k=2,
    n_shared_experts=1,
    q_lora_rank=64,
    kv_lora_rank=32,
    qk_nope_dim=32,
    qk_rope_dim=16,
    v_head_dim=32,
    mtp=True,
)

register(FULL, REDUCED)
