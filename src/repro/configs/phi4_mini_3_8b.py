"""Phi-4-mini-3.8B — 32L d=3072 24H (kv=8) d_ff=8192 vocab=200064,
RoPE (partial) + SwiGLU + GQA. [arXiv:2412.08905; hf:microsoft/Phi-4-mini-instruct]"""

from repro.configs import ModelConfig, register

FULL = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=200064,
    rope_fraction=0.75,  # phi4-mini partial_rotary_factor
    rope_theta=10000.0,
    tie_embeddings=True,
)

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=512
)

register(FULL, REDUCED)
