"""Roofline term derivation from compiled artifacts (assignment §ROOFLINE).

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * LINK_BW)

IMPORTANT accounting note (verified empirically, see EXPERIMENTS.md §Dry-run):
``compiled.cost_analysis()`` on an SPMD-partitioned module reports the cost
of the PER-DEVICE program (the HLO module is the per-partition program), and
the shapes appearing in its collective ops are per-device payloads. So the
terms below use per-device numbers directly — dividing whole-program numbers
by chips (the assignment's formula) and using per-device numbers are the
same quantity. MODEL_FLOPS is global and is divided by chips when compared.

collective_bytes: for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute we count the RESULT bytes of the op (the
payload a device moves through its ICI links, up to the O(1) ring factor
(g-1)/g ≈ 1).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "s2": 0.25, "u2": 0.25,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9\[\]{},: ]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind from optimized HLO text."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        shape_str = m.group(1) or m.group(2) or ""
        kind = m.group(3)
        b = _shape_bytes(shape_str)
        # -start/-done pairs would double-count; only count -start or bare
        span_txt = hlo_text[m.start():m.end()]
        if "-done(" in span_txt:
            continue
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device (cost_analysis of the per-partition module)
    hbm_bytes: float  # per-device
    coll_bytes: float  # per-device
    chips: int
    model_flops: Optional[float] = None  # GLOBAL 6·N·D / 2·N·tokens

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if not self.model_flops or not self.flops:
            return None
        return (self.model_flops / self.chips) / self.flops

    @property
    def roofline_fraction(self) -> Optional[float]:
        """MFU upper bound: time the model-FLOPs would take at peak, over the
        roofline-bound step time. This is the §Perf score per cell."""
        if not self.model_flops:
            return None
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / self.bound_time

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(compiled, chips: int, model_flops: Optional[float] = None) -> Roofline:
    """Roofline from the compiled artifact, using the trip-count-aware HLO
    cost model (launch/hlo_cost.py) — XLA:CPU's cost_analysis undercounts
    while-loop bodies (counted once, not x trips)."""
    from repro.launch.hlo_cost import analyze_hlo

    hc = analyze_hlo(compiled.as_text())
    return Roofline(flops=hc["flops"], hbm_bytes=hc["bytes"],
                    coll_bytes=hc["coll_bytes"], chips=chips,
                    model_flops=model_flops)
