"""Serving launcher: prefill/decode step construction + a batched-request
serving loop (continuous-batching-style slot management).

The decode step is the function the ``decode_*`` / ``long_*`` dry-run cells
lower; the ``Server`` class is the runnable end-to-end driver used by
examples/serve_quantized.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.registry import get_model


def make_prefill_step(cfg: ModelConfig) -> Callable:
    model = get_model(cfg)

    def prefill_step(params, tokens, state, **frontend):
        return model.prefill(params, cfg, tokens, state, **frontend)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    model = get_model(cfg)

    def decode_step(params, state, tokens):
        return model.decode_step(params, cfg, state, tokens)

    return decode_step


@dataclasses.dataclass
class Request:
    prompt: jax.Array  # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Minimal batched serving loop: static batch of slots, greedy sampling.

    Requests are admitted into free slots; all slots decode in lock-step (the
    TPU-efficient layout); finished requests free their slot. Per-slot
    positions are tracked so prompts of different lengths coexist.
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4, max_len: int = 128):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.batch = batch_slots
        self.max_len = max_len
        self.state = self.model.init_decode_state(cfg, batch_slots, max_len)
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self._decode = jax.jit(
            lambda p, st, t: self.model.decode_step(p, cfg, st, t)
        )

    def submit(self, req: Request) -> bool:
        """Admit into a free slot; prefill its prompt via per-slot decode."""
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                # feed the prompt token-by-token through the shared decode
                # step (slot-local prefill; cache positions are global-step
                # aligned, so prompts are left-padded into the timeline)
                for t in range(req.prompt.shape[0]):
                    tok = jnp.zeros((self.batch, 1), jnp.int32)
                    tok = tok.at[i, 0].set(req.prompt[t])
                    logits, self.state = self._decode(self.params, self.state, tok)
                req._last_logits = logits[i, -1]
                return True
        return False

    def step(self) -> int:
        """One lock-step decode for all active slots; returns #active."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        tok = jnp.zeros((self.batch, 1), jnp.int32)
        for i in active:
            req = self.slots[i]
            nxt = int(jnp.argmax(req._last_logits)) % self.cfg.vocab
            req.out.append(nxt)
            tok = tok.at[i, 0].set(nxt)
        logits, self.state = self._decode(self.params, self.state, tok)
        for i in active:
            req = self.slots[i]
            req._last_logits = logits[i, -1]
            if len(req.out) >= req.max_new or int(self.state["pos"]) >= self.max_len - 1:
                req.done = True
                self.slots[i] = None
        return len(active)

    def run_until_done(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            if self.step() == 0:
                return
