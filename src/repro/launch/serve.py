"""Serving launcher: prefill/decode step construction + a continuous-batching
serving engine built on per-slot cache state, with an optional PAGED KV-cache
runtime (block-table attention, page allocator, prompt-prefix cache).

The decode step is the function the ``decode_*`` / ``long_*`` dry-run cells
lower; :class:`ContinuousBatchingEngine` is the runnable end-to-end driver
used by examples/serve_quantized.py and benchmarks/bench_throughput.py.

Engine architecture (DESIGN.md §10, §14):

* Every decode state carries a **per-slot position vector** ``pos (B,)`` —
  each batch slot is an independent timeline, so requests of different
  lengths decode in lock-step without sharing a global step counter.
* **Admission** runs the model's real prefill once on a batch-1 state (one
  batched pass over the whole prompt, not T decode steps). Prompts are
  padded to power-of-two **buckets** (compile count O(log S_max), not
  O(distinct lengths)); the model's ``length`` kwarg keeps the padded math
  exact and ``compile_stats()`` reports the trace inventory.
* The slot axis of every state leaf is inferred structurally (batch-2 vs
  batch-1 ``eval_shape`` diff), so the same engine serves KV-cache
  transformers, MLA latent caches, SSM/xLSTM recurrent states, and hybrid
  stacks without per-family splice code.
* **Paged mode** (``paged=True``): sequence-carrying cache leaves live in a
  global page pool shared by all slots (``models.common.init_paged_state``);
  a host-side :class:`PageAllocator` owns the free list and refcounts,
  admission is gated on free PAGES (not just free slots), eviction returns
  pages, and a :class:`PrefixCache` maps shared prompt prefixes (hashed at
  page granularity) into new slots copy-free so only the suffix re-prefills.
  The dense per-slot layout stays alive behind the flag as the A/B and
  correctness oracle.
* **Eviction** is host bookkeeping plus (paged) page release: a finished
  request frees its slot and pages; stale device state is invisible behind
  the per-slot mask / unmapped block-table rows. A request stopped by cache
  capacity before producing ``max_new`` tokens is flagged ``truncated``.
* Sampling is per-request (greedy / temperature / top-k) on the host.
* **Ragged mode** (``ragged=True``, requires paged + a family with
  ``ragged_step``): chunked prefill and decode are unified into ONE launch
  per engine step over a flat token batch capped at ``token_budget`` —
  decode latency stays flat while long prompts stream in, and the prefill
  bucket inventory collapses to a single token-budget trace. See
  docs/serving.md for the full lifecycle.
* **Request lifecycle** is an explicit state machine (:class:`RequestState`:
  ``QUEUED -> PREFILL -> DECODE -> {DONE, FAILED, CANCELLED, TIMED_OUT,
  PREEMPTED}``) with per-request error capture (``req.error`` holds a
  machine-readable reason code), ``engine.cancel(request_id)``, per-request
  deadlines (``deadline_steps`` / ``deadline_s``), and — in paged mode with
  ``preemption=True`` — preempt + requeue under page pressure: the victim's
  pages are released, its generated tokens are kept, and it re-enqueues
  with prompt+generated as the new prefix so the prefix cache restores the
  shared pages copy-free on readmission. Every exit path (done, failed,
  cancelled, timed out, preempted, stalled) releases pages and neutralizes
  bt/pos through the same ``_release_slot`` helper. A finite-logits guard
  at the sanctioned sync points quarantines a slot producing NaN/Inf logits
  (``status=FAILED``, ``error="nan_logits"``) without perturbing the rest
  of the batch. See docs/serving.md "Fault model & request lifecycle".
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models import common as C
from repro.models.registry import get_model


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """Bind cfg into the family's prefill: (params, tokens (B, S), state,
    **frontend) -> (last-position logits, filled state). The engine jits one
    instance per engine; the bucketed admission path drives it."""
    model = get_model(cfg)

    def prefill_step(params, tokens, state, **frontend):
        return model.prefill(params, cfg, tokens, state, **frontend)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """Bind cfg into the family's decode step: (params, state, tokens (B, sq))
    -> (logits (B, sq, V), new state); sq == 1 plain decode, sq > 1 stacks
    speculative draft rows (paged dense/moe). The ``decode_*`` / ``long_*``
    dry-run cells lower exactly this function."""
    model = get_model(cfg)

    def decode_step(params, state, tokens):
        return model.decode_step(params, cfg, state, tokens)

    return decode_step


def make_ragged_step(cfg: ModelConfig) -> Callable:
    """Bind cfg into the family's unified ragged step (ragged engine mode):
    (params, state, tokens (T,), slot (T,), pos (T,), ctx (B,), logit_idx
    (B,)) -> (logits (B, V), new state). One launch carries every live
    slot's scheduled tokens — prefill chunks and decode tokens together.
    Only families exposing ``ragged_step`` (dense/moe) support it."""
    model = get_model(cfg)

    def ragged_step(params, state, tokens, slot, pos, ctx, logit_idx):
        return model.ragged_step(params, cfg, state, tokens, slot, pos, ctx, logit_idx)

    return ragged_step


# ---------------------------------------------------------------------------
# requests + sampling
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SamplingParams:
    """Per-request sampling. ``temperature <= 0`` means greedy; ``top_k > 0``
    restricts sampling to the k most likely tokens."""

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


class RequestState:
    """Explicit request lifecycle states (docs/serving.md "Fault model").

    ``NEW -> QUEUED -> PREFILL -> DECODE -> {DONE, FAILED, CANCELLED,
    TIMED_OUT}`` with ``PREEMPTED`` as the requeue detour (``PREFILL/DECODE
    -> PREEMPTED -> PREFILL`` on readmission). ``TERMINAL`` is the set of
    states a request never leaves; the engine enforces the transition table
    so an illegal edge is a loud bug, not silent state drift."""

    NEW = "NEW"
    QUEUED = "QUEUED"
    PREFILL = "PREFILL"
    DECODE = "DECODE"
    DONE = "DONE"
    FAILED = "FAILED"
    CANCELLED = "CANCELLED"
    TIMED_OUT = "TIMED_OUT"
    PREEMPTED = "PREEMPTED"
    TERMINAL = frozenset({DONE, FAILED, CANCELLED, TIMED_OUT})


_TRANSITIONS: dict[str, frozenset] = {
    RequestState.NEW: frozenset({RequestState.QUEUED}),
    RequestState.QUEUED: frozenset({
        RequestState.PREFILL, RequestState.CANCELLED, RequestState.TIMED_OUT,
    }),
    RequestState.PREFILL: frozenset({
        RequestState.DECODE, RequestState.FAILED, RequestState.CANCELLED,
        RequestState.TIMED_OUT, RequestState.PREEMPTED,
    }),
    RequestState.DECODE: frozenset({
        RequestState.DONE, RequestState.FAILED, RequestState.CANCELLED,
        RequestState.TIMED_OUT, RequestState.PREEMPTED,
    }),
    RequestState.PREEMPTED: frozenset({
        RequestState.PREFILL, RequestState.CANCELLED, RequestState.TIMED_OUT,
    }),
    RequestState.DONE: frozenset(),
    RequestState.FAILED: frozenset(),
    RequestState.CANCELLED: frozenset(),
    RequestState.TIMED_OUT: frozenset(),
}

# terminal state -> the stats counter it bumps
_FINISH_COUNTER = {
    RequestState.DONE: "requests_done",
    RequestState.FAILED: "requests_failed",
    RequestState.CANCELLED: "requests_cancelled",
    RequestState.TIMED_OUT: "requests_timed_out",
}


class _SlotFault(RuntimeError):
    """Internal: a slot-attributable fault detected during admission (e.g.
    non-finite prefill logits); carries the machine-readable reason code."""

    def __init__(self, code: str, detail: str):
        super().__init__(detail)
        self.code = code
        self.detail = detail


def _fault_of(e: Exception) -> tuple[str, str]:
    """(code, detail) for an exception caught on a slot-attributable path."""
    if isinstance(e, _SlotFault):
        return e.code, e.detail
    return "prefill_exception", f"{type(e).__name__}: {e}"


@dataclasses.dataclass(eq=False)
class Request:
    """One generation request: a prompt, a token quota, and sampling params.

    The engine writes results back onto the object: ``out`` (generated token
    ids), ``status`` (a :class:`RequestState` value), ``done`` (reached a
    terminal state), ``truncated`` (stopped by cache capacity before filling
    ``max_new``), and — on FAILED/TIMED_OUT exits — ``error`` (machine-
    readable reason code, e.g. ``nan_logits`` / ``deadline_steps``) plus
    ``error_detail`` (human-readable context). ``request_id`` is assigned at
    ``submit()`` when not provided; ``priority`` orders preemption victims
    (lower preempts first); ``deadline_steps`` / ``deadline_s`` bound the
    request's lifetime in engine steps / wall-clock seconds from submission.

    **Stream surface** (docs/serving.md "SLO metrics & traffic harness"):
    ``on_token`` is an optional per-token callback ``(request, token_id)``
    fired by the engine exactly once per emitted token, at the step that
    produced it (preemption + resume never re-fires already-emitted tokens).
    A raising callback is detached with a warning after its first exception —
    a sloppy consumer must not wedge the batch. The engine also stamps
    ``t_submit`` / ``t_first_token`` / ``t_done`` (``time.monotonic``) and
    appends one entry to ``token_times`` per emitted token, so TTFT and
    per-token latency are MEASURED, not inferred (``launch/metrics.py``).
    Fields prefixed ``_`` are engine-private."""

    prompt: Any  # (S,) int32
    max_new: int = 16
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    frontend: dict = dataclasses.field(default_factory=dict)  # vlm/encdec extras
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # stream + latency observability (docs/serving.md)
    on_token: Optional[Callable] = dataclasses.field(default=None, repr=False)
    t_submit: Optional[float] = dataclasses.field(default=None, repr=False)
    t_first_token: Optional[float] = dataclasses.field(default=None, repr=False)
    t_done: Optional[float] = dataclasses.field(default=None, repr=False)
    token_times: list = dataclasses.field(default_factory=list, repr=False)
    # set at eviction when the request hit cache capacity before filling its
    # max_new quota (prompt_len + max_new > engine.max_len)
    truncated: bool = False
    # lifecycle (docs/serving.md "Fault model & request lifecycle")
    request_id: Optional[str] = None  # assigned at submit() when None
    priority: int = 0  # preemption picks the lowest-priority victim first
    deadline_steps: Optional[int] = None  # engine steps allowed after submit
    deadline_s: Optional[float] = None  # wall-clock budget after submit
    status: str = RequestState.NEW
    error: Optional[str] = None  # machine-readable failure reason code
    error_detail: Optional[str] = None
    # engine-private
    _last_logits: Any = dataclasses.field(default=None, repr=False)
    _rng: Any = dataclasses.field(default=None, repr=False)
    # ragged mode: prompt tokens already written to the cache (chunk cursor)
    # and the prompt as a host int32 array, cached at admission
    _filled: int = dataclasses.field(default=0, repr=False)
    _prompt: Any = dataclasses.field(default=None, repr=False)
    # host copy of the ORIGINAL prompt (set at submit); preemption rebuilds
    # the effective prompt as _prompt_host + out without device transfers
    _prompt_host: Any = dataclasses.field(default=None, repr=False)
    # resolved deadlines (absolute engine step / monotonic time), set at submit
    _deadline_step: Any = dataclasses.field(default=None, repr=False)
    _deadline_t: Any = dataclasses.field(default=None, repr=False)
    _preemptions: int = dataclasses.field(default=0, repr=False)


# ---------------------------------------------------------------------------
# paged-pool host bookkeeping (DESIGN.md §14)
# ---------------------------------------------------------------------------


class AllocatorError(AssertionError):
    """A page-allocator bookkeeping violation — double release, unknown page
    id, or sharing an unreferenced page — raised with the page id and its
    refcount spelled out instead of silently corrupting the free list.
    Subclasses ``AssertionError`` so callers treating allocator misuse as an
    assertion failure keep working."""


class EngineStalledError(RuntimeError):
    """``run_until_done`` exhausted its step budget with live work remaining.

    The still-live requests have already been marked ``TIMED_OUT`` (pages
    released, error code ``engine_stalled``) by the time this raises, so a
    wedged engine cannot be mistaken for a drained one and never leaks its
    page reservations."""


class PageAllocator:
    """Free-list allocator with refcounts over the global KV page pool.

    A page's refcount is exactly (number of slot block-tables mapping it)
    plus (1 if a prefix-cache entry holds it). ``alloc`` hands out ref=1
    pages, ``share`` adds a reference (prefix reuse / cache registration),
    ``release`` drops one and returns fully-freed pages to the free list.
    ``audit`` asserts the free list and refcounts partition the pool — the
    no-leak / no-double-map invariant the churn tests exercise. Misuse
    (double release, unknown ids, sharing unreferenced pages) raises
    :class:`AllocatorError` with the offending page and refcount."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.free: deque[int] = deque(range(n_pages))
        self.ref = np.zeros(n_pages, np.int32)
        self.peak_used = 0

    @property
    def n_free(self) -> int:
        """Pages currently on the free list."""
        return len(self.free)

    @property
    def n_used(self) -> int:
        """Pages currently mapped or cached (refcount > 0)."""
        return self.n_pages - len(self.free)

    def alloc(self, n: int) -> Optional[list[int]]:
        """Take ``n`` pages off the free list at ref=1; None if the pool
        cannot satisfy the request (admission then waits for evictions)."""
        if n > len(self.free):
            return None
        pages = [self.free.popleft() for _ in range(n)]
        for p in pages:
            assert self.ref[p] == 0, f"free page {p} had ref {self.ref[p]}"
            self.ref[p] = 1
        self.peak_used = max(self.peak_used, self.n_used)
        return pages

    def _known(self, p: int, op: str) -> int:
        p = int(p)
        if not 0 <= p < self.n_pages:
            raise AllocatorError(
                f"{op} of unknown page {p}: valid page ids are "
                f"0..{self.n_pages - 1}"
            )
        return p

    def share(self, pages) -> None:
        """Add one reference to each already-referenced page (prefix-cache
        reuse in a new slot, or cache registration). Sharing an unknown or
        unreferenced page raises :class:`AllocatorError` — an unreferenced
        page may already be recycled into another slot's timeline."""
        for p in pages:
            p = self._known(p, "share")
            if self.ref[p] <= 0:
                raise AllocatorError(
                    f"sharing unreferenced page {p} (refcount "
                    f"{int(self.ref[p])}): only mapped or cached pages can "
                    "take another reference"
                )
            self.ref[p] += 1

    def release(self, pages) -> None:
        """Drop one reference per page; fully-unreferenced pages return to
        the free list. Releasing an unknown page or a page whose refcount is
        already zero raises :class:`AllocatorError` (a double release would
        put the page on the free list twice and hand it to two slots)."""
        for p in pages:
            p = self._known(p, "release")
            if self.ref[p] <= 0:
                raise AllocatorError(
                    f"double release of page {p} (refcount already "
                    f"{int(self.ref[p])}): the page is on the free list and "
                    "releasing it again would corrupt the pool"
                )
            self.ref[p] -= 1
            if self.ref[p] == 0:
                self.free.append(p)

    def audit(self) -> None:
        """Assert the free list and refcounts partition the pool (no leaks,
        no double-maps, no duplicated free entries)."""
        free = set(self.free)
        assert len(free) == len(self.free), "free list contains duplicates"
        for p in range(self.n_pages):
            if p in free:
                assert self.ref[p] == 0, f"free page {p} has ref {self.ref[p]}"
            else:
                assert self.ref[p] > 0, f"page {p} leaked (ref 0 but not free)"


class _PrefixEntry:
    __slots__ = ("key", "page", "eid", "parent", "children", "tick")


class PrefixCache:
    """Prompt-prefix page cache (hash-chained at page granularity).

    Entry j of a prompt's chain is keyed by (parent entry id, the page's
    token tuple), so a key identifies the FULL token prefix up to that page
    boundary without hashing collisions or storing O(S^2) token copies.
    A hit maps already-filled, fully-immutable pages (only whole pages fully
    covered by prompt tokens are ever registered; decode writes land strictly
    after the prompt, so registered pages are never written again) into the
    new slot's block table copy-free. Registered pages carry one cache
    reference; ``evict`` drops least-recently-used leaf entries to refill
    the free list when admission runs out of pages."""

    def __init__(self, allocator: PageAllocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        self.entries: dict[tuple, _PrefixEntry] = {}
        self._by_id: dict[int, _PrefixEntry] = {}
        self._next_id = 1
        self._tick = 0

    def __len__(self) -> int:
        return len(self.entries)

    def _key(self, parent: int, prompt, j: int) -> tuple:
        ps = self.page_size
        return (parent, tuple(int(t) for t in prompt[j * ps : (j + 1) * ps]))

    def match(self, prompt) -> tuple[int, list[int]]:
        """Longest cached prefix of whole pages, capped at len(prompt)-1 so
        at least one suffix token always remains to produce prefill logits.
        Returns (n_tokens_matched, pages)."""
        self._tick += 1
        pages: list[int] = []
        parent = 0
        for j in range((len(prompt) - 1) // self.page_size):
            e = self.entries.get(self._key(parent, prompt, j))
            if e is None:
                break
            e.tick = self._tick
            pages.append(e.page)
            parent = e.eid
        return len(pages) * self.page_size, pages

    def register(self, prompt, pages: list[int]) -> None:
        """Register a freshly admitted prompt's full pages (``pages`` = the
        slot's mapped pages in timeline order, shared prefix included)."""
        self._tick += 1
        parent = 0
        for j in range(min(len(prompt) // self.page_size, len(pages))):
            key = self._key(parent, prompt, j)
            e = self.entries.get(key)
            if e is None:
                e = _PrefixEntry()
                e.key, e.page, e.parent = key, pages[j], parent
                e.eid = self._next_id
                self._next_id += 1
                e.children = 0
                self.entries[key] = e
                self._by_id[e.eid] = e
                if parent:
                    self._by_id[parent].children += 1
                self.allocator.share([e.page])
            e.tick = self._tick
            parent = e.eid

    def evict(self, n_free_needed: int, protect=()) -> int:
        """Drop LRU leaf entries (an inner entry is only evictable once its
        children are gone) until the allocator has ``n_free_needed`` free
        pages or nothing evictable remains. Returns entries evicted.

        ``protect`` is a collection of page ids that must survive: under
        preemption, the pages an admission attempt just MATCHED are a
        preempted request's resume ticket, and evicting them to fund that
        same (possibly failing) allocation would destroy the copy-free
        restore for zero gain."""
        evicted = 0
        while self.allocator.n_free < n_free_needed:
            leaves = [
                e for e in self.entries.values()
                if e.children == 0 and e.page not in protect
            ]
            if not leaves:
                break
            e = min(leaves, key=lambda e: e.tick)
            del self.entries[e.key]
            del self._by_id[e.eid]
            if e.parent:
                self._by_id[e.parent].children -= 1
            self.allocator.release([e.page])
            evicted += 1
        return evicted


# ---------------------------------------------------------------------------
# slot-state splicing
# ---------------------------------------------------------------------------


def _slot_axes(cfg: ModelConfig, model, max_len: int):
    """Pytree of ints: the slot (batch) axis of every decode-state leaf,
    inferred by diffing a batch-2 against a batch-1 ``eval_shape`` — exactly
    one dim differs (2 vs 1), and that dim is the slot axis. Works for any
    family without hand-written per-leaf layout tables."""
    big = jax.eval_shape(lambda: model.init_decode_state(cfg, 2, max_len))
    one = jax.eval_shape(lambda: model.init_decode_state(cfg, 1, max_len))

    def axis(b, o):
        diffs = [i for i, (db, do) in enumerate(zip(b.shape, o.shape)) if db != do]
        if len(diffs) != 1 or b.shape[diffs[0]] != 2 or o.shape[diffs[0]] != 1:
            raise ValueError(f"cannot infer slot axis: {b.shape} vs {o.shape}")
        return diffs[0]

    return jax.tree.map(axis, big, one)


def _make_slot_insert(axes, keys=None) -> Callable:
    """jit-compiled splice of a batch-1 state into slot ``idx`` of the full
    state; one dynamic_update_slice_in_dim per leaf, index traced so every
    slot shares one executable. ``keys`` restricts the splice to a subset of
    (flat dict) state leaves — the paged engine splices only per-slot leaves
    and routes pooled leaves through the page writer instead."""
    if keys is None:
        def insert(state, sub, idx):
            return jax.tree.map(
                lambda leaf, subleaf, ax: jax.lax.dynamic_update_slice_in_dim(
                    leaf, subleaf.astype(leaf.dtype), idx, axis=ax
                ),
                state, sub, axes,
            )
    else:
        keys = tuple(keys)

        def insert(state, sub, idx):
            out = dict(state)
            for k in keys:
                out[k] = jax.lax.dynamic_update_slice_in_dim(
                    state[k], sub[k].astype(state[k].dtype), idx, axis=axes[k]
                )
            return out

    return jax.jit(insert)


def _make_page_writer(pool_keys) -> Callable:
    """jit-compiled scatter of a batch-1 prefill's cache rows into mapped
    pages: sub leaf (L, 1, S, ...) -> pool pages ``page_ids`` (n,). Rows are
    zero-padded / truncated to n*page_size — trailing garbage rows inside a
    reserved page are invisible behind the per-slot pos mask and overwritten
    token-by-token as decode proceeds. Retraces per (n, S) combination, both
    bucketed, so the executable count stays O(log S_max)."""
    pool_keys = tuple(pool_keys)

    def write(state, sub, page_ids):
        n = page_ids.shape[0]
        out = dict(state)
        for k in pool_keys:
            pool = state[k]
            ps = pool.shape[2]
            rows = sub[k][:, 0]  # (L, S, ...)
            need = n * ps
            if rows.shape[1] < need:
                pad = [(0, 0)] * rows.ndim
                pad[1] = (0, need - rows.shape[1])
                rows = jnp.pad(rows, pad)
            else:
                rows = rows[:, :need]
            rows = rows.reshape(rows.shape[0], n, ps, *rows.shape[2:])
            out[k] = pool.at[:, page_ids].set(rows.astype(pool.dtype))
        return out

    return jax.jit(write)


def _make_prefix_gather(pool_keys) -> Callable:
    """jit-compiled gather of shared prefix pages into the dense (L, 1, m,
    ...) context the family prefill's ``prefix`` kwarg consumes."""
    pool_keys = tuple(pool_keys)

    def gather(state, ids):
        out = {}
        for k in pool_keys:
            pool = state[k]
            pages = pool[:, ids]  # (L, m_pages, ps, ...)
            out[k] = pages.reshape(
                pool.shape[0], 1, ids.shape[0] * pool.shape[2], *pool.shape[3:]
            )
        return out

    return jax.jit(gather)


def _ngram_draft(hist: list, k: int) -> list:
    """Self-drafting for speculative decode: propose the ``k`` tokens that
    followed the most recent earlier occurrence of the history's trailing
    n-gram (n = 3, 2, 1, longest context first), falling back to repeating
    the last token. Host-side and deterministic — the draft only has to be
    cheap and often right; verification makes any draft safe."""
    n = len(hist)
    if n == 0:
        return [0] * k
    for m in (3, 2, 1):
        if n <= m:
            continue
        key = hist[n - m:]
        for j in range(n - m - 1, -1, -1):
            if hist[j : j + m] == key:
                cont = hist[j + m : j + m + k]
                if cont:
                    return cont + [cont[-1]] * (k - len(cont))
                break
    return [hist[-1]] * k


# families whose decode state is FULLY page-addressable (caches + pos only),
# so a prompt prefix maps onto shared pages with no residual per-slot state.
# vlm is excluded (patch frontends make token-hashed prefixes unsound),
# encdec has per-request encoder K/V, recurrent families carry O(1) state.
_PREFIX_FAMILIES = ("dense", "moe", "mla_moe")


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


class ContinuousBatchingEngine:
    """Continuous-batching server: a static batch of B independent slot
    timelines, per-slot admission/eviction, per-request sampling, lock-step
    decode (the TPU-efficient layout), and throughput accounting.

    ``paged=True`` switches the decode state to the paged layout (global page
    pool + per-slot block tables): cache memory is proportional to pages in
    use instead of slots x max_len, admission gates on free pages, and shared
    prompt prefixes are served from the prefix cache without re-prefilling.
    ``paged=False`` (default) keeps the dense per-slot layout — the A/B lane
    and correctness oracle for the paging invariant tests.

    Prompts are padded to power-of-two buckets by default
    (``bucket_prompts``), so prefill compiles O(log max_len) executables
    instead of one per distinct prompt length; ``compile_stats()`` reports
    the inventory.

    ``ragged=True`` (requires ``paged=True`` and a family exposing
    ``ragged_step``; dense/moe) replaces the bucketed-prefill + lock-step
    split entirely: every ``step()`` concatenates the scheduled tokens of
    ALL live slots — one decode token per decoding slot plus prompt chunks
    for admitting slots, capped at ``token_budget`` — into one flat ragged
    batch and runs ONE launch over it. Long prompts are chunked across
    steps, so decode latency stays flat during admission, and the whole
    engine compiles a single token-budget-shaped executable instead of the
    O(log max_len) prefill bucket inventory (docs/serving.md).
    ``max_chunk_share`` caps the fraction of ``token_budget`` prompt chunks
    may claim per step — the decode-priority knob under long-prompt floods.

    ``speculation=True`` (requires ``paged=True``, non-ragged, dense/moe)
    turns each decode launch into a self-speculative verify step: the
    sampled token plus ``spec_k - 1`` drafted candidates run as one
    multi-row launch through the paged-attention kernel, the longest
    greedy-matching draft prefix commits, and rejected rows roll back by a
    ``pos`` rewind. Greedy output is token-identical to the non-speculative
    engine; ``throughput()`` reports ``acceptance_rate`` and
    ``tokens_per_step`` (docs/serving.md "Speculative decoding").
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4, max_len: int = 128,
                 paged: bool = False, page_size: int = 16, n_pages: Optional[int] = None,
                 prefix_caching: bool = True, bucket_prompts: bool = True,
                 on_truncation: str = "warn", ragged: bool = False,
                 token_budget: int = 64, max_chunk_share: float = 1.0,
                 preemption: bool = False, speculation: bool = False,
                 spec_k: int = 4, draft_fn: Optional[Callable] = None):
        if on_truncation not in ("warn", "reject"):
            raise ValueError(f"on_truncation must be 'warn' or 'reject', got {on_truncation!r}")
        if not 0.0 < max_chunk_share <= 1.0:
            raise ValueError(
                f"max_chunk_share must be in (0, 1], got {max_chunk_share}"
            )
        self.cfg = cfg
        self.model = get_model(cfg)
        # serving default: pre-merge sibling quantized packs (q/k/v, gate/up,
        # wq_a/wkv_a) ONCE so fused launches read merged packs directly —
        # trace-time fusion would otherwise re-concatenate the packs inside
        # every jitted step (they are jit arguments, not constants). A no-op
        # for bf16/w4a16/already-merged trees; skipped when the process-wide
        # fusion toggle is off (the benchmarks' --no-fused A/B lane).
        from repro.core.twinquant import fuse_params
        from repro.kernels.dispatch import fusion_enabled

        self.params = fuse_params(params) if fusion_enabled() else params
        self.batch = batch_slots
        self.max_len = max_len
        self.paged = paged
        self.bucket_prompts = bucket_prompts
        self.on_truncation = on_truncation
        # preempt + requeue under page pressure (paged mode only): opt-in so
        # the no-preemption admission behavior stays the A/B baseline
        self.preemption = bool(preemption)
        self._steps = 0  # lifetime engine steps (deadline_steps clock)
        self._next_rid = 0
        # frontend row inflation: vlm prefill prepends n_patches rows to the
        # decoder cache, so capacity/page math must count them with the prompt
        self._extra_rows = cfg.n_patches if cfg.family == "vlm" else 0
        # structural leaf classification (slot axis / optional seq axis)
        self._layout = C.paged_layout(self.model.init_decode_state, cfg, max_len)
        self._pool_keys = tuple(k for k, (_, seq) in self._layout.items() if seq is not None)
        axes = _slot_axes(cfg, self.model, max_len)

        if paged and self._pool_keys:
            if page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            self.page_size = page_size
            self._max_pages = -(-max_len // page_size)
            self.n_pages = n_pages if n_pages is not None else batch_slots * self._max_pages
            self.state = C.init_paged_state(
                self.model.init_decode_state, cfg, batch_slots, max_len, page_size, self.n_pages
            )
            self.allocator: Optional[PageAllocator] = PageAllocator(self.n_pages)
            self.prefix_cache: Optional[PrefixCache] = (
                PrefixCache(self.allocator, page_size)
                if prefix_caching and cfg.family in _PREFIX_FAMILIES else None
            )
            self._bt = np.full((batch_slots, self._max_pages), -1, np.int32)
            slot_keys = tuple(k for k in self._layout if k not in self._pool_keys)
            self._insert = _make_slot_insert(axes, keys=slot_keys)
            self._page_write = _make_page_writer(self._pool_keys)
            self._prefix_gather = _make_prefix_gather(self._pool_keys)
        else:
            # dense per-slot layout — also the degenerate "paged" layout for
            # purely recurrent families, whose state has nothing to page
            self.page_size = 0
            self.n_pages = 0
            self.state = self.model.init_decode_state(cfg, batch_slots, max_len)
            self.allocator = None
            self.prefix_cache = None
            self._insert = _make_slot_insert(axes)
        # constant zero batch-1 state, built once: the splice source for every
        # admission (prefill never donates/mutates its inputs)
        self._sub_template = self.model.init_decode_state(cfg, 1, max_len)
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(make_decode_step(cfg))
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._prefill_traces: dict[tuple, int] = {}
        # unified ragged step (chunked prefill + decode in one launch)
        self.ragged = False
        self.token_budget = int(token_budget)
        self.max_chunk_share = float(max_chunk_share)
        self._ragged_traces: dict[int, int] = {}
        if ragged:
            ok = (
                self.allocator is not None
                and self._extra_rows == 0
                and getattr(self.model, "ragged_step", None) is not None
            )
            if not ok:
                warnings.warn(
                    "ragged=True needs paged mode and a family with a "
                    "ragged_step (dense/moe); falling back to bucketed "
                    "prefill + lock-step decode",
                    stacklevel=2,
                )
            else:
                if self.token_budget < batch_slots:
                    raise ValueError(
                        f"token_budget ({self.token_budget}) must be >= "
                        f"batch_slots ({batch_slots}) so every decoding slot "
                        f"gets a row each step"
                    )
                self.ragged = True
                self._ragged = jax.jit(make_ragged_step(cfg))
                # host mirror of per-slot committed rows: the ragged loop
                # never downloads state["pos"] (no per-step sync for it)
                self._pos_host = np.zeros(batch_slots, np.int32)
        # self-speculative multi-token verification (docs/serving.md
        # "Speculative decoding"): each decode launch stacks the sampled
        # token plus spec_k-1 self-drafted candidates per slot and accepts
        # the longest greedy-matching prefix. Needs the paged layout (the
        # rollback is a pos rewind behind the full up-front page
        # reservation) and a family whose decode_step takes (B, sq) rows
        # through the paged_decode kernel (dense/moe).
        self.speculation = False
        self.spec_k = int(spec_k)
        self._draft_fn = draft_fn
        self._spec_traces: dict[tuple, int] = {}
        if speculation:
            from repro.kernels.autotune import DECODE_M_MAX

            ok = (
                self.allocator is not None
                and not self.ragged
                and self._extra_rows == 0
                and cfg.family in ("dense", "moe")
            )
            if not ok:
                warnings.warn(
                    "speculation=True needs paged (non-ragged) mode and a "
                    "family with multi-row paged decode (dense/moe); falling "
                    "back to one-token decode steps",
                    stacklevel=2,
                )
            elif not 2 <= self.spec_k <= DECODE_M_MAX:
                raise ValueError(
                    f"spec_k must be in [2, {DECODE_M_MAX}] (the kernel's "
                    f"multi-query row cap), got {self.spec_k}"
                )
            else:
                self.speculation = True
        # latency observability (docs/serving.md "SLO metrics"): every request
        # ever submitted (for engine.latency()) and a per-step queue-depth
        # sample — both host bookkeeping, no device traffic
        self._requests: list[Request] = []
        self._queue_depths: list[int] = []
        self.stats = {
            "prefill_tokens": 0, "prefill_s": 0.0,
            "decode_tokens": 0, "decode_steps": 0, "decode_s": 0.0,
            "requests_done": 0, "requests_truncated": 0,
            "requests_failed": 0, "requests_cancelled": 0,
            "requests_timed_out": 0, "requests_preempted": 0,
            "prefix_lookups": 0, "prefix_hits": 0, "prefix_hit_tokens": 0,
            "spec_launches": 0, "spec_slot_steps": 0,
            "spec_drafted": 0, "spec_accepted": 0,
        }
        # dispatch-counter baseline: routing() reports the delta, i.e. the
        # kernel routes this engine's traces took (quantized params only)
        from repro.kernels.dispatch import dispatch_counters

        self._dispatch0 = dispatch_counters()

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Enqueue a request; admit immediately if a slot (and, when paged,
        enough pages) is free. Returns True when the request went straight
        into a slot. Invalid requests are rejected HERE, before touching
        queue or slot state, so one bad request can never strand a batch
        mid-generation. Re-submitting a request that is already queued or
        live is a no-op."""
        if req.status in RequestState.TERMINAL or req.done:
            return True  # already resolved (e.g. admitted+finished inside one step)
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be 1-D (S,), got shape {prompt.shape}")
        n = int(prompt.shape[0])
        if n and not np.issubdtype(prompt.dtype, np.integer):
            raise ValueError(
                f"prompt must hold integer token ids, got dtype {prompt.dtype}"
            )
        if n and (int(prompt.min()) < 0 or int(prompt.max()) >= self.cfg.vocab):
            bad = [int(t) for t in prompt if not 0 <= int(t) < self.cfg.vocab][:8]
            raise ValueError(
                f"prompt contains token ids outside the model vocab "
                f"[0, {self.cfg.vocab}): {bad} — rejected at submit() so "
                "garbage input fails at the API boundary, not as an XLA "
                "gather deep inside prefill"
            )
        rows = n + self._extra_rows  # cache rows the prompt occupies
        if not 1 <= rows < self.max_len:
            raise ValueError(
                f"prompt length {n} (+{self._extra_rows} frontend rows) must "
                f"leave room in max_len={self.max_len}"
            )
        if rows + req.max_new > self.max_len:
            msg = (f"request will truncate: prompt rows {rows} + max_new {req.max_new} "
                   f"> max_len {self.max_len} (the slot runs out of cache rows "
                   f"after {self.max_len - rows} new tokens)")
            if self.on_truncation == "reject":
                raise ValueError(msg)
            warnings.warn(msg, stacklevel=2)
        if self.allocator is not None:
            worst = -(-min(rows + req.max_new, self.max_len) // self.page_size)
            if worst > self.n_pages:
                raise ValueError(
                    f"request needs up to {worst} pages but the pool only has "
                    f"{self.n_pages}; it could never be admitted"
                )
        if any(s is req for s in self.slots) or any(q is req for q in self.queue):
            return any(s is req for s in self.slots)
        if req.request_id is None:
            req.request_id = f"req-{self._next_rid}"
            self._next_rid += 1
        if req.t_submit is None:
            req.t_submit = time.monotonic()
        if all(req is not r for r in self._requests):
            self._requests.append(req)
        req._prompt_host = prompt.astype(np.int32)
        if req.status == RequestState.NEW:
            self._set_status(req, RequestState.QUEUED)
        if req.deadline_steps is not None and req._deadline_step is None:
            req._deadline_step = self._steps + int(req.deadline_steps)
        if req.deadline_s is not None and req._deadline_t is None:
            req._deadline_t = time.monotonic() + float(req.deadline_s)
        self.queue.append(req)
        self._admit()
        return any(s is req for s in self.slots)

    @staticmethod
    def _bucket(n: int, cap: int) -> int:
        """Power-of-two prompt bucket (min 8), capped at the cache capacity."""
        return max(n, min(1 << max(3, (n - 1).bit_length()), cap))

    def _run_prefill(self, req: Request, tokens: np.ndarray, off: int = 0,
                     shared_pages: Optional[list[int]] = None):
        """One batched prefill of ``tokens`` (the prompt, or the suffix after
        ``off`` prefix-cached tokens), bucket-padded. Returns (last_logits
        np (V,), sub_state, bucket_len)."""
        s_real = len(tokens)
        cap = self.max_len - off - self._extra_rows
        bucket = self._bucket(s_real, cap) if self.bucket_prompts else s_real
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :s_real] = tokens
        kwargs = dict(req.frontend)
        if bucket != s_real or off or self.bucket_prompts:
            kwargs["length"] = jnp.full((1,), s_real, jnp.int32)
        if off:
            kwargs["prefix"] = self._prefix_gather(
                {k: self.state[k] for k in self._pool_keys},
                jnp.asarray(shared_pages, jnp.int32),
            )
        key = (bucket, off, tuple(sorted(req.frontend)))
        self._prefill_traces[key] = self._prefill_traces.get(key, 0) + 1
        t0 = time.monotonic()
        logits, sub = self._prefill(self.params, jnp.asarray(toks), self._sub_template, **kwargs)
        last = np.asarray(logits[0, -1].astype(jnp.float32))  # sync-point
        last = C.logits_tap(last, "prefill")
        self.stats["prefill_s"] += time.monotonic() - t0
        self.stats["prefill_tokens"] += s_real
        return last, sub, bucket

    def _check_prefill_logits(self, last: np.ndarray) -> None:
        """Finite-logits guard on the freshly-downloaded prefill row: a
        non-finite row fails only THIS request (reason ``nan_logits``), never
        the batch."""
        if C.nonfinite_rows(last[None, :], self.cfg.vocab):
            raise _SlotFault("nan_logits", "non-finite prefill logits")

    # -- lifecycle ----------------------------------------------------------

    def _set_status(self, req: Request, new: str) -> None:
        allowed = _TRANSITIONS.get(req.status, frozenset())
        if new not in allowed:
            raise RuntimeError(
                f"illegal request state transition {req.status} -> {new} "
                f"(request {req.request_id})"
            )
        req.status = new

    def _finish(self, req: Request, status: str,
                code: Optional[str] = None, detail: Optional[str] = None) -> None:
        """Terminal host bookkeeping shared by every exit path: transition to
        ``status``, set ``done``, capture the failure reason, bump the
        matching stats counter."""
        self._set_status(req, status)
        req.done = True
        if req.t_done is None:
            req.t_done = time.monotonic()
        if code is not None:
            req.error = code
            req.error_detail = detail
        req._last_logits = None
        self.stats[_FINISH_COUNTER[status]] += 1

    def _release_slot(self, i: int) -> None:
        """Release slot ``i``'s pages and neutralize its device state — the
        ONE reclaim path every exit (done, failed, cancelled, timed out,
        preempted, stalled) goes through, so no exit can leak pages or leave
        a stale block-table row attending garbage."""
        if self.allocator is not None:
            self.allocator.release([int(p) for p in self._bt[i] if p >= 0])
            self._bt[i, :] = -1
            # block-table upload is a sanctioned exit-path transfer: the
            # transfer-guard sanitizer keeps the rest of the decode loop
            # transfer-free (see analysis/sanitizers.guarded_decode)
            with jax.transfer_guard("allow"):
                self.state["bt"] = jnp.asarray(self._bt)
                # neutralize the freed slot: pos 0 + unmapped block table means
                # its lock-step garbage decode attends nothing and writes nowhere
                self.state["pos"] = self.state["pos"].at[i].set(0)
        if self.ragged:
            self._pos_host[i] = 0

    def _effective_prompt(self, req: Request) -> np.ndarray:
        """The request's prompt as the engine should (re)prefill it now:
        the original prompt, extended by the generated tokens when the
        request was preempted mid-generation (host arrays only — no device
        transfer)."""
        base = req._prompt_host
        if base is None:  # direct _admit_one callers that bypassed submit()
            base = np.asarray(req.prompt, np.int32)
        if not req.out:
            return base
        return np.concatenate([base, np.asarray(req.out, np.int32)])

    def _committed_rows(self, i: int, req: Request) -> int:
        """Cache rows slot ``i`` has actually written (prompt + generated)."""
        if self.ragged:
            return int(self._pos_host[i])
        return len(req._prompt_host) + self._extra_rows + len(req.out)

    def cancel(self, request) -> bool:
        """Cancel a queued or live request by ``request_id`` (or the Request
        object itself). A live request's pages are released and its slot
        neutralized exactly like an eviction; generated-so-far tokens stay on
        ``req.out``. Returns True when the request was cancelled, False when
        it is unknown or already terminal."""
        req = None
        if isinstance(request, Request):
            req = request
        else:
            for r in list(self.queue) + [s for s in self.slots if s is not None]:
                if r.request_id == request:
                    req = r
                    break
        if req is None or req.status in RequestState.TERMINAL:
            return False
        for i, s in enumerate(self.slots):
            if s is req:
                self.slots[i] = None
                self._release_slot(i)
                self._finish(req, RequestState.CANCELLED)
                return True
        try:
            self.queue.remove(req)
        except ValueError:
            return False  # not queued, not live: nothing to cancel
        self._finish(req, RequestState.CANCELLED)
        return True

    def _deadline_code(self, req: Request, now: float) -> Optional[str]:
        if req._deadline_step is not None and self._steps > req._deadline_step:
            return "deadline_steps"
        if req._deadline_t is not None and now >= req._deadline_t:
            return "deadline_s"
        return None

    def _expire_deadlines(self) -> None:
        """TIME_OUT every queued or live request whose step/wall-clock
        deadline has passed; live slots release pages through the common
        exit path. Runs at the top of every engine step."""
        now = time.monotonic()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            code = self._deadline_code(req, now)
            if code:
                self.slots[i] = None
                self._release_slot(i)
                self._finish(req, RequestState.TIMED_OUT, code,
                             f"deadline expired at engine step {self._steps}")
        if any(self._deadline_code(q, now) for q in self.queue):
            keep: deque[Request] = deque()
            for q in self.queue:
                code = self._deadline_code(q, now)
                if code:
                    self._finish(q, RequestState.TIMED_OUT, code,
                                 f"deadline expired at engine step {self._steps} "
                                 "while queued")
                else:
                    keep.append(q)
            self.queue = keep

    def _preempt(self, i: int, req: Request) -> None:
        """Preempt slot ``i``: register its fully-written pages under
        prompt+generated (so readmission restores them copy-free from the
        prefix cache), release the slot's page reservation, keep the
        generated tokens, and re-enqueue. A resumed greedy request emits
        tokens identical to an uninterrupted run — the effective prompt IS
        the uninterrupted timeline."""
        committed = self._committed_rows(i, req)
        eff = self._effective_prompt(req)
        if self.prefix_cache is not None and not req.frontend:
            row = [int(p) for p in self._bt[i] if p >= 0]
            self.prefix_cache.register(eff[:committed], row)
        self._set_status(req, RequestState.PREEMPTED)
        req._preemptions += 1
        self.stats["requests_preempted"] += 1
        self.slots[i] = None
        self._release_slot(i)
        req._last_logits = None
        req._filled = 0
        req._prompt = None
        self.queue.append(req)

    def _preempt_for(self, head: Request, admitted: list) -> bool:
        """Pick and preempt one victim so the page-starved ``head`` can
        admit: the lowest-priority live slot, ties broken by longest
        remaining quota. Only a STRICTLY lower-priority slot is eligible —
        equal-priority preemption could ping-pong two requests that each
        need the whole pool, whereas strict ordering makes every preemption
        chain finite. Slots admitted during this admission pass and slots
        about to hit capacity anyway are also exempt. Returns True when a
        victim was preempted (the caller retries the head)."""
        if not self.preemption or self.allocator is None:
            return False
        victims = [
            (req.priority, -(req.max_new - len(req.out)), i)
            for i, req in enumerate(self.slots)
            if req is not None
            and all(req is not a for a in admitted)
            and req.priority < head.priority
            and self._committed_rows(i, req) + 1 < self.max_len
        ]
        if not victims:
            return False
        _, _, i = min(victims)
        self._preempt(i, self.slots[i])
        return True

    def _admit(self) -> None:
        admitted: list = []
        while self.queue:
            free = [i for i, s in enumerate(self.slots) if s is None]
            if not free:
                return
            head = self.queue[0]
            if self._admit_one(head, free[0]):
                self.queue.popleft()
                admitted.append(head)
                continue
            # page-gated: preempt the cheapest victim and retry the head, or
            # (preemption off / no eligible victim) wait for evictions
            if not self._preempt_for(head, admitted):
                return

    def _admit_one_ragged(self, req: Request, i: int) -> bool:
        """Ragged-mode admission: reserve the request's pages (prefix-cache
        hits included) and park it in slot ``i`` with its chunk cursor at the
        first uncached prompt token. No prefill call happens here — the
        prompt is streamed through subsequent ``_step_ragged`` launches in
        token-budget-sized chunks."""
        prompt = self._effective_prompt(req)  # prompt (+generated, if preempted)
        n = len(prompt)
        remaining = req.max_new - len(req.out)
        need = min(n + remaining, self.max_len)
        n_res = -(-need // self.page_size)
        m_tok, shared = 0, []
        if self.prefix_cache is not None and not req.frontend:
            self.stats["prefix_lookups"] += 1
            # no power-of-two bucketing of the match: chunk scheduling is
            # position-exact, so any matched page count costs zero extra
            # traces (the single token-budget executable covers all offsets)
            m_tok, shared = self.prefix_cache.match(prompt)
        self.allocator.share(shared)
        pages = self.allocator.alloc(n_res - len(shared))
        if pages is None and self.prefix_cache is not None:
            # under preemption, never evict the pages this attempt matched —
            # they are the preempted request's copy-free resume ticket
            protect = frozenset(shared) if self.preemption else frozenset()
            self.prefix_cache.evict(n_res - len(shared), protect=protect)
            pages = self.allocator.alloc(n_res - len(shared))
        if pages is None:
            self.allocator.release(shared)
            return False  # admission gated on free pages
        self._set_status(req, RequestState.PREFILL)
        try:
            if m_tok:
                self.stats["prefix_hits"] += 1
                self.stats["prefix_hit_tokens"] += m_tok
            row = shared + pages
            self._bt[i, :] = -1
            self._bt[i, : len(row)] = row
            with jax.transfer_guard("allow"):
                self.state["bt"] = jnp.asarray(self._bt)
            req._prompt = prompt
            req._filled = m_tok
            self._pos_host[i] = m_tok
            req._last_logits = None
            if req._rng is None:  # survive preemption: don't reset the stream
                req._rng = np.random.default_rng(req.sampling.seed)
            self.slots[i] = req
        except Exception as e:
            # quarantine THIS request, release its whole reservation, and
            # report the head as consumed so the rest of the queue proceeds
            self._bt[i, :] = -1
            with jax.transfer_guard("allow"):
                self.state["bt"] = jnp.asarray(self._bt)
            self.allocator.release(shared + pages)
            self.slots[i] = None
            self._pos_host[i] = 0
            self._finish(req, RequestState.FAILED, *_fault_of(e))
        return True

    def _admit_one(self, req: Request, i: int) -> bool:
        if self.ragged:
            return self._admit_one_ragged(req, i)
        if self.allocator is None:
            self._set_status(req, RequestState.PREFILL)
            try:
                last, sub, _ = self._run_prefill(req, self._effective_prompt(req))
                self._check_prefill_logits(last)
                self.state = self._insert(self.state, sub, i)
            except Exception as e:
                self._finish(req, RequestState.FAILED, *_fault_of(e))
                return True  # consumed (quarantined), not page-gated
        else:
            prompt = self._effective_prompt(req)  # prompt (+generated, if preempted)
            n = len(prompt)
            # reserve the request's full timeline up front (prompt rows incl.
            # frontend inflation + remaining quota) so decode never needs a
            # mid-flight allocation; preemption is the only sanctioned reclaim
            # path and it releases whole reservations
            remaining = req.max_new - len(req.out)
            need = min(n + self._extra_rows + remaining, self.max_len)
            n_res = -(-need // self.page_size)
            m_tok, shared = 0, []
            if self.prefix_cache is not None and not req.frontend:
                self.stats["prefix_lookups"] += 1
                m_tok, shared = self.prefix_cache.match(prompt)
                if shared:
                    # bucket the prefix to a power-of-two page count: the
                    # suffix-prefill executable is shaped by the prefix
                    # length, so raw offsets would compile one trace per
                    # distinct matched length — this keeps the inventory
                    # O(log max_pages), like prompt bucketing itself
                    keep = 1 << (len(shared).bit_length() - 1)
                    shared = shared[:keep]
                    m_tok = keep * self.page_size
            # take our reference on the shared pages BEFORE any eviction:
            # cache eviction under pressure may drop the matched entries, and
            # an unreferenced match could be recycled out from under us
            self.allocator.share(shared)
            n_own = n_res - len(shared)
            pages = self.allocator.alloc(n_own)
            if pages is None and self.prefix_cache is not None:
                # under preemption, never evict the pages this attempt
                # matched — they are the resume ticket of a preempted request
                protect = frozenset(shared) if self.preemption else frozenset()
                self.prefix_cache.evict(n_own, protect=protect)
                pages = self.allocator.alloc(n_own)
            if pages is None:
                self.allocator.release(shared)
                return False  # admission gated on free pages
            self._set_status(req, RequestState.PREFILL)
            try:
                if m_tok:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_hit_tokens"] += m_tok
                last, sub, bucket = self._run_prefill(req, prompt[m_tok:], off=m_tok,
                                                      shared_pages=shared)
                self._check_prefill_logits(last)
                self.state = self._insert(self.state, sub, i)
                n_write = min(-(-(bucket + self._extra_rows) // self.page_size),
                              len(pages))
                self.state = self._page_write(
                    self.state, sub, jnp.asarray(pages[:n_write], jnp.int32)
                )
                row = shared + pages
                self._bt[i, :] = -1
                self._bt[i, : len(row)] = row
                self.state["bt"] = jnp.asarray(self._bt)
                if self.prefix_cache is not None and not req.frontend:
                    self.prefix_cache.register(prompt, row)
            except Exception as e:
                # quarantine THIS request (tampered pack, NaN prefill, ...):
                # hand back the whole reservation, neutralize the row, and
                # consume the queue head so the fault can't wedge admission
                self._bt[i, :] = -1
                with jax.transfer_guard("allow"):
                    self.state["bt"] = jnp.asarray(self._bt)
                self.allocator.release(shared + pages)
                self._finish(req, RequestState.FAILED, *_fault_of(e))
                return True
        req._last_logits = last
        if req._rng is None:  # survive preemption: don't reset the stream
            req._rng = np.random.default_rng(req.sampling.seed)
        self._set_status(req, RequestState.DECODE)
        self.slots[i] = req
        return True

    # -- sampling -----------------------------------------------------------

    def _sample(self, req: Request) -> int:
        logits = req._last_logits[: self.cfg.vocab]
        sp = req.sampling
        if sp.temperature <= 0.0:
            return int(np.argmax(logits))
        scaled = logits / sp.temperature
        if sp.top_k > 0 and sp.top_k < scaled.shape[0]:
            kth = np.partition(scaled, -sp.top_k)[-sp.top_k]
            scaled = np.where(scaled < kth, -np.inf, scaled)
        p = np.exp(scaled - scaled.max())
        p /= p.sum()
        return int(req._rng.choice(p.shape[0], p=p))

    def _emit_token(self, req: Request, tok: int) -> None:
        """The ONE token-emission path every mode (bucketed, ragged,
        speculative) goes through: append to ``req.out``, stamp the
        wall-clock emission time (TTFT on the first), and fire the request's
        ``on_token`` stream callback exactly once for this token. A raising
        callback is detached with a warning — the consumer loses its stream,
        the batch loses nothing."""
        now = time.monotonic()
        req.out.append(tok)
        req.token_times.append(now)
        if req.t_first_token is None:
            req.t_first_token = now
        if req.on_token is not None:
            try:
                req.on_token(req, tok)
            except Exception as e:  # noqa: BLE001 — hostile-consumer guard
                req.on_token = None
                warnings.warn(
                    f"on_token callback for request {req.request_id} raised "
                    f"{type(e).__name__}: {e} — callback detached, request "
                    "continues without streaming",
                    stacklevel=2,
                )

    # -- decode -------------------------------------------------------------

    def _evict(self, i: int, req: Request, truncated: bool) -> None:
        self.slots[i] = None
        self._release_slot(i)
        req.truncated = truncated
        if truncated:
            self.stats["requests_truncated"] += 1
        self._finish(req, RequestState.DONE)

    def _draft_tokens(self, req: Request, k: int) -> list:
        """``k`` draft tokens continuing the request's committed history
        (prompt + generated, the just-sampled token included). An installed
        ``draft_fn(req, k)`` hook (e.g. a small draft model) takes precedence
        over the built-in n-gram self-draft; its proposals are clamped into
        the vocab so a sloppy hook cannot crash the embed gather."""
        if self._draft_fn is not None:
            d = [int(t) for t in self._draft_fn(req, k)][:k]
            d = [min(max(t, 0), self.cfg.vocab - 1) for t in d]
            last = d[-1] if d else (req.out[-1] if req.out else 0)
            return d + [last] * (k - len(d))
        hist = req._prompt_host.tolist() + req.out
        return _ngram_draft(hist, k)

    def _step_spec(self, active: list) -> None:
        """One speculative decode launch (docs/serving.md "Speculative
        decoding"): per live slot, sample the next token from the held
        logits (exactly the non-speculative commit), stack it with
        ``spec_k - 1`` self-drafted candidates, and run ONE multi-row decode
        launch — the paged kernel attends all rows causally and the page
        scatter writes all rows' KV. Greedy slots then accept the longest
        draft prefix matching the launch's own argmaxes (each accepted row's
        logits re-verify the next), capped by quota and cache capacity;
        sampled (temperature > 0) slots commit only the sampled token, so
        their random streams are untouched. Rejected rows are rolled back by
        rewinding ``pos`` — the full up-front page reservation makes the
        stale rows invisible behind the prefix mask until overwritten."""
        k = self.spec_k
        tok = np.zeros((self.batch, k), np.int32)
        with jax.transfer_guard("allow"):
            pos = np.asarray(self.state["pos"])  # sync-point: next write offset per slot
        live: list[int] = []
        drafts: dict[int, list] = {}
        for i in active:
            req = self.slots[i]
            nxt = self._sample(req)
            self._emit_token(req, nxt)
            if len(req.out) >= req.max_new:
                self._evict(i, req, truncated=False)
            elif int(pos[i]) >= self.max_len:
                self._evict(i, req, truncated=True)
            else:
                drafts[i] = self._draft_tokens(req, k - 1)
                tok[i, 0] = nxt
                tok[i, 1:] = drafts[i]
                live.append(i)
        if not live:
            return
        t0 = time.monotonic()
        with jax.transfer_guard("allow"):
            logits, self.state = self._decode(self.params, self.state, jnp.asarray(tok))
            last = np.asarray(logits.astype(jnp.float32))  # sync-point: (B, k, V) verify download
        last = C.logits_tap(last, "decode")
        dt = time.monotonic() - t0
        self._spec_traces[(self.batch, k)] = self._spec_traces.get((self.batch, k), 0) + 1
        # flat row b*k + j -> slot b (all of a bad slot's rows are suspect)
        bad = {f // k for f in C.nonfinite_rows(last, self.cfg.vocab)}
        # phase 1: acceptance — longest draft prefix whose tokens match the
        # launch's own greedy choices, then rewind pos past the rejects
        committed: dict[int, int] = {}
        delta = np.zeros(self.batch, np.int32)
        for i in live:
            req = self.slots[i]
            n_acc = 0
            if i not in bad and req.sampling.temperature <= 0.0:
                quota_room = req.max_new - len(req.out)
                cap_rows = self.max_len - int(pos[i]) - 1
                while (n_acc < k - 1 and n_acc < quota_room and n_acc < cap_rows
                       and int(drafts[i][n_acc])
                       == int(np.argmax(last[i, n_acc, : self.cfg.vocab]))):
                    self._emit_token(req, int(drafts[i][n_acc]))
                    n_acc += 1
                self.stats["spec_drafted"] += k - 1
                self.stats["spec_accepted"] += n_acc
            committed[i] = 1 + n_acc
            delta[i] = k - committed[i]
        with jax.transfer_guard("allow"):
            # sync-point: upload the per-slot rewind (rejected rows become
            # invisible garbage past pos, overwritten by the next commits)
            self.state["pos"] = self.state["pos"] - jnp.asarray(delta)
        self.stats["decode_s"] += dt
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += sum(committed.values())
        self.stats["spec_launches"] += 1
        self.stats["spec_slot_steps"] += len(live)
        # phase 2: per-slot exits AFTER the rewind (the release path zeroes
        # pos; rewinding later would resurrect the freed slot's offset)
        for i in live:
            req = self.slots[i]
            if i in bad:
                self.slots[i] = None
                self._release_slot(i)
                self._finish(req, RequestState.FAILED, "nan_logits",
                             f"non-finite decode logits at engine step "
                             f"{self._steps}")
                continue
            req._last_logits = last[i, committed[i] - 1]
            if len(req.out) >= req.max_new:
                self._evict(i, req, truncated=False)
            elif int(pos[i]) + committed[i] >= self.max_len:
                # mirror the non-speculative order exactly: the token past
                # the last cache row is still sampled and kept, THEN the
                # slot exits (truncated unless that token filled the quota)
                self._emit_token(req, self._sample(req))
                self._evict(i, req, truncated=len(req.out) < req.max_new)

    def _step_ragged(self) -> int:
        """One unified ragged engine step (docs/serving.md): sample + schedule
        one decode token per decoding slot FIRST (decode rows are never
        displaced by admission), fill the remaining token budget with prompt
        chunks FIFO across admitting slots, then run ONE ``ragged_step``
        launch over the flat batch. Pad rows carry the sentinel slot id B and
        are inert in attention and cache writes."""
        self._steps += 1
        self._expire_deadlines()
        self._admit()
        self._queue_depths.append(len(self.queue))
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        budget = self.token_budget
        tokens = np.zeros(budget, np.int32)
        slot = np.full(budget, self.batch, np.int32)  # pad sentinel = B
        pos = np.zeros(budget, np.int32)
        logit_idx = np.zeros(self.batch, np.int32)
        row = 0
        decode_rows: list[int] = []
        # decode tokens first: a slot mid-generation gets its row every step
        for i in active:
            req = self.slots[i]
            if req._last_logits is None:
                continue  # still prefilling — chunks scheduled below
            nxt = self._sample(req)
            self._emit_token(req, nxt)
            # quota filled (or no cache row left for the new token): evict
            # BEFORE the launch — its next logits would be discarded anyway
            if len(req.out) >= req.max_new:
                self._evict(i, req, truncated=False)
            elif int(self._pos_host[i]) >= self.max_len:
                self._evict(i, req, truncated=True)
            else:
                tokens[row] = nxt
                slot[row] = i
                pos[row] = self._pos_host[i]
                logit_idx[i] = row
                decode_rows.append(i)
                row += 1
        # prompt chunks fill whatever budget decode left, FIFO across slots,
        # additionally capped at max_chunk_share of the token budget — the
        # decode-priority knob: a long-prompt flood can never swell the
        # launch beyond the configured share, so steady decoders keep their
        # per-step cadence at a bounded launch size. The floor of one token
        # keeps admission live even at tiny shares.
        chunk_cap = max(1, int(self.token_budget * self.max_chunk_share))
        chunks: list[tuple[int, int]] = []  # (slot, tokens scheduled)
        n_chunk = 0
        for i in active:
            req = self.slots[i]
            if req is None or req._last_logits is not None:
                continue
            space = min(budget - row, chunk_cap - n_chunk)
            if space <= 0:
                break
            take = min(space, len(req._prompt) - req._filled)
            tokens[row : row + take] = req._prompt[req._filled : req._filled + take]
            slot[row : row + take] = i
            pos[row : row + take] = self._pos_host[i] + np.arange(take, dtype=np.int32)
            if req._filled + take == len(req._prompt):
                logit_idx[i] = row + take - 1  # last prompt token's logits
            chunks.append((i, take))
            n_chunk += take
            row += take
        if row == 0:
            self._admit()
            return len(active)
        t0 = time.monotonic()
        with jax.transfer_guard("allow"):
            logits, self.state = self._ragged(
                self.params, self.state, jnp.asarray(tokens), jnp.asarray(slot),
                jnp.asarray(pos), jnp.asarray(self._pos_host.copy()),
                jnp.asarray(logit_idx),
            )
            last = np.asarray(logits.astype(jnp.float32))  # sync-point: per-slot logits download
        last = C.logits_tap(last, "ragged")
        dt = time.monotonic() - t0
        # split wall time by scheduled-token share so both tok/s stay honest
        self.stats["decode_s"] += dt * len(decode_rows) / row
        self.stats["prefill_s"] += dt * n_chunk / row
        self.stats["decode_steps"] += 1
        self.stats["decode_tokens"] += len(decode_rows)
        self.stats["prefill_tokens"] += n_chunk
        self._ragged_traces[budget] = self._ragged_traces.get(budget, 0) + 1
        # finite-logits guard at the step's single sync point: a NaN/Inf row
        # fails only its own slot; every other slot's bytes are untouched
        bad = set(C.nonfinite_rows(last, self.cfg.vocab))
        for i in decode_rows:
            req = self.slots[i]
            if i in bad:
                self.slots[i] = None
                self._release_slot(i)
                self._finish(req, RequestState.FAILED, "nan_logits",
                             f"non-finite decode logits at engine step {self._steps}")
                continue
            self._pos_host[i] += 1
            req._last_logits = last[i]
        for i, take in chunks:
            req = self.slots[i]
            self._pos_host[i] += take
            req._filled += take
            if req._filled == len(req._prompt):
                if i in bad:
                    self.slots[i] = None
                    self._release_slot(i)
                    self._finish(req, RequestState.FAILED, "nan_logits",
                                 f"non-finite prefill logits at engine step "
                                 f"{self._steps}")
                    continue
                req._last_logits = last[i]
                self._set_status(req, RequestState.DECODE)
                # deferred prefix registration: the prompt's pages are only
                # fully written once its last chunk lands
                if self.prefix_cache is not None and not req.frontend:
                    self.prefix_cache.register(
                        req._prompt, [int(p) for p in self._bt[i] if p >= 0]
                    )
        self._admit()
        return len(active)

    def step(self) -> int:
        """Admit queued work, sample one token per active slot, then one
        lock-step decode for the slots that still need logits (ragged mode:
        one unified chunked-prefill + decode launch, see ``_step_ragged``).
        Returns the number of slots that were live at entry."""
        if self.ragged:
            return self._step_ragged()
        self._steps += 1
        self._expire_deadlines()
        self._admit()
        self._queue_depths.append(len(self.queue))
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        if self.speculation:
            self._step_spec(active)
            self._admit()
            return len(active)
        tok = np.zeros((self.batch, 1), np.int32)
        with jax.transfer_guard("allow"):
            pos = np.asarray(self.state["pos"])  # sync-point: next write offset per slot
        live = []
        for i in active:
            req = self.slots[i]
            nxt = self._sample(req)
            self._emit_token(req, nxt)
            tok[i, 0] = nxt
            # a request whose quota is now filled (or whose token has no cache
            # row left) is evicted BEFORE the decode — its final logits would
            # be discarded anyway
            if len(req.out) >= req.max_new:
                self._evict(i, req, truncated=False)
            elif int(pos[i]) >= self.max_len:
                self._evict(i, req, truncated=True)
            else:
                live.append(i)
        if live:
            t0 = time.monotonic()
            with jax.transfer_guard("allow"):
                logits, self.state = self._decode(self.params, self.state, jnp.asarray(tok))
                last = np.asarray(logits[:, -1].astype(jnp.float32))  # sync-point
            last = C.logits_tap(last, "decode")
            self.stats["decode_s"] += time.monotonic() - t0
            self.stats["decode_steps"] += 1
            self.stats["decode_tokens"] += len(live)
            # finite-logits guard at the step's sync point: quarantine only
            # the offending slot, every other slot's logits are untouched
            bad = set(C.nonfinite_rows(last, self.cfg.vocab))
            for i in live:
                req = self.slots[i]
                if i in bad:
                    self.slots[i] = None
                    self._release_slot(i)
                    self._finish(req, RequestState.FAILED, "nan_logits",
                                 f"non-finite decode logits at engine step "
                                 f"{self._steps}")
                else:
                    req._last_logits = last[i]
        self._admit()
        return len(active)

    # -- drivers ------------------------------------------------------------

    def run_until_done(self, max_steps: int = 100_000) -> None:
        """Drive ``step()`` until no slot is live and the queue is empty.

        Exhausting ``max_steps`` SURFACES instead of silently stopping: every
        still-live or still-queued request is marked ``TIMED_OUT`` (error code
        ``engine_stalled``), its pages are released through the common exit
        path, and :class:`EngineStalledError` is raised — a wedged engine can
        never be mistaken for a drained one."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
        stranded: list[str] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            stranded.append(str(req.request_id))
            self.slots[i] = None
            self._release_slot(i)
            self._finish(req, RequestState.TIMED_OUT, "engine_stalled",
                         f"run_until_done exhausted {max_steps} steps")
        while self.queue:
            req = self.queue.popleft()
            stranded.append(str(req.request_id))
            self._finish(req, RequestState.TIMED_OUT, "engine_stalled",
                         f"run_until_done exhausted {max_steps} steps while queued")
        raise EngineStalledError(
            f"engine stalled: run_until_done exhausted {max_steps} steps with "
            f"{len(stranded)} request(s) unfinished ({', '.join(stranded)}); "
            "they are marked TIMED_OUT and their pages have been released"
        )

    def serve(self, requests: list[Request], max_steps: int = 100_000) -> list[Request]:
        """Submit all requests and drive the loop to completion. Results ride
        on the Request objects (``out``, ``done``, ``truncated``)."""
        for r in requests:
            self.submit(r)
        self.run_until_done(max_steps)
        return requests

    def stream(self, request: Request, max_steps: int = 100_000):
        """Submit ``request`` and yield its tokens as the engine emits them.

        A synchronous streaming iterator (docs/serving.md "Stream API"):
        each ``next()`` drives ``step()`` until at least one new token lands
        on ``request.out`` (so the time-to-first-yield IS the TTFT, modulo
        the consumer's own latency), then yields the tokens in emission
        order. Other queued/active requests keep being served by the same
        steps — streaming one request does not stall the batch. Returns
        when the request reaches a terminal state; exhausting ``max_steps``
        marks every unfinished request ``TIMED_OUT`` through the common
        exit path and raises :class:`EngineStalledError`, exactly like
        :meth:`run_until_done`. For callback-style consumption (many
        concurrent streams) set ``Request.on_token`` and drive the engine
        yourself."""
        self.submit(request)
        emitted = 0
        for _ in range(max_steps):
            self.step()
            while emitted < len(request.out):
                yield request.out[emitted]
                emitted += 1
            if request.done:
                return
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slots[i] = None
            self._release_slot(i)
            self._finish(req, RequestState.TIMED_OUT, "engine_stalled",
                         f"stream exhausted {max_steps} steps")
        while self.queue:
            req = self.queue.popleft()
            self._finish(req, RequestState.TIMED_OUT, "engine_stalled",
                         f"stream exhausted {max_steps} steps while queued")
        raise EngineStalledError(
            f"engine stalled: stream exhausted {max_steps} steps with request "
            f"{request.request_id} unfinished; unfinished requests are marked "
            "TIMED_OUT and their pages have been released"
        )

    def reset_stats(self) -> None:
        """Zero the timing counters (e.g. after a warm-up pass).

        The latency surface (request roster + queue-depth samples feeding
        ``latency()``) is cleared too — a warm-up request's compile-inflated
        TTFT would otherwise sit in the percentiles forever. The
        dispatch-routing baseline is NOT reset: routing decisions happen
        at trace time, so a warm executable would otherwise report an empty
        route table. The prefill-trace inventory (compile_stats) persists for
        the same reason."""
        self.stats = {k: type(v)() for k, v in self.stats.items()}
        self._requests.clear()
        self._queue_depths.clear()
        if self.allocator is not None:
            self.allocator.peak_used = self.allocator.n_used

    # -- introspection ------------------------------------------------------

    def compile_stats(self) -> dict:
        """Prefill executable inventory: with prompt bucketing every distinct
        (bucket, prefix-offset, frontend) triple is one trace, so the count
        stays O(log max_len) under arbitrary prompt-length traffic."""
        return {
            "prefill_traces": len(self._prefill_traces),
            "prefill_calls": sum(self._prefill_traces.values()),
            "prefill_buckets": sorted({k[0] for k in self._prefill_traces}),
            # distinct (prefix-offset, frontend) variants: the recompile
            # sanitizer's budget is O(log max_len) buckets PER variant
            "prefill_variants": len({k[1:] for k in self._prefill_traces}),
            "decode_traces": 1 if (self.stats["decode_steps"] and not self.ragged
                                   and not self.speculation) else 0,
            # ragged mode compiles ONE token-budget-shaped executable for
            # everything (chunked prefill + decode); the compile-budget
            # sanitizer asserts ragged_traces + prefill_traces <= 2
            "ragged_traces": len(self._ragged_traces),
            # speculative mode likewise compiles ONE (batch, spec_k)-shaped
            # decode executable; every distinct spec launch shape is a trace
            "spec_traces": len(self._spec_traces),
        }

    def memory(self) -> dict:
        """Cache-memory accounting: the paged pool's bytes and peak pages in
        use vs the dense per-slot footprint the same (batch, max_len) engine
        would allocate — the capacity headroom paging buys."""
        dense_shapes = jax.eval_shape(
            lambda: self.model.init_decode_state(self.cfg, self.batch, self.max_len)
        )
        dense_bytes = sum(
            int(np.prod(dense_shapes[k].shape)) * dense_shapes[k].dtype.itemsize
            for k in self._pool_keys
        )
        out = {
            "mode": "paged" if self.allocator is not None else "dense",
            "dense_cache_bytes": dense_bytes,
        }
        if self.allocator is None:
            out["cache_bytes"] = dense_bytes
            out["peak_cache_bytes"] = dense_bytes
            return out
        page_bytes = 0
        for k in self._pool_keys:
            pool = self.state[k]
            page_bytes += int(np.prod(pool.shape[:1] + pool.shape[2:])) * pool.dtype.itemsize
        out.update(
            page_size=self.page_size,
            n_pages=self.n_pages,
            page_bytes=page_bytes,
            cache_bytes=page_bytes * self.n_pages,
            pages_in_use=self.allocator.n_used,
            pages_peak=self.allocator.peak_used,
            peak_cache_bytes=page_bytes * self.allocator.peak_used,
            prefix_entries=0 if self.prefix_cache is None else len(self.prefix_cache),
        )
        return out

    def check_page_invariants(self) -> None:
        """Debug/test hook: allocator audit plus exact refcount accounting —
        every pool page's refcount equals the number of slot block-tables
        mapping it plus its prefix-cache registrations, no slot maps a page
        twice, and free/used pages partition the pool."""
        if self.allocator is None:
            return
        self.allocator.audit()
        refs = np.zeros(self.n_pages, np.int32)
        for i in range(self.batch):
            row = [int(p) for p in self._bt[i] if p >= 0]
            assert len(set(row)) == len(row), f"slot {i} maps a page twice: {row}"
            assert self.slots[i] is not None or not row, \
                f"empty slot {i} still maps pages {row}"
            assert (self.slots[i] is None
                    or self.slots[i].status not in RequestState.TERMINAL), \
                f"slot {i} holds terminal request {self.slots[i].request_id}"
            for p in row:
                refs[p] += 1
        if self.prefix_cache is not None:
            for e in self.prefix_cache.entries.values():
                refs[e.page] += 1
        assert np.array_equal(refs, self.allocator.ref), (
            f"refcount drift: mapped+cached {refs.tolist()} "
            f"vs allocator {self.allocator.ref.tolist()}"
        )

    def routing(self) -> dict:
        """Kernel routes taken by this engine's traces: {kind/path: count}.

        Counts compiled routes (trace-time dispatch decisions) for the
        quantized linears in this engine's prefill/decode executables —
        the end-to-end evidence that decode steps hit the decode-shaped
        kernel schedule and prefill steps hit the prefill one, and (kind
        ``dual_fused``) that sibling projections (q/k/v, gate/up) ran as
        one fused launch rather than one per sibling. The per-kind sums
        are the launches-per-traced-step number the bench gate ratchets.

        Attribution caveat: the underlying counters are process-global, so
        the delta also includes routes traced by OTHER engines (or eager
        quant_linear calls) between this engine's construction and now.
        Reliable per-engine attribution requires constructing and driving
        engines sequentially, as the benchmarks do."""
        from repro.kernels.dispatch import dispatch_counters

        now = dispatch_counters()
        return {
            k: v - self._dispatch0.get(k, 0)
            for k, v in now.items()
            if v - self._dispatch0.get(k, 0) > 0
        }

    def throughput(self) -> dict:
        """Tokens/s summary from the accounting counters."""
        st = self.stats
        return {
            "decode_tok_s": st["decode_tokens"] / max(st["decode_s"], 1e-9),
            "prefill_tok_s": st["prefill_tokens"] / max(st["prefill_s"], 1e-9),
            "mean_batch_occupancy": st["decode_tokens"] / max(st["decode_steps"], 1),
            # speculative decode quality: drafts accepted / drafts verified,
            # and committed tokens per slot per decode launch (>= 1.0; the
            # speculation speedup lever, 1.0 exactly when speculation is off)
            "acceptance_rate": st["spec_accepted"] / max(st["spec_drafted"], 1),
            "tokens_per_step": (
                st["decode_tokens"] / max(st["spec_slot_steps"], 1)
                if self.speculation
                else (1.0 if st["decode_tokens"] else 0.0)
            ),
            "routing": self.routing(),
            **st,
        }

    def latency(self, slo=None) -> dict:
        """SLO-facing latency summary over every request this engine has seen
        (docs/serving.md "SLO metrics & traffic harness"): TTFT / per-token
        / end-to-end percentiles, goodput under ``slo`` (an
        :class:`repro.launch.metrics.SLO` or None for raw throughput),
        queue-depth profile, preemption and prefix-hit rates. Timing comes
        from the wall-clock stamps ``submit()``/``_emit_token``/``_finish``
        record on each Request; the counters ride on ``self.stats``. Sits
        beside :meth:`routing` and :meth:`throughput` as the third
        introspection surface — this one is about tails, not means."""
        from repro.launch.metrics import summarize

        return summarize(self._requests, slo=slo,
                         queue_depths=self._queue_depths, stats=self.stats)


# Backwards-compatible name: the engine replaced the original demo Server.
Server = ContinuousBatchingEngine
