"""Serving launcher: prefill/decode step construction + a continuous-batching
serving engine built on per-slot cache state.

The decode step is the function the ``decode_*`` / ``long_*`` dry-run cells
lower; :class:`ContinuousBatchingEngine` is the runnable end-to-end driver
used by examples/serve_quantized.py and benchmarks/bench_throughput.py.

Engine architecture (DESIGN.md §10):

* Every decode state carries a **per-slot position vector** ``pos (B,)`` —
  each batch slot is an independent timeline, so requests of different
  lengths decode in lock-step without sharing a global step counter.
* **Admission** runs the model's real prefill once on a batch-1 state (one
  batched pass over the whole prompt, not T decode steps) and splices the
  resulting cache/recurrent state into the free slot with a single
  ``dynamic_update_slice_in_dim`` per leaf — live slots are never touched.
* The slot axis of every state leaf is inferred structurally (batch-2 vs
  batch-1 ``eval_shape`` diff), so the same engine serves KV-cache
  transformers, MLA latent caches, SSM/xLSTM recurrent states, and hybrid
  stacks without per-family splice code.
* **Eviction** is host bookkeeping only: a finished request frees its slot;
  stale device state is fully overwritten at the next admission, and
  per-slot masking (``arange(S) < pos[b]``) keeps it invisible meanwhile.
* Sampling is per-request (greedy / temperature / top-k) on the host.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models.registry import get_model


def make_prefill_step(cfg: ModelConfig) -> Callable:
    model = get_model(cfg)

    def prefill_step(params, tokens, state, **frontend):
        return model.prefill(params, cfg, tokens, state, **frontend)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    model = get_model(cfg)

    def decode_step(params, state, tokens):
        return model.decode_step(params, cfg, state, tokens)

    return decode_step


# ---------------------------------------------------------------------------
# requests + sampling
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SamplingParams:
    """Per-request sampling. ``temperature <= 0`` means greedy; ``top_k > 0``
    restricts sampling to the k most likely tokens."""

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass(eq=False)
class Request:
    prompt: Any  # (S,) int32
    max_new: int = 16
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    frontend: dict = dataclasses.field(default_factory=dict)  # vlm/encdec extras
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # engine-private
    _last_logits: Any = dataclasses.field(default=None, repr=False)
    _rng: Any = dataclasses.field(default=None, repr=False)


# ---------------------------------------------------------------------------
# slot-state splicing
# ---------------------------------------------------------------------------


def _slot_axes(cfg: ModelConfig, model, max_len: int):
    """Pytree of ints: the slot (batch) axis of every decode-state leaf,
    inferred by diffing a batch-2 against a batch-1 ``eval_shape`` — exactly
    one dim differs (2 vs 1), and that dim is the slot axis. Works for any
    family without hand-written per-leaf layout tables."""
    big = jax.eval_shape(lambda: model.init_decode_state(cfg, 2, max_len))
    one = jax.eval_shape(lambda: model.init_decode_state(cfg, 1, max_len))

    def axis(b, o):
        diffs = [i for i, (db, do) in enumerate(zip(b.shape, o.shape)) if db != do]
        if len(diffs) != 1 or b.shape[diffs[0]] != 2 or o.shape[diffs[0]] != 1:
            raise ValueError(f"cannot infer slot axis: {b.shape} vs {o.shape}")
        return diffs[0]

    return jax.tree.map(axis, big, one)


def _make_slot_insert(axes) -> Callable:
    """jit-compiled splice of a batch-1 state into slot ``idx`` of the full
    state; one dynamic_update_slice_in_dim per leaf, index traced so every
    slot shares one executable."""

    def insert(state, sub, idx):
        return jax.tree.map(
            lambda leaf, subleaf, ax: jax.lax.dynamic_update_slice_in_dim(
                leaf, subleaf.astype(leaf.dtype), idx, axis=ax
            ),
            state, sub, axes,
        )

    return jax.jit(insert)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


class ContinuousBatchingEngine:
    """Continuous-batching server: a static batch of B independent slot
    timelines, per-slot admission/eviction, per-request sampling, lock-step
    decode (the TPU-efficient layout), and throughput accounting.

    Note: prefill jit-specializes on prompt length — callers serving wildly
    varied prompt lengths should bucket/pad prompts upstream.
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4, max_len: int = 128):
        self.cfg = cfg
        self.model = get_model(cfg)
        # serving default: pre-merge sibling quantized packs (q/k/v, gate/up,
        # wq_a/wkv_a) ONCE so fused launches read merged packs directly —
        # trace-time fusion would otherwise re-concatenate the packs inside
        # every jitted step (they are jit arguments, not constants). A no-op
        # for bf16/w4a16/already-merged trees; skipped when the process-wide
        # fusion toggle is off (the benchmarks' --no-fused A/B lane).
        from repro.core.twinquant import fuse_params
        from repro.kernels.dispatch import fusion_enabled

        self.params = fuse_params(params) if fusion_enabled() else params
        self.batch = batch_slots
        self.max_len = max_len
        self.state = self.model.init_decode_state(cfg, batch_slots, max_len)
        # constant zero batch-1 state, built once: the splice source for every
        # admission (prefill never donates/mutates its inputs)
        self._sub_template = self.model.init_decode_state(cfg, 1, max_len)
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self.queue: deque[Request] = deque()
        self._insert = _make_slot_insert(_slot_axes(cfg, self.model, max_len))
        self._decode = jax.jit(make_decode_step(cfg))
        self._prefill = jax.jit(make_prefill_step(cfg))
        self.stats = {
            "prefill_tokens": 0, "prefill_s": 0.0,
            "decode_tokens": 0, "decode_steps": 0, "decode_s": 0.0,
            "requests_done": 0,
        }
        # dispatch-counter baseline: routing() reports the delta, i.e. the
        # kernel routes this engine's traces took (quantized params only)
        from repro.kernels.dispatch import dispatch_counters

        self._dispatch0 = dispatch_counters()

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Enqueue a request; admit immediately if a slot is free. Returns
        True when the request went straight into a slot. Invalid requests
        are rejected HERE, before touching queue or slot state, so one bad
        request can never strand a batch mid-generation. Re-submitting a
        request that is already queued or live is a no-op."""
        if req.done:  # already served (e.g. admitted+finished inside one step)
            return True
        prompt = jnp.asarray(req.prompt)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be 1-D (S,), got shape {prompt.shape}")
        n = int(prompt.shape[0])
        if not 1 <= n < self.max_len:
            raise ValueError(
                f"prompt length {n} must be in [1, max_len={self.max_len})"
            )
        if any(s is req for s in self.slots) or any(q is req for q in self.queue):
            return any(s is req for s in self.slots)
        self.queue.append(req)
        self._admit()
        return any(s is req for s in self.slots)

    def _admit(self) -> None:
        for i in range(self.batch):
            if not self.queue:
                return
            if self.slots[i] is not None:
                continue
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            sub = self._sub_template  # fresh-state splice source (read-only)
            t0 = time.monotonic()
            logits, sub = self._prefill(self.params, prompt, sub, **req.frontend)
            self.state = self._insert(self.state, sub, i)
            last = np.asarray(logits[0, -1].astype(jnp.float32))  # sync point
            self.stats["prefill_s"] += time.monotonic() - t0
            self.stats["prefill_tokens"] += int(prompt.shape[1])
            req._last_logits = last
            req._rng = np.random.default_rng(req.sampling.seed)
            self.slots[i] = req

    # -- sampling -----------------------------------------------------------

    def _sample(self, req: Request) -> int:
        logits = req._last_logits[: self.cfg.vocab]
        sp = req.sampling
        if sp.temperature <= 0.0:
            return int(np.argmax(logits))
        scaled = logits / sp.temperature
        if sp.top_k > 0 and sp.top_k < scaled.shape[0]:
            kth = np.partition(scaled, -sp.top_k)[-sp.top_k]
            scaled = np.where(scaled < kth, -np.inf, scaled)
        p = np.exp(scaled - scaled.max())
        p /= p.sum()
        return int(req._rng.choice(p.shape[0], p=p))

    # -- decode -------------------------------------------------------------

    def step(self) -> int:
        """Admit queued work, sample one token per active slot, then one
        lock-step decode for the slots that still need logits. Returns the
        number of slots that produced a token."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        tok = np.zeros((self.batch, 1), np.int32)
        pos = np.asarray(self.state["pos"])  # next write offset per slot
        live = []
        for i in active:
            req = self.slots[i]
            nxt = self._sample(req)
            req.out.append(nxt)
            tok[i, 0] = nxt
            # a request whose quota is now filled (or whose token has no cache
            # row left) is evicted BEFORE the decode — its final logits would
            # be discarded anyway
            if len(req.out) >= req.max_new or int(pos[i]) >= self.max_len:
                req.done = True
                self.slots[i] = None
                self.stats["requests_done"] += 1
            else:
                live.append(i)
        if live:
            t0 = time.monotonic()
            logits, self.state = self._decode(self.params, self.state, jnp.asarray(tok))
            last = np.asarray(logits[:, -1].astype(jnp.float32))  # sync point
            self.stats["decode_s"] += time.monotonic() - t0
            self.stats["decode_steps"] += 1
            self.stats["decode_tokens"] += len(live)
            for i in live:
                self.slots[i]._last_logits = last[i]
        self._admit()
        return len(active)

    # -- drivers ------------------------------------------------------------

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return

    def serve(self, requests: list[Request], max_steps: int = 100_000) -> list[Request]:
        """Submit all requests and drive the loop to completion."""
        for r in requests:
            self.submit(r)
        self.run_until_done(max_steps)
        return requests

    def reset_stats(self) -> None:
        """Zero the timing counters (e.g. after a warm-up pass).

        The dispatch-routing baseline is NOT reset: routing decisions happen
        at trace time, so a warm executable would otherwise report an empty
        route table."""
        self.stats = {k: type(v)() for k, v in self.stats.items()}

    def routing(self) -> dict:
        """Kernel routes taken by this engine's traces: {kind/path: count}.

        Counts compiled routes (trace-time dispatch decisions) for the
        quantized linears in this engine's prefill/decode executables —
        the end-to-end evidence that decode steps hit the decode-shaped
        kernel schedule and prefill steps hit the prefill one, and (kind
        ``dual_fused``) that sibling projections (q/k/v, gate/up) ran as
        one fused launch rather than one per sibling. The per-kind sums
        are the launches-per-traced-step number the bench gate ratchets.

        Attribution caveat: the underlying counters are process-global, so
        the delta also includes routes traced by OTHER engines (or eager
        quant_linear calls) between this engine's construction and now.
        Reliable per-engine attribution requires constructing and driving
        engines sequentially, as the benchmarks do."""
        from repro.kernels.dispatch import dispatch_counters

        now = dispatch_counters()
        return {
            k: v - self._dispatch0.get(k, 0)
            for k, v in now.items()
            if v - self._dispatch0.get(k, 0) > 0
        }

    def throughput(self) -> dict:
        """Tokens/s summary from the accounting counters."""
        st = self.stats
        return {
            "decode_tok_s": st["decode_tokens"] / max(st["decode_s"], 1e-9),
            "prefill_tok_s": st["prefill_tokens"] / max(st["prefill_s"], 1e-9),
            "mean_batch_occupancy": st["decode_tokens"] / max(st["decode_steps"], 1),
            "routing": self.routing(),
            **st,
        }


# Backwards-compatible name: the engine replaced the original demo Server.
Server = ContinuousBatchingEngine
