"""HLO-text cost model with while-loop trip-count multiplication.

Why this exists: XLA:CPU's ``compiled.cost_analysis()`` counts a while-loop
body ONCE, not x trip-count (verified: a 16-step scan of a 0.54 GFLOP matmul
reports 0.56 GFLOP, the unrolled version 8.9 GFLOP). All our models scan over
layers, so the built-in numbers undercount by ~n_layers. This module parses
the optimized per-partition HLO and recomputes:

* flops     — dot ops: 2 x result-elements x contraction size (batch dims are
              part of the result). Elementwise/reduce ops contribute 1 flop
              per output element. Multiplied through while trip counts.
* bytes     — per top-level instruction: result + operand bytes ("bytes
              accessed" semantics; fusions count only their boundary I/O).
* collectives — result bytes per kind, x trip counts.

Trip counts come from the loop-condition computation's s32 ``constant(N)``
(jax scans lower to `compare(counter, N), direction=LT`).

Validated against cost_analysis on unrolled programs (tests/test_roofline.py).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s*constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast"}


def shape_elems(shape_str: str) -> float:
    total = 0.0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str  # operands + attributes (may span the rest of the line)

    def operands(self) -> list[str]:
        # operands live before the first "), " attribute boundary
        depth = 0
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    args = self.rest[:i]
                    break
                depth -= 1
        else:
            args = self.rest
        return _OPERAND_RE.findall(args)


class HloCost:
    def __init__(self, text: str):
        self.comps: dict[str, list[Instr]] = {}
        self.symtab: dict[str, dict[str, str]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._flops_memo: dict[str, float] = {}
        self._trip_memo: dict[str, int] = {}

    def _parse(self, text: str) -> None:
        cur = None
        for line in text.splitlines():
            hdr = _COMP_HDR.match(line)
            if hdr and ("->" in line):
                cur = hdr.group(1)
                self.comps[cur] = []
                self.symtab[cur] = {}
                if line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                ins = Instr(name=m.group(1), shape=m.group(2), opcode=m.group(3),
                            rest=m.group(4))
                self.comps[cur].append(ins)
                self.symtab[cur][ins.name] = ins.shape

    # ------------------------------------------------------------------

    def trip_count(self, cond_comp: str) -> int:
        if cond_comp in self._trip_memo:
            return self._trip_memo[cond_comp]
        best = 1
        for ins in self.comps.get(cond_comp, []):
            for c in _CONST_RE.finditer(ins.shape + " " + ins.opcode + "(" + ins.rest):
                best = max(best, int(c.group(1)))
            # constants may also appear as standalone constant instrs
            if ins.opcode == "constant" and ins.shape.startswith("s32[]"):
                m = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
                if m:
                    best = max(best, int(m.group(1)))
            # dig into fused compare computations
            cm = _CALLS_RE.search(ins.rest)
            if cm and cm.group(1) in self.comps:
                best = max(best, self.trip_count(cm.group(1)))
        self._trip_memo[cond_comp] = best
        return best

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_elems = shape_elems(ins.shape)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
        ops = ins.operands()
        if not m or not ops:
            return 2 * out_elems
        lhs_shape = self.symtab[comp].get(ops[0], "")
        dims = _SHAPE_RE.search(lhs_shape)
        if not dims:
            return 2 * out_elems
        lhs_dims = [int(d) for d in dims.group(2).split(",") if d]
        k = 1
        for idx in m.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
        return 2.0 * out_elems * k

    def comp_flops(self, comp: str) -> float:
        if comp in self._flops_memo:
            return self._flops_memo[comp]
        self._flops_memo[comp] = 0.0  # cycle guard
        total = 0.0
        for ins in self.comps.get(comp, []):
            if ins.opcode == "dot":
                total += self._dot_flops(comp, ins)
            elif ins.opcode == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                trip = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    total += trip * self.comp_flops(body.group(1))
            elif ins.opcode in ("fusion", "call", "conditional", "map"):
                for cm in set(_CALLS_RE.findall(ins.rest)):
                    total += self.comp_flops(cm)
            elif ins.opcode in ("reduce", "reduce-window"):
                ops = ins.operands()
                if ops:
                    total += shape_elems(self.symtab[comp].get(ops[0], ins.shape))
            elif ins.opcode in ("add", "multiply", "subtract", "divide", "exponential",
                                "tanh", "rsqrt", "maximum", "minimum", "compare",
                                "select", "convert", "log"):
                total += shape_elems(ins.shape)
        self._flops_memo[comp] = total
        return total

    def _param_slice_bytes(self, called: str) -> dict[int, float]:
        """For a fused computation: parameter index -> effective read bytes,
        for params consumed ONLY by dynamic-slice / dynamic-update-slice /
        gather (operand 0). Scan bodies slice one layer's weights out of the
        (L, ...) stacked array per iteration — counting the full stacked
        operand per trip overcounts HBM reads by L."""
        out: dict[int, float] = {}
        instrs = self.comps.get(called, [])
        sym = self.symtab.get(called, {})
        pidx: dict[str, int] = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                m = re.match(r"(\d+)\)", ins.rest)
                if m:
                    pidx[ins.name] = int(m.group(1))
        consumers: dict[str, list] = defaultdict(list)
        for ins in instrs:
            for pos, op in enumerate(ins.operands()):
                if op in pidx:
                    consumers[op].append((ins, pos))
        for pname, uses in consumers.items():
            ok = True
            eff = 0.0
            for ins, pos in uses:
                if ins.opcode in ("dynamic-slice", "gather") and pos == 0:
                    eff += shape_bytes(ins.shape)
                elif ins.opcode == "dynamic-update-slice" and pos == 0:
                    ops = ins.operands()
                    upd = sym.get(ops[1], "") if len(ops) > 1 else ""
                    eff += 2 * shape_bytes(upd)
                else:
                    ok = False
                    break
            if ok and uses:
                out[pidx[pname]] = eff
        return out

    def _fusion_root(self, called: str):
        instrs = self.comps.get(called, [])
        return instrs[-1] if instrs else None

    def _instr_bytes(self, comp: str, ins: Instr) -> float:
        if ins.opcode in ("dynamic-slice", "gather"):
            return 2 * shape_bytes(ins.shape)
        if ins.opcode == "dynamic-update-slice":
            ops = ins.operands()
            upd = self.symtab[comp].get(ops[1], "") if len(ops) > 1 else ""
            return 2 * shape_bytes(upd)
        b = shape_bytes(ins.shape)
        inplace_dus = False
        eff: dict[int, float] = {}
        if ins.opcode == "fusion":
            cm = _CALLS_RE.search(ins.rest)
            if cm and cm.group(1) in self.comps:
                eff = self._param_slice_bytes(cm.group(1))
                # in-place cache update: a dus inside the fusion whose result
                # has the same element count as the fusion result means XLA
                # aliases the big buffer and writes only the update window
                # (possibly wrapped in CPU-only bf16<->f32 converts) —
                # counting the full result overcounts by S per decode step
                res_elems = shape_elems(ins.shape)
                for inner in self.comps[cm.group(1)]:
                    is_dus = inner.opcode == "dynamic-update-slice"
                    if is_dus and shape_elems(inner.shape) == res_elems:
                        iops = inner.operands()
                        upd = self.symtab[cm.group(1)].get(iops[1], "") if len(iops) > 1 else ""
                        b = 2 * shape_bytes(upd)
                        inplace_dus = True
                        break
        for pos, op in enumerate(ins.operands()):
            if pos in eff:
                b += eff[pos]
            elif (
                inplace_dus
                and shape_elems(self.symtab[comp].get(op, "")) == shape_elems(ins.shape)
            ):
                pass  # the aliased big operand — not re-read
            else:
                b += shape_bytes(self.symtab[comp].get(op, ""))
        return b

    def _comp_bytes_coll(self, comp: str, mult: float, bytes_acc: list,
                         coll: dict, visited: tuple) -> None:
        if comp in visited:
            return
        for ins in self.comps.get(comp, []):
            if ins.opcode == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                trip = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    self._comp_bytes_coll(body.group(1), mult * trip, bytes_acc,
                                          coll, visited + (comp,))
                continue
            if ins.opcode in ("call", "conditional"):
                for cm in set(_CALLS_RE.findall(ins.rest)):
                    self._comp_bytes_coll(cm, mult, bytes_acc, coll, visited + (comp,))
                continue
            opbase = ins.opcode.replace("-start", "").replace("-done", "")
            if opbase in COLLECTIVES and not ins.opcode.endswith("-done"):
                coll[opbase] += mult * shape_bytes(ins.shape)
                coll["count_" + opbase] += mult
            if ins.opcode in _SKIP_BYTES:
                continue
            bytes_acc[0] += mult * self._instr_bytes(comp, ins)

    def analyze(self) -> dict:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        flops = self.comp_flops(self.entry)
        bytes_acc = [0.0]
        coll: dict = defaultdict(float)
        self._comp_bytes_coll(self.entry, 1.0, bytes_acc, coll, ())
        coll_total = sum(v for k, v in coll.items() if not k.startswith("count_"))
        return {
            "flops": flops,
            "bytes": bytes_acc[0],
            "coll_bytes": coll_total,
            "coll_detail": dict(coll),
        }


def analyze_hlo(text: str) -> dict:
    return HloCost(text).analyze()


def top_bytes(text: str, n: int = 20) -> list[tuple[float, str, str]]:
    """Debug: the n largest (bytes x trip-mult) instructions — the
    hypothesis-generation tool of the §Perf loop."""
    hc = HloCost(text)
    rows: list[tuple[float, str, str]] = []

    def walk(comp: str, mult: float, visited: tuple):
        if comp in visited:
            return
        for ins in hc.comps.get(comp, []):
            if ins.opcode == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                trip = hc.trip_count(cond.group(1)) if cond else 1
                if body:
                    walk(body.group(1), mult * trip, visited + (comp,))
                continue
            if ins.opcode in ("call", "conditional"):
                for cm in set(_CALLS_RE.findall(ins.rest)):
                    walk(cm, mult, visited + (comp,))
                continue
            if ins.opcode in _SKIP_BYTES:
                continue
            b = hc._instr_bytes(comp, ins) * mult
            rows.append((b, ins.opcode, f"{comp}/{ins.name} {ins.shape[:60]} x{mult:g}"))

    walk(hc.entry, 1.0, ())
    rows.sort(reverse=True)
    return rows[:n]
