"""Production mesh construction (assignment §MULTI-POD DRY-RUN).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""

from __future__ import annotations

import jax

AXES_SINGLE = ("data", "model")
AXES_MULTI = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = AXES_MULTI if multi_pod else AXES_SINGLE
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=AXES_SINGLE):
    """Small host-device mesh for tests (XLA_FLAGS device_count >= prod(shape))."""
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def use_mesh(mesh):
    """Version-compatible mesh context: ``with use_mesh(mesh): ...``.

    ``jax.set_mesh`` landed after 0.4.x (and ``jax.sharding.use_mesh``
    before that); on older installs entering the ``Mesh`` itself sets the
    resource env, which is all the dry-run/compile paths need."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh
