"""Training launcher: step construction + fault-tolerant supervision loop.

``make_train_step`` builds the pjit-able step (loss -> grads -> optional int8
gradient compression -> AdamW). ``TrainLoop`` wraps it with checkpointing,
restart-on-failure, and straggler detection — the parts that make the system
runnable on a real multi-pod cluster (deliverable: fault tolerance).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.registry import get_model
from repro.optim import AdamW
from repro.optim.grad_compression import compress_grads_int8, decompress_grads_int8


def make_train_step(cfg: ModelConfig, opt: AdamW, grad_compress: bool = False) -> Callable:
    model = get_model(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, cfg, batch))(params)
        if grad_compress:
            # int8 EF compression of the DP gradient reduction (the psum is
            # implicit in SPMD; compressing before the reduce shrinks the
            # all-reduce payload 4x — the collective term of the roofline)
            ef = opt_state[1]
            q, s, ef = compress_grads_int8(grads, ef)
            grads = decompress_grads_int8(q, s)
            adam_state, _ = opt_state
            new_params, adam_state = opt.update(grads, adam_state, params)
            return new_params, (adam_state, ef), loss
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    return train_step


def init_train_state(cfg: ModelConfig, opt: AdamW, key, grad_compress: bool = False):
    model = get_model(cfg)
    params = model.init_params(cfg, key)
    opt_state = opt.init(params)
    if grad_compress:
        ef = jax.tree.map(jnp.zeros_like, params)
        return params, (opt_state, ef)
    return params, opt_state


# ---------------------------------------------------------------------------
# supervision loop: checkpoint/restart + straggler monitoring
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than ``threshold`` x EWMA.

    On a real cluster the flag feeds preemption/rescheduling; here it is the
    hook point (and is unit-tested with injected delays)."""

    alpha: float = 0.1
    threshold: float = 2.0
    ewma: Optional[float] = None
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ewma is not None and dt > self.threshold * self.ewma
        self.ewma = dt if self.ewma is None else (1 - self.alpha) * self.ewma + self.alpha * dt
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


class TrainLoop:
    """Fault-tolerant training driver.

    * periodic async checkpoints (manager handles atomic publish/retention)
    * on step failure: restore latest checkpoint and continue (max_restarts)
    * data pipeline is resumed deterministically from the checkpointed step
    """

    def __init__(self, cfg: ModelConfig, step_fn, ckpt_manager, data_iter_factory,
                 ckpt_every: int = 100, max_restarts: int = 3,
                 monitor: Optional[StragglerMonitor] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.data_iter_factory = data_iter_factory
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.monitor = monitor or StragglerMonitor()
        self.restarts = 0

    def run(self, params, opt_state, start_step: int, num_steps: int,
            fail_injector: Optional[Callable[[int], None]] = None):
        """Returns (params, opt_state, losses, end_step)."""
        step = start_step
        losses = []
        data = self.data_iter_factory(step)
        while step < num_steps:
            try:
                batch = next(data)
                if fail_injector is not None:
                    fail_injector(step)
                t0 = time.monotonic()
                params, opt_state, loss = self.step_fn(params, opt_state, batch)
                jax.block_until_ready(loss)
                self.monitor.observe(step, time.monotonic() - t0)
                losses.append(float(loss))
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, {"params": params, "opt": opt_state})
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                template = {"params": params, "opt": opt_state}
                restored = self.ckpt.restore_latest(like=template)
                if restored is None:
                    # no checkpoint yet: restart from the initial state
                    data = self.data_iter_factory(start_step)
                    step = start_step
                    continue
                step, state = restored
                params, opt_state = state["params"], state["opt"]
                data = self.data_iter_factory(step)
        self.ckpt.save(step, {"params": params, "opt": opt_state})
        self.ckpt.wait()
        return params, opt_state, losses, step
