"""Sharding rules: param-path -> PartitionSpec, per architecture family.

Conventions (Megatron/MaxText-style, see DESIGN.md §5):

* ``fsdp`` axes shard a weight's *contraction-adjacent* dim (ZeRO-3); XLA
  SPMD inserts the all-gathers.
* ``model`` (TP) shards attention heads / MLP hidden / experts / vocab.
* Activations: batch over dp axes; hidden dim unsharded between blocks
  (sequence-parallel resharding is an option flag used by the perf loop).
* Quantized packs inherit the spec of the bf16 weight they replace (packed
  rows halve K — same axis mapping).

Rules are (regex over the '/'-joined param path, spec-builder) pairs; first
match wins; default replicate. This table IS the parallelism layout of the
framework — the dry-run and the real launcher share it.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.models.context import MeshContext

__all__ = ["param_specs", "batch_specs", "decode_state_specs", "make_shardings"]


def _rules(cfg: ModelConfig, ctx: MeshContext):
    f = tuple(ctx.fsdp_axes) or None  # fsdp axes (e.g. ("data",)) or replicate
    m = ctx.tp_axis  # "model"
    e = ctx.ep_axis

    def last2(spec_in, spec_out):
        """Spec for a (possibly layer-stacked) matrix: leading dims None."""

        def build(shape):
            lead = (None,) * (len(shape) - 2)
            return P(*lead, spec_in, spec_out)

        return build

    def lastn(*specs):
        def build(shape):
            lead = (None,) * (len(shape) - len(specs))
            return P(*lead, *specs)

        return build

    def vec(spec):
        def build(shape):
            lead = (None,) * (len(shape) - 1)
            return P(*lead, spec)

        return build

    R = [
        # --- embeddings / heads: vocab over model, feature over fsdp
        (r"embed$", lambda s: P(m, f)),
        (r"head/w$", last2(f, m)),
        # --- attention (dense/GQA, whisper, zamba shared, xlstm-free)
        (r"attn/[qkv]/w$", last2(f, m)),
        (r"attn/[qkv]/b$", vec(m)),
        (r"attn/o/w$", last2(m, f)),
        (r"xattn/[qkv]/w$", last2(f, m)),
        (r"xattn/o/w$", last2(m, f)),
        (r"shared/[qkv]/w$", last2(f, m)),
        (r"shared/o/w$", last2(m, f)),
        # --- MLA projections
        (r"wq_a/w$", last2(f, None)),
        (r"wq_b/w$", last2(f, m)),
        (r"wkv_a/w$", last2(f, None)),
        (r"wkv_b/w$", last2(f, m)),
        # --- MLP (dense & shared-expert & whisper gelu & slstm ffn)
        (r"(mlp|ffn|shared/mlp|moe/shared)/(gate|up)/w$", last2(f, m)),
        (r"(mlp|ffn|shared/mlp|moe/shared)/(gate|up)/b$", vec(m)),
        (r"(mlp|ffn|shared/mlp|moe/shared)/down/w$", last2(m, f)),
        # --- MoE experts: E over ep, then D over fsdp (gathered in-shard)
        (r"moe/(gate|up)/w$", lastn(e, f, None)),
        (r"moe/down/w$", lastn(e, None, f)),
        (r"moe/router$", lastn(None, None)),
        # --- quantized packs inherit their parent linear's layout
        (r"attn/[qkv]/(rp|rs|up|us)$", last2(f, m)),
        (r"attn/[qkv]/(vp|vs)$", last2(None, m)),
        (r"attn/o/(rp|rs|up|us)$", last2(m, f)),
        (r"attn/o/(vp|vs)$", last2(None, f)),
        (r"(mlp|ffn)/(gate|up)/(rp|rs|up|us)$", last2(f, m)),
        (r"(mlp|ffn)/(gate|up)/(vp|vs)$", last2(None, m)),
        (r"(mlp|ffn)/down/(rp|rs|up|us)$", last2(m, f)),
        (r"(mlp|ffn)/down/(vp|vs)$", last2(None, f)),
        (r"abits$", lambda s: P()),
        # --- xLSTM
        (r"m_layers/(up|wq|wk|wv)/w$", last2(f, m)),
        (r"m_layers/wif/w$", last2(f, None)),
        (r"m_layers/down/w$", last2(m, f)),
        (r"m_layers/conv$", vec(m)),
        # --- Mamba2
        (r"m_layers/in_proj/w$", last2(f, None)),
        (r"(m_layers|rest_layers)/out_proj/w$", last2(m, f)),
        (r"(m_layers|rest_layers)/conv$", vec(None)),
        (r"rest_layers/in_proj/w$", last2(f, None)),
        # --- sLSTM recurrence: heads over model
        (r"s_layers/w/w$", last2(f, None)),
        (r"s_layers/r$", lastn(m, None, None)),
        # --- zamba adapters
        (r"adapters/w$", last2(m, f)),
        # --- MTP
        (r"mtp/proj/w$", last2(f, m)),
    ]
    return [(re.compile(pat), fn) for pat, fn in R]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(getattr(p, "idx", p)))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, params_tree, ctx: MeshContext):
    """Pytree of PartitionSpec matching params_tree (arrays or SDS)."""
    rules = _rules(cfg, ctx)

    def spec_for(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        for pat, fn in rules:
            if pat.search(s):
                spec = fn(shape)
                return _fit(spec, shape, ctx)
        return P()  # replicate (norms, scalars, gates, biases)

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def _axis_size(ctx: MeshContext, axes) -> int:
    if axes is None or ctx.mesh is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= ctx.mesh.shape[a]
    return n


def _fit(spec: P, shape, ctx: MeshContext) -> P:
    """Drop axis assignments that don't divide the dim (keeps XLA from
    padding tiny dims like kv-head counts below the axis size)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
        elif dim % _axis_size(ctx, ax) == 0 and dim >= _axis_size(ctx, ax):
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def opt_state_specs(cfg: ModelConfig, params_tree, pspecs, ctx: MeshContext):
    """ZeRO-1 option: when params are replicated (small models, fsdp off),
    still shard the f32 Adam moments over the dp axes (largest divisible
    dim) — they are 4x the param bytes and dominate replicated-state HBM."""
    dp = tuple(ctx.dp_axes) or None

    def spec_for(leaf, pspec):
        if any(ax is not None for ax in tuple(pspec)):
            return pspec  # follow the param sharding (ZeRO-3)
        shape = leaf.shape
        for i, dim in enumerate(shape):
            if dp and dim % _axis_size(ctx, dp) == 0 and dim >= _axis_size(ctx, dp):
                spec = [None] * len(shape)
                spec[i] = dp
                return P(*spec)
        return P()

    return jax.tree.map(spec_for, params_tree, pspecs)


def batch_specs(cfg: ModelConfig, batch_tree, ctx: MeshContext):
    """Batch inputs: leading batch dim over dp axes (dropped when the batch
    doesn't divide, e.g. long_500k's batch=1)."""
    dp = tuple(ctx.dp_axes) or None

    def spec_for(path, leaf):
        lead = (None,) * (len(leaf.shape) - 1)
        return _fit(P(dp, *lead), leaf.shape, ctx)

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)


def decode_state_specs(cfg: ModelConfig, state_tree, ctx: MeshContext, *,
                       seq_shard: bool = False):
    """KV caches / recurrent states.

    Layout: (L, B, S, KV, hd) caches -> batch over dp; kv-heads over model if
    they divide, else the sequence dim; ``seq_shard=True`` (long_500k,
    batch < dp size) shards S over (dp + model) instead.
    """
    dp = tuple(ctx.dp_axes) or None
    m = ctx.tp_axis

    def spec_for(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        if s.endswith("pos"):
            return P()
        if "shared_" in s or s.endswith(("k", "v", "xk", "xv")) and len(shape) == 5:
            # (L, B, S, KV, hd)
            if seq_shard:
                spec = P(None, None, tuple(ctx.dp_axes) + ((m,) if m else ()), None, None)
                return _fit(spec, shape, ctx)
            kv = shape[3]
            if m and kv % _axis_size(ctx, m) == 0:
                return _fit(P(None, dp, None, m, None), shape, ctx)
            return _fit(P(None, dp, m, None, None), shape, ctx)
        if s.endswith(("ckv", "krope")) and len(shape) == 4:
            # MLA latent cache (L, B, S, r): batch over dp, seq over model
            if seq_shard:
                spec = P(None, None, tuple(ctx.dp_axes) + ((m,) if m else ()), None)
                return _fit(spec, shape, ctx)
            return _fit(P(None, dp, m, None), shape, ctx)
        if s.endswith(("mC", "mn", "mm")):
            # xlstm matrix state (..., B, H, dh[, dh]): batch dp, value dim model
            idx = len(shape) - (4 if s.endswith("mC") else (3 if s.endswith("mn") else 2))
            spec = [None] * len(shape)
            spec[idx] = dp
            if s.endswith("mC") and m:
                spec[-1] = m
            return _fit(P(*spec), shape, ctx)
        if s.endswith("ssm") or s.endswith("ssm_rest"):
            # (L..., B, H, P, N): batch dp, ssm heads over model
            spec = [None] * len(shape)
            spec[-4] = dp
            spec[-3] = m
            return _fit(P(*spec), shape, ctx)
        if s.endswith(("conv", "conv_rest")):
            spec = [None] * len(shape)
            spec[-3] = dp
            return _fit(P(*spec), shape, ctx)
        if len(shape) >= 2:
            spec = [None] * len(shape)
            spec[-2] = dp  # (L?, B, D) recurrent vectors: batch dim heuristic
            if s.startswith(("sh", "sc", "sn", "sm")) or "/s" in s:
                spec = [None] * len(shape)
                spec[-2] = dp
            return _fit(P(*spec), shape, ctx)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, state_tree)


def make_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
