"""SLO-facing latency metrics for the serving engine (docs/serving.md
"SLO metrics & traffic harness").

The engine stamps wall-clock times on every :class:`~repro.launch.serve.
Request` it touches — ``t_submit`` at ``submit()``, ``t_first_token`` and one
``token_times`` entry per emitted token inside ``_emit_token``, ``t_done`` at
``_finish`` — so every number here is MEASURED at the emission site, not
inferred from aggregate counters. :func:`summarize` turns a set of finished
(or in-flight) requests into the tail-latency summary the bench gate and
``engine.latency()`` expose:

- **TTFT** (time to first token): ``t_first_token - t_submit``, the number a
  chat user feels. Queue wait is included by construction — a request that
  sat behind a long prompt pays for it here.
- **TPOT** (time per output token): inter-token gaps within one request's
  ``token_times``. Pooled across requests so p99 captures the worst gap
  anywhere in the run (a preemption or a long admission chunk shows up as a
  fat TPOT tail, not a hidden mean shift).
- **E2E**: ``t_done - t_submit`` for terminal requests.
- **goodput under SLO**: tokens from DONE requests that met the SLO, divided
  by the wall span of the run — throughput that served somebody on time.
  Tokens generated for requests that blew their deadline count for nothing.
- **queue depth / preemption / prefix-hit**: load-shape context for the
  latency numbers, straight from the engine's step samples and counters.

Stateless and engine-agnostic on purpose: anything that records the same
stamps on its request objects can be summarized, which is what lets the
bench compare bucketed / ragged / speculative configurations side by side.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.launch.serve import Request, RequestState


@dataclasses.dataclass(frozen=True)
class SLO:
    """A service-level objective: per-request latency bounds.

    A DONE request *meets* the SLO when its TTFT is at most ``ttft_s``
    seconds AND its mean time-per-output-token is at most ``tpot_s``
    seconds. Requests that exit FAILED / CANCELLED / TIMED_OUT never meet
    it regardless of speed — an answer that never arrived has no latency.
    Defaults are deliberately loose (interactive-chat scale); benches pin
    their own."""

    ttft_s: float = 1.0
    tpot_s: float = 0.1


def percentiles(xs: Iterable[float]) -> dict:
    """``{"p50", "p95", "p99", "mean", "max", "n"}`` of a sample, in the
    sample's own units. Empty samples yield zeros (JSON-stable) with
    ``n == 0`` so a consumer can tell "fast" from "absent"."""
    arr = np.asarray(list(xs), dtype=np.float64)
    if arr.size == 0:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0, "n": 0}
    p50, p95, p99 = np.percentile(arr, [50.0, 95.0, 99.0])
    return {
        "p50": float(p50),
        "p95": float(p95),
        "p99": float(p99),
        "mean": float(arr.mean()),
        "max": float(arr.max()),
        "n": int(arr.size),
    }


def request_ttft_s(req: Request) -> Optional[float]:
    """Seconds from submit to first emitted token; None before either stamp
    exists (a request that never produced a token has no TTFT)."""
    if req.t_submit is None or req.t_first_token is None:
        return None
    return req.t_first_token - req.t_submit


def request_tpot_s(req: Request) -> list[float]:
    """Inter-token gaps (seconds) within one request's emission trace —
    empty for requests with fewer than two tokens."""
    ts = req.token_times
    return [ts[i + 1] - ts[i] for i in range(len(ts) - 1)]


def meets_slo(req: Request, slo: SLO) -> bool:
    """Whether a request counts toward goodput under ``slo``: it finished
    DONE, its TTFT is within ``slo.ttft_s``, and its mean per-token gap is
    within ``slo.tpot_s`` (single-token requests have no gaps and pass the
    TPOT bound vacuously)."""
    if req.status != RequestState.DONE:
        return False
    ttft = request_ttft_s(req)
    if ttft is None or ttft > slo.ttft_s:
        return False
    gaps = request_tpot_s(req)
    return not gaps or float(np.mean(gaps)) <= slo.tpot_s


def summarize(
    requests: Sequence[Request],
    *,
    slo: Optional[SLO] = None,
    queue_depths: Sequence[int] = (),
    stats: Optional[dict] = None,
) -> dict:
    """The latency/SLO summary dict (``engine.latency()``, BENCH_SLO.json).

    ``requests`` is every request the run touched (terminal or not);
    ``queue_depths`` is the engine's per-step queue-depth samples and
    ``stats`` its counter dict (for preemption / prefix-hit rates). With
    ``slo=None`` the goodput denominator still runs but every DONE request
    qualifies — goodput degenerates to completed-token throughput and
    ``slo_met_rate`` to the completion rate, which keeps the dict's shape
    (and the CI presence gate) identical with and without an objective."""
    stats = stats or {}
    ttfts = [t for r in requests if (t := request_ttft_s(r)) is not None]
    tpots = [g for r in requests for g in request_tpot_s(r)]
    e2es = [
        r.t_done - r.t_submit
        for r in requests
        if r.t_done is not None and r.t_submit is not None
    ]
    done = [r for r in requests if r.status == RequestState.DONE]
    met = [r for r in done if slo is None or meets_slo(r, slo)]
    t0 = min((r.t_submit for r in requests if r.t_submit is not None), default=None)
    t1 = max((r.t_done for r in requests if r.t_done is not None), default=None)
    span_s = (t1 - t0) if (t0 is not None and t1 is not None) else 0.0
    qd = np.asarray(list(queue_depths), dtype=np.float64)
    return {
        "n_requests": len(requests),
        "n_done": len(done),
        "n_slo_met": len(met),
        "slo": None if slo is None else dataclasses.asdict(slo),
        "slo_met_rate": len(met) / max(len(requests), 1),
        "goodput_tok_s": sum(len(r.out) for r in met) / max(span_s, 1e-9),
        "span_s": span_s,
        "ttft_ms": percentiles(t * 1e3 for t in ttfts),
        "tpot_ms": percentiles(g * 1e3 for g in tpots),
        "e2e_ms": percentiles(t * 1e3 for t in e2es),
        "queue_depth_mean": float(qd.mean()) if qd.size else 0.0,
        "queue_depth_max": int(qd.max()) if qd.size else 0,
        "preemption_rate": stats.get("requests_preempted", 0) / max(len(requests), 1),
        "prefix_hit_rate": (
            stats.get("prefix_hits", 0) / max(stats.get("prefix_lookups", 0), 1)
        ),
    }
