"""Trace-driven traffic generation for the serving engine (docs/serving.md
"SLO metrics & traffic harness").

A workload is a *deterministic function of its seed*: arrival times, scenario
mix, prompt contents, priorities and deadlines all come from one
``np.random.default_rng(seed)`` stream, so building the same workload twice
yields request-for-request identical traffic. That determinism is the whole
point — the SLO bench replays a workload through the engine under test, then
rebuilds it from the same seed and replays each request alone through the
solo oracle, and gates on EXACT token equality between the two.

Arrival times are in ENGINE STEPS, not wall-clock seconds: :func:`replay`
submits a request the moment the step counter reaches its ``at`` and drives
``engine.step()`` in between. Step-clocked arrivals keep the schedule (and
therefore every token stream) reproducible on any machine; wall-clock stamps
for TTFT/TPOT are still recorded per emission, so latency numbers stay real
while the *traffic* stays deterministic. For the same reason scenarios use
``deadline_steps`` (step-clocked) rather than ``deadline_s``.

Two arrival processes:

- :func:`poisson_arrivals` — seeded exponential inter-arrival gaps (the
  classic open-loop load model), with per-scenario burst clustering layered
  on top (a burst scenario lands ``burst`` requests on one step).
- a replayed trace — pass ``trace=[0, 3, 3, 17, ...]`` to
  :func:`make_workload` and those step numbers are used verbatim, so a
  production arrival log can be replayed against any engine configuration.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.launch.serve import Request


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One traffic class in the mix.

    ``weight`` is the relative draw probability; ``prompt_len`` / ``max_new``
    are inclusive ``(lo, hi)`` ranges sampled per request;
    ``shared_prefix_len`` prepends a prefix common to every request of this
    scenario (page-align it to the engine's ``page_size`` so the prefix
    cache can serve it); ``burst`` clusters that many requests onto one
    arrival step (short-query fan-out); ``priority`` / ``deadline_steps``
    ride onto the Request so the lifecycle machinery (preemption ordering,
    deadline expiry) is exercised by the mix itself."""

    name: str
    weight: float
    prompt_len: tuple[int, int]
    max_new: tuple[int, int]
    priority: int = 0
    deadline_steps: Optional[int] = None
    shared_prefix_len: int = 0
    burst: int = 1


def default_scenarios(page_size: int = 8) -> list[Scenario]:
    """The three-way production mix the SLO bench runs (ISSUE 10): chat
    turns behind one shared system prompt (3 pages — the prefix-cache hit
    path), long-document summarization (the chunked-prefill path), and
    short bursty queries at top priority with a step deadline (the
    preemption / deadline path). Prompt lengths are sized for the tiny
    bench configs; scale them up for real models."""
    return [
        Scenario(
            name="chat",
            weight=0.5,
            prompt_len=(4, 12),
            max_new=(6, 12),
            priority=1,
            shared_prefix_len=3 * page_size,
        ),
        Scenario(
            name="summarize",
            weight=0.25,
            prompt_len=(40, 56),
            max_new=(8, 16),
            priority=0,
        ),
        Scenario(
            name="burst",
            weight=0.25,
            prompt_len=(4, 8),
            max_new=(4, 8),
            priority=2,
            deadline_steps=600,
            burst=3,
        ),
    ]


@dataclasses.dataclass(frozen=True)
class WorkloadItem:
    """One scheduled arrival: submit ``request`` when the engine-step
    counter reaches ``at``. ``scenario`` names the traffic class it was
    drawn from (for per-class reporting)."""

    at: int
    scenario: str
    request: Request


@dataclasses.dataclass
class Workload:
    """A fully materialized traffic trace: ``items`` in arrival order.
    Rebuilding with :func:`make_workload` from the same ``seed`` (and the
    same scenario list / knobs) reproduces it exactly — requests included."""

    seed: int
    items: list[WorkloadItem]

    @property
    def requests(self) -> list[Request]:
        """The item requests in arrival order (results ride on these after
        :func:`replay`)."""
        return [it.request for it in self.items]


def poisson_arrivals(rng: np.random.Generator, n: int, mean_gap_steps: float) -> list[int]:
    """``n`` arrival steps with exponential inter-arrival gaps of mean
    ``mean_gap_steps`` engine steps (a seeded open-loop Poisson process),
    floored to integer steps starting at 0."""
    gaps = rng.exponential(mean_gap_steps, size=n)
    return [int(t) for t in np.floor(np.cumsum(gaps) - gaps[0])] if n else []


def make_workload(
    seed: int,
    *,
    n_requests: int = 12,
    mean_gap_steps: float = 4.0,
    scenarios: Optional[Sequence[Scenario]] = None,
    vocab: int = 256,
    trace: Optional[Sequence[int]] = None,
) -> Workload:
    """Materialize a deterministic workload from ``seed``.

    Draws ``n_requests`` scenario assignments (weight-proportional), lays
    them on Poisson arrivals of mean ``mean_gap_steps`` — or on ``trace``
    verbatim when given (replayed-trace mode; its length caps the request
    count) — then expands burst scenarios into clusters sharing one arrival
    step. Prompt token ids are drawn in ``[1, vocab)`` (0 stays free for
    padding conventions); each scenario's shared prefix is drawn once and
    prepended to all of its requests."""
    rng = np.random.default_rng(seed)
    scenarios = list(default_scenarios() if scenarios is None else scenarios)
    # shared prefixes first, in scenario order, so the draw sequence (and
    # therefore every downstream sample) is fixed by (seed, scenario list)
    prefixes = {
        s.name: rng.integers(1, vocab, size=s.shared_prefix_len, dtype=np.int32)
        for s in scenarios
    }
    weights = np.asarray([s.weight for s in scenarios], dtype=np.float64)
    weights = weights / weights.sum()
    picks = rng.choice(len(scenarios), size=n_requests, p=weights)
    if trace is not None:
        arrivals = [int(t) for t in trace]
        picks = picks[: len(arrivals)]
    else:
        arrivals = poisson_arrivals(rng, n_requests, mean_gap_steps)
    items: list[WorkloadItem] = []
    for at, pick in zip(arrivals, picks):
        s = scenarios[int(pick)]
        for _ in range(max(1, s.burst)):
            tail = rng.integers(
                1, vocab,
                size=int(rng.integers(s.prompt_len[0], s.prompt_len[1] + 1)),
                dtype=np.int32,
            )
            prompt = np.concatenate([prefixes[s.name], tail])
            items.append(WorkloadItem(
                at=at,
                scenario=s.name,
                request=Request(
                    prompt=prompt,
                    max_new=int(rng.integers(s.max_new[0], s.max_new[1] + 1)),
                    priority=s.priority,
                    deadline_steps=s.deadline_steps,
                ),
            ))
    items.sort(key=lambda it: it.at)  # stable: ties keep draw order
    return Workload(seed=seed, items=items)


def replay(engine, workload: Workload, *, max_steps: int = 100_000) -> list[Request]:
    """Drive ``workload`` through ``engine`` on the step clock.

    Submits each item the moment the step counter reaches its ``at``
    (idle gaps still advance the clock — open-loop load does not wait for
    the engine), steps once per tick, then drains the tail with
    ``run_until_done`` so a wedged engine surfaces as
    :class:`~repro.launch.serve.EngineStalledError` rather than a silent
    partial replay. Returns the workload's requests; results (tokens,
    stamps, terminal states) ride on them."""
    i, step = 0, 0
    items = workload.items
    while i < len(items) and step < max_steps:
        while i < len(items) and items[i].at <= step:
            engine.submit(items[i].request)
            i += 1
        engine.step()
        step += 1
    engine.run_until_done(max_steps)
    return workload.requests
