import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  (the device-count flag must precede every jax import)
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, ModelConfig, QuantSpec, get_config
from repro.core.twinquant import quantize_params
from repro.launch.mesh import dp_axes, make_production_mesh, use_mesh
from repro.launch.roofline import Roofline
from repro.launch.sharding import batch_specs, decode_state_specs, make_shardings, param_specs
from repro.launch.train import make_train_step
from repro.models.context import MeshContext, set_mesh_context
from repro.models.registry import SHAPE_SETS, applicable_shapes, get_model, input_specs
from repro.optim import AdamW

"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

For every (architecture x input-shape x mesh) cell:
  jit(step).lower(**ShapeDtypeStructs).compile()
on the 16x16 (=256 chip) production mesh and the 2x16x16 (=512 chip)
multi-pod mesh, printing memory_analysis() (fits-per-device proof) and
cost_analysis() (FLOPs/bytes for §Roofline). Results land in
artifacts/dryrun/<cell>.json for launch/roofline.py + EXPERIMENTS.md.
"""


def _mesh_ctx(cfg: ModelConfig, mesh) -> MeshContext:
    dps = dp_axes(mesh)
    # FSDP policy (§Perf cell A iteration 4): ZeRO-3 param sharding forces
    # per-layer all-gathers in fwd AND bwd; for models whose bf16 params fit
    # replicated (<= ~4 GB/chip) we replicate params and ZeRO-1-shard only
    # the f32 Adam moments (see sharding.opt_state_specs).
    params_bytes = cfg.total_params() * 2
    fsdp = dps if params_bytes > 4e9 * 1 else ()
    return MeshContext(
        mesh=mesh,
        dp_axes=dps,
        tp_axis="model",
        ep_axis="model" if cfg.n_experts else None,
        fsdp_axes=fsdp,
    )


def _model_flops(cfg: ModelConfig, shape_name: str) -> float:
    spec = SHAPE_SETS[shape_name]
    n_active = cfg.active_params()
    if spec["kind"] == "train":
        return 6.0 * n_active * spec["batch"] * spec["seq"]
    if spec["kind"] == "prefill":
        return 2.0 * n_active * spec["batch"] * spec["seq"]
    return 2.0 * n_active * spec["batch"]  # decode: one token per sequence


def _shape_tree_bytes(tree) -> float:
    return sum(
        float(jnp.prod(jnp.array(l.shape)) * l.dtype.itemsize) if l.shape else l.dtype.itemsize
        for l in jax.tree.leaves(tree)
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, quant: str,
             outdir: Path, verbose: bool = True) -> dict:
    cfg = get_config(arch, quant=QuantSpec(mode=quant))
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = _mesh_ctx(cfg, mesh)
    set_mesh_context(ctx)
    model = get_model(cfg)
    chips = mesh.size
    spec = SHAPE_SETS[shape_name]
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)

    t0 = time.monotonic()
    params_sds = jax.eval_shape(lambda k: model.init_params(cfg, k), key_sds)
    if quant != "bf16" and spec["kind"] != "train":
        params_sds = jax.eval_shape(lambda p: quantize_params(p, cfg, cfg.quant), params_sds)
    pspecs = param_specs(cfg, params_sds, ctx)
    pshard = make_shardings(mesh, pspecs)
    batch_sds = input_specs(cfg, shape_name)
    bspecs = batch_specs(cfg, batch_sds, ctx)
    bshard = make_shardings(mesh, bspecs)

    with use_mesh(mesh):
        if spec["kind"] == "train":
            opt = AdamW(moment_dtype=jnp.bfloat16 if "671b" in arch else jnp.float32)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            from repro.launch.sharding import opt_state_specs

            mspecs = opt_state_specs(cfg, params_sds, pspecs, ctx)
            ospecs = type(opt_sds)(mu=mspecs, nu=mspecs, count=P())
            oshard = make_shardings(mesh, ospecs)
            step = make_train_step(cfg, opt)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif spec["kind"] == "prefill":
            b = spec["batch"]
            # VLM prefill prepends n_patches stub embeddings to the sequence
            max_len = spec["seq"] + (cfg.n_patches if cfg.family == "vlm" else 0)
            state_sds = jax.eval_shape(
                lambda: model.init_decode_state(cfg, b, max_len)
            )
            sspecs = decode_state_specs(cfg, state_sds, ctx)
            sshard = make_shardings(mesh, sspecs)
            tokens = batch_sds.pop("tokens")
            tshard = bshard.pop("tokens")
            fr_key = next(iter(batch_sds), None)  # patches / frames if any

            if fr_key is None:
                def prefill_step(params, tokens, state):
                    return model.prefill(params, cfg, tokens, state)

                jitted = jax.jit(
                    prefill_step,
                    in_shardings=(pshard, tshard, sshard),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(params_sds, tokens, state_sds)
            else:
                def prefill_step(params, tokens, state, fr):
                    return model.prefill(params, cfg, tokens, state, **{fr_key: fr})

                jitted = jax.jit(
                    prefill_step,
                    in_shardings=(pshard, tshard, sshard, bshard[fr_key]),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(params_sds, tokens, state_sds, batch_sds[fr_key])
        else:  # decode
            b = spec["batch"]
            long_ctx = shape_name.startswith("long")
            state_sds = jax.eval_shape(
                lambda: model.init_decode_state(cfg, b, spec["seq"])
            )
            sspecs = decode_state_specs(cfg, state_sds, ctx, seq_shard=long_ctx)
            sshard = make_shardings(mesh, sspecs)
            tokens = batch_sds["tokens"]
            tshard = bshard["tokens"]

            def decode_step(params, state, tokens):
                return model.decode_step(params, cfg, state, tokens)

            jitted = jax.jit(
                decode_step,
                in_shardings=(pshard, sshard, tshard),
                out_shardings=None,
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_sds, state_sds, tokens)

        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    mem_fields = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes",
              "alias_size_in_bytes", "generated_code_size_in_bytes"):
        mem_fields[f] = getattr(mem, f, None)
    print(f"[{arch} | {shape_name} | {'multi' if multi_pod else 'single'} | {quant}] "
          f"memory_analysis: {mem_fields}")
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost_fields = {k: float(v) for k, v in cost.items()
                   if isinstance(v, (int, float)) and k in ("flops", "bytes accessed",
                   "bytes accessed0{}", "bytes accessed1{}", "bytes accessedout{}",
                   "optimal_seconds", "transcendentals")}
    print(f"  cost_analysis: flops={cost_fields.get('flops', 0):.3e} "
          f"bytes={cost_fields.get('bytes accessed', 0):.3e}")

    from repro.launch.hlo_cost import analyze_hlo

    hc = analyze_hlo(compiled.as_text())
    rf = Roofline(flops=hc["flops"], hbm_bytes=hc["bytes"],
                  coll_bytes=hc["coll_bytes"], chips=chips,
                  model_flops=_model_flops(cfg, shape_name))
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "quant": quant,
        "status": "ok",
        "t_lower_s": t_lower,
        "t_compile_s": t_compile,
        "memory_analysis": mem_fields,
        "param_bytes_global": _shape_tree_bytes(params_sds),
        "cost_xla_raw": cost_fields,  # XLA's scan-body-once numbers, reference
        "hlo_cost": {k: v for k, v in hc.items() if k != "coll_detail"},
        "collectives": hc["coll_detail"],
        "roofline": rf.to_dict(),
    }
    outdir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}__{quant}.json"
    (outdir / fname).write_text(json.dumps(result, indent=2))
    if verbose:
        r = result["roofline"]
        print(f"  roofline: compute={r['t_compute_s']:.4f}s memory={r['t_memory_s']:.4f}s "
              f"collective={r['t_collective_s']:.4f}s dominant={r['dominant']}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="bf16", choices=["bf16", "w4a16", "w4a8", "w4a4"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS[:10] if args.arch == "all" else [args.arch]
    shapes = list(SHAPE_SETS) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    outdir = Path(args.out)

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        applicable = applicable_shapes(cfg)
        for shape in shapes:
            if applicable[shape] != "run":
                print(f"[{arch} | {shape}] SKIP: {applicable[shape]}")
                outdir.mkdir(parents=True, exist_ok=True)
                for mp in meshes:
                    fname = f"{arch}__{shape}__{'multi' if mp else 'single'}__{args.quant}.json"
                    (outdir / fname).write_text(json.dumps({
                        "arch": arch, "shape": shape, "quant": args.quant,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "skip", "reason": applicable[shape],
                    }, indent=2))
                continue
            for mp in meshes:
                fname = f"{arch}__{shape}__{'multi' if mp else 'single'}__{args.quant}.json"
                if args.skip_existing and (outdir / fname).exists():
                    existing = json.loads((outdir / fname).read_text())
                    if existing.get("status") == "ok":
                        print(f"[{arch} | {shape} | {fname}] exists, skipping")
                        continue
                try:
                    run_cell(arch, shape, mp, args.quant, outdir)
                except Exception as e:  # record failures; they are bugs to fix
                    traceback.print_exc()
                    failures.append((arch, shape, mp, str(e)))
                    (outdir / fname).write_text(json.dumps({
                        "arch": arch, "shape": shape, "quant": args.quant,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "fail", "error": str(e)[-2000:],
                    }, indent=2))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f[:3], f[3][:200])
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
