"""Seeded, deterministic fault injection for the serving engine.

:class:`FaultInjector` is the chaos half of the fault-tolerance contract
(docs/serving.md "Fault model & request lifecycle"): it perturbs exactly one
thing, at exactly one point, reproducibly — so ``tests/test_chaos.py`` can
assert the engine's recovery invariants (unaffected requests bit-identical
to a fault-free run, allocator audit green after every step) rather than
merely "it didn't crash". Faults on offer:

* :meth:`deny_alloc` — make ``PageAllocator.alloc`` report exhaustion at the
  Nth call (admission back-pressure / preemption trigger without actually
  shrinking the pool);
* :meth:`force_ref_dispatch` — flip every dispatch entry point onto its
  reference path (the degraded mode when a kernel backend is suspect);
* :meth:`tamper_pack` — return a params tree with ONE TwinQuant pack's
  ``rp`` truncated along K, so the next trace raises a ContractError
  (exercises the engine's quarantine-on-prefill-exception path);
* :meth:`corrupt_logits` — poison one slot's row of the downloaded logits at
  the Nth sync-point tap (exercises the finite-logits guard).

Every injection records a log entry and pushes an undo thunk;
:meth:`restore` (or exiting the ``with`` block) unwinds them LIFO, so a
failing test can never leak a fault into the next one.

All injection is host-side (allocator calls, the sync-point logits tap, the
params pytree before engine construction) — device executables are never
patched, which is what keeps the injected runs bit-comparable to clean ones.
"""

from __future__ import annotations

import copy
from typing import Any

import numpy as np

from repro.kernels import dispatch

# sibling keys the fusion pass may merge at engine construction
# (core.twinquant.FUSE_GROUPS): tampering one of THOSE packs would crash
# fuse_params before the engine even exists, which is a different failure
# than the mid-prefill ContractError the chaos suite wants to exercise
_FUSABLE_KEYS = frozenset(
    {"q", "k", "v", "gate", "up", "wq_a", "wkv_a", "qkv", "gate_up", "wqkv_a"}
)


def _is_pack(d: Any) -> bool:
    return isinstance(d, dict) and "rp" in d


class FaultInjector:
    """Deterministic, seeded fault injection with LIFO undo.

    Use as a context manager so faults can't outlive the test::

        with FaultInjector(seed=0) as fi:
            fi.deny_alloc(engine, at_call=3)
            engine.serve(requests)
    """

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.log: list[dict] = []
        self._undo: list = []

    # -- bookkeeping --------------------------------------------------------

    def _note(self, kind: str, **info) -> None:
        self.log.append({"kind": kind, **info})

    def restore(self) -> None:
        """Unwind every active injection, most recent first."""
        while self._undo:
            self._undo.pop()()

    def __enter__(self) -> "FaultInjector":
        return self

    def __exit__(self, *exc) -> None:
        self.restore()

    # -- faults -------------------------------------------------------------

    def deny_alloc(self, engine, at_call: int, count: int = 1) -> None:
        """Make the engine's ``PageAllocator.alloc`` report exhaustion
        (return None) for calls ``at_call .. at_call+count-1`` (1-based),
        counted from now. ``count=0`` denies every call from ``at_call`` on.
        The free list itself is untouched — this is pure back-pressure."""
        allocator = engine.allocator
        orig = allocator.alloc
        state = {"calls": 0}

        def flaky_alloc(n):
            state["calls"] += 1
            c = state["calls"]
            if c >= at_call and (count == 0 or c < at_call + count):
                self._note("deny_alloc", call=c, n=n)
                return None
            return orig(n)

        allocator.alloc = flaky_alloc

        def undo():
            allocator.alloc = orig

        self._undo.append(undo)

    def force_ref_dispatch(self) -> None:
        """Route every dispatch entry traced from now on to its reference
        path (``<kind>/ref[forced]``). Trace-time only: flip BEFORE building
        the engine under test (jit-cached executables keep their routes)."""
        prev = dispatch.set_force_ref(True)
        self._note("force_ref_dispatch", prev=prev)
        self._undo.append(lambda: dispatch.set_force_ref(prev))

    def tamper_pack(self, params) -> Any:
        """Return a deep copy of ``params`` with ONE TwinQuant pack's ``rp``
        truncated along its K axis — a malformed pack the dispatch contract
        layer rejects with a ContractError at the next trace. Only
        non-fusable packs (e.g. attention output, MLP down) are candidates,
        so the corruption surfaces inside engine prefill, not in the fusion
        pass at construction. The victim is chosen by the injector's rng."""
        tampered = copy.deepcopy(params)
        packs: list[tuple[str, dict]] = []

        def walk(tree, path):
            if isinstance(tree, dict):
                for key, sub in tree.items():
                    if _is_pack(sub) and key not in _FUSABLE_KEYS:
                        packs.append((f"{path}/{key}", sub))
                    elif isinstance(sub, dict):
                        walk(sub, f"{path}/{key}")

        walk(tampered, "")
        if not packs:
            raise ValueError("tamper_pack: no non-fusable TwinQuant pack in params")
        path, pack = packs[self.rng.integers(len(packs))]
        pack["rp"] = pack["rp"][..., :-1, :]
        self._note("tamper_pack", path=path, rp_shape=tuple(pack["rp"].shape))
        return tampered

    def corrupt_logits(self, slot: int, at_call: int = 1, tag: str = "decode",
                       value: float = float("nan")) -> None:
        """Poison slot ``slot``'s row of the downloaded logits at the Nth
        sync-point tap whose tag matches (``"prefill"`` / ``"decode"`` /
        ``"ragged"``; 1-based count). The array is copied before writing, so
        nothing upstream (device buffers, other rows' bytes) is touched —
        which is exactly why the rest of the batch must stay bit-identical."""
        from repro.models import common as C

        state = {"calls": 0}

        def tap(last, t):
            if t != tag:
                return last
            state["calls"] += 1
            if state["calls"] != at_call:
                return last
            self._note("corrupt_logits", slot=slot, tag=t, call=at_call)
            last = np.array(last, copy=True)
            if last.ndim == 1:
                last[:] = value
            else:
                last[slot, :] = value
            return last

        prev = C.set_logits_tap(tap)
        self._undo.append(lambda: C.set_logits_tap(prev))
