"""Data pipeline: byte-level tokenizer over a real in-repo text corpus,
deterministic sharded batching with exact step-resume (the fault-tolerance
contract: restoring step N reproduces the batches the failed run would have
seen).

The corpus is the repository's own source + docs (real, offline text). The
paper's calibration protocol (128 sequences x 2048 tokens from WikiText2)
maps onto :func:`calibration_batch` with the same sampling structure.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import numpy as np

PAD, BOS, EOS = 256, 257, 258
VOCAB = 260  # 256 bytes + specials, padded even


def load_corpus(root: Optional[str] = None, max_bytes: int = 8_000_000) -> np.ndarray:
    """Concatenate repo text files into a uint16 token array (byte-level)."""
    root_p = Path(root) if root else Path(__file__).resolve().parents[3]
    chunks = []
    total = 0
    exts = (".py", ".md", ".txt", ".toml", ".json")
    for p in sorted(root_p.rglob("*")):
        if p.suffix not in exts or not p.is_file() or "artifacts" in p.parts:
            continue
        try:
            b = p.read_bytes()
        except OSError:
            continue
        chunks.append(np.frombuffer(b, np.uint8).astype(np.uint16))
        chunks.append(np.array([EOS], np.uint16))
        total += len(b)
        if total > max_bytes:
            break
    if not chunks:
        raise FileNotFoundError(f"no corpus files under {root_p}")
    return np.concatenate(chunks)


@dataclasses.dataclass
class TokenDataset:
    """Deterministic LM batches over a flat token stream.

    Batch for step ``i`` is a pure function of (i, seed, shape) — resuming at
    step N after a failure replays exactly the stream the lost run saw.
    Multi-host: each host reads only its ``host_id`` slice of the batch.
    """

    tokens: np.ndarray
    batch: int
    seq: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    def __post_init__(self):
        assert self.batch % self.n_hosts == 0
        self._n = len(self.tokens)
        rng = np.random.default_rng(self.seed)
        self._offset = int(rng.integers(0, self._n))

    def batch_at(self, step: int) -> dict:
        b_loc = self.batch // self.n_hosts
        per_step = self.batch * self.seq
        out = np.empty((b_loc, self.seq), np.int32)
        for j in range(b_loc):
            row = self.host_id * b_loc + j
            start = (self._offset + step * per_step + row * self.seq) % self._n
            idx = (start + np.arange(self.seq)) % self._n
            out[j] = self.tokens[idx]
        return {"tokens": out, "labels": out.copy()}

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def calibration_batch(tokens: np.ndarray, n_samples: int = 128, seq: int = 2048,
                      seed: int = 0) -> np.ndarray:
    """The paper's calibration sampling: n random sequences of `seq` tokens."""
    rng = np.random.default_rng(seed)
    n = len(tokens)
    out = np.empty((n_samples, seq), np.int32)
    for i in range(n_samples):
        s = int(rng.integers(0, n - seq - 1))
        out[i] = tokens[s : s + seq]
    return out
