"""CLI: ``python -m repro.analysis [paths...] [--json FILE] [--rules ...]``.

Exit status 0 when clean, 1 when any finding (or parse error) is reported —
the blocking contract the ``analyze`` CI lane relies on. ``--json`` writes
the machine-readable report CI uploads as an artifact; human output always
goes to stdout.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.core import all_rules, analyze_paths, render_human, render_json


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__
    )
    ap.add_argument(
        "paths", nargs="*", default=["src"], help="files or directories (default: src)"
    )
    ap.add_argument("--json", metavar="FILE", help="also write a JSON report")
    ap.add_argument(
        "--rules",
        metavar="IDS",
        help="comma-separated rule subset (default: all)",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog and exit"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, fn in sorted(all_rules().items()):
            doc = (fn.__doc__ or "").strip().splitlines()[0]
            print(f"{rid}  {doc}")
        return 0

    rules = args.rules.split(",") if args.rules else None
    unknown = set(rules or ()) - set(all_rules())
    if unknown:
        print(f"unknown rules: {sorted(unknown)}", file=sys.stderr)
        return 2

    findings, n_files = analyze_paths(args.paths, rules=rules)
    print(render_human(findings, n_files))
    if args.json:
        Path(args.json).write_text(render_json(findings, n_files))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
