"""Serving-engine hygiene rules (EN...): the per-token decode loop must not
hide host syncs or jit construction.

EN001 guards ``step`` methods of engine classes against device-to-host
transfers outside the explicit ``# sync-point`` allowlist (the convention in
``launch/serve.py``: a transfer that is PART of the serving design — the
logits download, the position read — carries the comment on its line; any
other transfer is an accidental pipeline stall). EN002 bans ``jax.jit``
construction inside step/prefill functions, where it would silently rebuild
an executable per call. EN003 requires engine methods that allocate pages to
release them on every exception path: an ``alloc`` call in a method with no
``try`` whose handler/finally releases (directly or via the eviction
helpers) leaks the reservation when admission throws mid-flight.
"""

from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleAliases, rule

__all__ = ["en001_decode_syncs", "en002_jit_in_step", "en003_alloc_release"]

SYNC_POINT_MARK = "# sync-point"

# device-to-host sync constructors EN001 polices inside step methods
_NP_SYNC_FNS = ("asarray", "array")
_ATTR_SYNC_FNS = ("item", "block_until_ready")

# function names whose bodies are per-call hot paths (EN002)
_STEP_FN_NAMES = (
    "step",
    "decode_step",
    "_decode_step",
    "prefill_step",
    "_prefill_step",
    "_run_prefill",
    "ragged_step",
    "_step_ragged",
)


def _line_allowlisted(src_lines: list[str], node: ast.AST) -> bool:
    for lineno in {node.lineno, getattr(node, "end_lineno", node.lineno)}:
        if lineno and lineno <= len(src_lines):
            if SYNC_POINT_MARK in src_lines[lineno - 1]:
                return True
    return False


@rule("EN001")
def en001_decode_syncs(tree: ast.AST, src: str, path: str) -> list[Finding]:
    """No ``np.asarray`` / ``np.array`` / ``.item()`` / ``block_until_ready``
    / ``jax.device_get`` in an engine's per-token ``step`` method (or any
    ``_step*`` variant, e.g. the ragged engine's ``_step_ragged``), outside
    lines explicitly marked ``# sync-point``. Every unmarked transfer is a
    hidden decode-loop stall."""
    aliases = ModuleAliases(tree)
    np_names = aliases.names_for("np")
    jax_names = aliases.names_for("jax")
    src_lines = src.splitlines()
    findings: list[Finding] = []

    for cls in ast.walk(tree):
        if not (isinstance(cls, ast.ClassDef) and "Engine" in cls.name):
            continue
        for meth in cls.body:
            if not (
                isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef))
                and (meth.name == "step" or meth.name.startswith("_step"))
            ):
                continue
            for node in ast.walk(meth):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                label = None
                if (
                    isinstance(f, ast.Attribute)
                    and f.attr in _NP_SYNC_FNS
                    and isinstance(f.value, ast.Name)
                    and f.value.id in np_names
                ):
                    label = f"{f.value.id}.{f.attr}(...)"
                elif isinstance(f, ast.Attribute) and f.attr in _ATTR_SYNC_FNS:
                    label = f".{f.attr}()"
                elif (
                    isinstance(f, ast.Attribute)
                    and f.attr == "device_get"
                    and isinstance(f.value, ast.Name)
                    and f.value.id in jax_names
                ):
                    label = "jax.device_get(...)"
                if label and not _line_allowlisted(src_lines, node):
                    findings.append(
                        Finding(
                            "EN001",
                            f"host sync {label} in {cls.name}.{meth.name} outside the "
                            f"`{SYNC_POINT_MARK}` allowlist — a hidden "
                            "decode-loop stall (mark the line or move the "
                            "transfer out of the loop)",
                            path, node.lineno, node.col_offset,
                        )
                    )
    return findings


@rule("EN002")
def en002_jit_in_step(tree: ast.AST, src: str, path: str) -> list[Finding]:
    """No ``jax.jit(...)`` construction inside step/prefill functions: a jit
    wrapper built per call defeats executable caching (build it in
    ``__init__`` or at module scope and reuse it)."""
    aliases = ModuleAliases(tree)
    jax_names = aliases.names_for("jax")
    findings: list[Finding] = []
    for fn in ast.walk(tree):
        if not (
            isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
            and fn.name in _STEP_FN_NAMES
        ):
            continue
        for node in ast.walk(fn):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "jit"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in jax_names
            ):
                findings.append(
                    Finding(
                        "EN002",
                        f"jax.jit constructed inside `{fn.name}` — per-call jit "
                        "construction rebuilds the executable wrapper every "
                        "step; hoist it to __init__ or module scope",
                        path, node.lineno, node.col_offset,
                    )
                )
    return findings


# methods matching these names count as release-on-exception helpers for
# EN003: calling one inside an except/finally hands the reservation back
# through the engine's common exit path
_RELEASE_FNS = ("release", "_release_slot", "_evict")


def _try_releases(meth: ast.AST) -> bool:
    """True when the method contains a ``try`` whose handlers or ``finally``
    release pages (directly or through the eviction helpers)."""
    for node in ast.walk(meth):
        if not isinstance(node, ast.Try):
            continue
        guarded = list(node.finalbody)
        for h in node.handlers:
            guarded.extend(h.body)
        for g in guarded:
            for sub in ast.walk(g):
                if (
                    isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in _RELEASE_FNS
                ):
                    return True
    return False


@rule("EN003")
def en003_alloc_release(tree: ast.AST, src: str, path: str) -> list[Finding]:
    """Engine methods that allocate pages must release them on all exception
    paths: every ``.alloc(...)`` call in an ``*Engine`` method must be
    dominated by a ``try`` whose except/finally hands the reservation back
    (``.release(...)`` directly, or the ``_release_slot`` / ``_evict``
    helpers). Without one, any exception between allocation and slot insert
    — a tampered pack raising a ContractError mid-prefill, a NaN guard —
    leaks the pages for the life of the engine."""
    findings: list[Finding] = []
    for cls in ast.walk(tree):
        if not (isinstance(cls, ast.ClassDef) and "Engine" in cls.name):
            continue
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            allocs = [
                node
                for node in ast.walk(meth)
                if isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "alloc"
            ]
            if not allocs or _try_releases(meth):
                continue
            for node in allocs:
                findings.append(
                    Finding(
                        "EN003",
                        f"page allocation in {cls.name}.{meth.name} with no "
                        "try/except/finally that releases the reservation — "
                        "an exception between alloc and slot insert leaks "
                        "the pages (release in a handler, or route the exit "
                        "through _release_slot/_evict)",
                        path, node.lineno, node.col_offset,
                    )
                )
    return findings
