"""Documentation-coverage rules (DC...): the public serving/kernel surface
must carry docstrings.

DC001 is deliberately narrow: it polices only the modules that form the
repo's public API surface (the kernel dispatch layer and the serving
launcher — the modules README.md and docs/ point readers at), not every
helper in the tree. A public module-level function, class, or public method
of a public class without a docstring is a finding. Names with a leading
underscore (which covers dunders: the class docstring is the constructor
contract) and property setters are exempt.
"""

from __future__ import annotations

import ast
from pathlib import PurePosixPath

from repro.analysis.core import Finding, rule

__all__ = ["dc001_public_docstrings"]

# repo-relative module suffixes whose public surface the rule covers
DOCUMENTED_SURFACE = (
    "kernels/dispatch.py",
    "launch/serve.py",
)


def _covered(path: str) -> bool:
    p = PurePosixPath(str(path).replace("\\", "/"))
    return any(str(p).endswith(suffix) for suffix in DOCUMENTED_SURFACE)


def _public(name: str) -> bool:
    return not name.startswith("_")


def _has_docstring(node: ast.AST) -> bool:
    return ast.get_docstring(node, clean=False) is not None


@rule("DC001")
def dc001_public_docstrings(tree: ast.AST, src: str, path: str) -> list[Finding]:
    """Public functions, classes, and methods of the documented API surface
    (``kernels/dispatch.py``, ``launch/serve.py``) must have docstrings —
    docs/kernels.md and docs/serving.md link into this surface, and an
    undocumented entry point there is a docs regression, not a style nit."""
    if not _covered(path):
        return []
    findings: list[Finding] = []

    def flag(node: ast.AST, what: str, name: str) -> None:
        findings.append(
            Finding(
                "DC001",
                f"public {what} `{name}` on the documented API surface has no "
                "docstring (see docs/ and README.md; underscore-prefix it if "
                "it is genuinely internal)",
                path, node.lineno, node.col_offset,
            )
        )

    assert isinstance(tree, ast.Module)
    if not _has_docstring(tree):
        findings.append(
            Finding(
                "DC001",
                "documented-surface module has no module docstring",
                path, 1, 0,
            )
        )
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _public(node.name) and not _has_docstring(node):
                flag(node, "function", node.name)
        elif isinstance(node, ast.ClassDef) and _public(node.name):
            if not _has_docstring(node):
                flag(node, "class", node.name)
            for meth in node.body:
                if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if not _public(meth.name):
                    continue
                # a documented property getter covers its setter
                if any(
                    isinstance(d, ast.Attribute) and d.attr == "setter"
                    for d in meth.decorator_list
                ):
                    continue
                if not _has_docstring(meth):
                    flag(meth, "method", f"{node.name}.{meth.name}")
    return findings
