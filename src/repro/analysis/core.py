"""quantcheck core: findings, the rule registry, file walking, reporting.

The analyzer is a self-contained stdlib-``ast`` lint pass with repo-specific
rules (see rules_pallas.py / rules_engine.py / rules_docs.py). It deliberately imports
nothing from jax or the rest of ``repro`` at analysis time, so it can run in
a bare CI lane (the blocking ``analyze`` job) before any heavyweight deps
resolve.

A rule is a function ``(tree, src, path) -> list[Finding]`` registered with
:func:`rule`. ``python -m repro.analysis src/`` walks the tree, runs every
registered rule on every ``.py`` file, and exits nonzero on findings.
Human-readable output is one ``path:line:col RULE message`` per finding;
``--json`` additionally writes the machine-readable report CI uploads as an
artifact.
"""

from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path
from typing import Callable, Iterable, Optional

__all__ = [
    "Finding",
    "ModuleAliases",
    "all_rules",
    "analyze_paths",
    "analyze_source",
    "iter_python_files",
    "render_human",
    "render_json",
    "rule",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer diagnostic, anchored to a source location."""

    rule: str
    message: str
    path: str
    line: int
    col: int
    severity: str = "error"

    def human(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


RuleFn = Callable[[ast.AST, str, str], list[Finding]]

_RULES: dict[str, RuleFn] = {}


def rule(rule_id: str) -> Callable[[RuleFn], RuleFn]:
    """Register a rule function under its catalog id (e.g. ``PK001``)."""

    def register(fn: RuleFn) -> RuleFn:
        if rule_id in _RULES:
            raise ValueError(f"duplicate rule id {rule_id}")
        _RULES[rule_id] = fn
        fn.rule_id = rule_id
        return fn

    return register


def all_rules() -> dict[str, RuleFn]:
    """The registered rule catalog (imports the rule modules on first use)."""
    # imported lazily so core stays importable without the rules (and so the
    # rules can import core without a cycle)
    from repro.analysis import rules_docs, rules_engine, rules_pallas  # noqa: F401

    return dict(_RULES)


class ModuleAliases:
    """Resolve the file's local names for the modules the rules care about.

    Built from the module's import statements, so a file that does
    ``from jax.experimental import pallas as p`` is analyzed under its own
    alias rather than the conventional ``pl``.
    """

    CANONICAL = {
        "jax.experimental.pallas": "pallas",
        "jax.experimental.pallas.tpu": "pallas_tpu",
        "jax.numpy": "jnp",
        "numpy": "np",
        "jax": "jax",
    }

    def __init__(self, tree: ast.AST):
        self.alias_of: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    canon = self.CANONICAL.get(a.name)
                    if canon:
                        self.alias_of[a.asname or a.name.split(".")[0]] = canon
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    full = f"{node.module}.{a.name}"
                    canon = self.CANONICAL.get(full)
                    if canon:
                        self.alias_of[a.asname or a.name] = canon

    def is_(self, node: ast.AST, canon: str) -> bool:
        """Is ``node`` a Name bound (via import) to the canonical module?"""
        return isinstance(node, ast.Name) and self.alias_of.get(node.id) == canon

    def names_for(self, canon: str) -> set[str]:
        return {alias for alias, c in self.alias_of.items() if c == canon}


def analyze_source(
    src: str,
    path: str = "<string>",
    rules: Optional[Iterable[str]] = None,
) -> list[Finding]:
    """Run the rule catalog (or a subset) over one source string."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [
            Finding(
                "PARSE", f"syntax error: {e.msg}", path, e.lineno or 1, e.offset or 0
            )
        ]
    catalog = all_rules()
    if rules is not None:
        catalog = {rid: catalog[rid] for rid in rules}
    findings: list[Finding] = []
    for fn in catalog.values():
        findings.extend(fn(tree, src, path))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def iter_python_files(paths: Iterable[str]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        root = Path(p)
        if root.is_dir():
            files.extend(sorted(root.rglob("*.py")))
        elif root.suffix == ".py":
            files.append(root)
    return files


def analyze_paths(
    paths: Iterable[str], rules: Optional[Iterable[str]] = None
) -> tuple[list[Finding], int]:
    """Analyze every ``.py`` under ``paths``; returns (findings, files seen)."""
    findings: list[Finding] = []
    files = iter_python_files(paths)
    for f in files:
        findings.extend(analyze_source(f.read_text(), str(f), rules=rules))
    return findings, len(files)


def render_human(findings: list[Finding], n_files: int) -> str:
    lines = [f.human() for f in findings]
    noun = "finding" if len(findings) == 1 else "findings"
    lines.append(f"quantcheck: {len(findings)} {noun} in {n_files} files")
    return "\n".join(lines)


def render_json(findings: list[Finding], n_files: int) -> str:
    doc = {
        "schema": 1,
        "tool": "repro.analysis",
        "files": n_files,
        "findings": [dataclasses.asdict(f) for f in findings],
    }
    return json.dumps(doc, indent=2, sort_keys=True) + "\n"
