"""Opt-in runtime sanitizers for serving/kernel tests.

Where rules_pallas/rules_engine check source TEXT, these check a LIVE engine:

* :func:`no_recompiles` — fail if a code region traced anything new
  (per-family compile counts via ``engine.compile_stats()``).
* :func:`assert_compile_budget` — the ratchet: an engine's lifetime prefill
  trace count must stay within O(log max_len) buckets per (prefix-offset,
  frontend) variant.
* :func:`guarded_decode` — run the decode loop under
  ``jax.transfer_guard("disallow")``: any device transfer OUTSIDE the
  engine's explicit ``# sync-point`` sites (which wrap themselves in
  ``transfer_guard("allow")``) raises instead of silently stalling.
* :func:`page_invariant_checks` — wrap ``engine.step`` so
  ``check_page_invariants()`` (refcount/block-table/free-list audit) runs
  every N steps instead of only when a test remembers to call it.
* :func:`lifecycle_checks` — wrap ``engine.submit``/``engine.step`` so the
  request state machine is audited every step: terminal requests are done,
  carry their reason codes, and are off the slots/queue; live slots are
  PREFILL/DECODE; queued requests are QUEUED/PREEMPTED.

All are context managers designed for test bodies::

    with guarded_decode(), no_recompiles(engine), page_invariant_checks(engine):
        while engine.step():
            pass
    assert_compile_budget(engine)

This module imports jax and is NOT pulled in by the ``python -m
repro.analysis`` CLI, which stays stdlib-only.
"""

from __future__ import annotations

import contextlib
import math

import jax

__all__ = [
    "assert_compile_budget",
    "guarded_decode",
    "lifecycle_checks",
    "no_recompiles",
    "page_invariant_checks",
]


class SanitizerError(AssertionError):
    """A sanitizer-detected hot-path violation."""


@contextlib.contextmanager
def no_recompiles(engine):
    """Fail if the region traced any new prefill/decode executable.

    Use around steady-state serving (after warmup): every trace inside the
    region is a recompile the paper's latency numbers never paid for.
    """
    before = engine.compile_stats()
    yield engine
    after = engine.compile_stats()
    for key in ("prefill_traces", "decode_traces", "ragged_traces",
                "spec_traces"):
        if after.get(key, 0) > before.get(key, 0):
            raise SanitizerError(
                f"recompile sanitizer: {key} grew {before[key]} -> "
                f"{after[key]} inside a no-recompile region "
                f"(new traces: {after})"
            )


def compile_budget(max_len: int, variants: int) -> int:
    """The ratchet bound: distinct power-of-two prompt buckets (min 8) plus
    the capacity bucket, per (prefix-offset, frontend) variant."""
    buckets = max(1, int(math.log2(max(max_len, 8))) - 2) + 1
    return max(1, variants) * buckets


def assert_compile_budget(engine, max_len: int | None = None) -> dict:
    """Ratchet an engine's lifetime prefill trace count against the bucket
    bound. Returns the compile stats it validated (for test logging).

    A ragged engine is held to a far tighter bar: the unified step is ONE
    token-budget-shaped executable, so ragged + prefill traces together must
    not exceed 2 (the single ragged trace, plus at most one legacy prefill
    trace if a caller mixed modes)."""
    stats = engine.compile_stats()
    if stats.get("spec_traces", 0) > 1:
        raise SanitizerError(
            f"compile-budget sanitizer: {stats['spec_traces']} speculative "
            "decode traces; the (batch, spec_k) launch shape is static, so "
            "the speculative step must compile exactly once"
        )
    if getattr(engine, "ragged", False):
        total = stats.get("ragged_traces", 0) + stats["prefill_traces"]
        if total > 2:
            raise SanitizerError(
                f"compile-budget sanitizer: ragged engine traced {total} "
                f"executables (ragged={stats.get('ragged_traces', 0)}, "
                f"prefill={stats['prefill_traces']}); the unified step must "
                "compile once per token budget"
            )
        return stats
    if max_len is None:
        max_len = engine.max_len
    budget = compile_budget(max_len, stats.get("prefill_variants", 1))
    if stats["prefill_traces"] > budget:
        raise SanitizerError(
            f"compile-budget sanitizer: {stats['prefill_traces']} prefill "
            f"traces exceed the O(log max_len) budget {budget} for "
            f"max_len={max_len}, variants="
            f"{stats.get('prefill_variants', 1)} (buckets: "
            f"{stats['prefill_buckets']}) — prompt bucketing is leaking "
            "shapes"
        )
    return stats


@contextlib.contextmanager
def guarded_decode():
    """Disallow implicit device transfers for the region. The engine's
    sanctioned ``# sync-point`` sites run under their own
    ``transfer_guard("allow")`` scopes, so only UNsanctioned transfers trip
    the guard."""
    with jax.transfer_guard("disallow"):
        yield


@contextlib.contextmanager
def page_invariant_checks(engine, every: int = 1):
    """Audit page-allocator invariants inside the serving loop.

    Monkeypatches ``engine.step`` so ``check_page_invariants()`` runs after
    every ``every``-th step (and once more on exit), turning the existing
    debug hook into an always-on sanitizer for regression tests. No-op for
    dense (non-paged) engines.
    """
    if getattr(engine, "allocator", None) is None:
        yield engine
        return
    orig_step = engine.step
    count = 0

    def checked_step(*args, **kwargs):
        nonlocal count
        out = orig_step(*args, **kwargs)
        count += 1
        if count % every == 0:
            engine.check_page_invariants()
        return out

    engine.step = checked_step
    try:
        yield engine
        engine.check_page_invariants()
    finally:
        engine.step = orig_step


@contextlib.contextmanager
def lifecycle_checks(engine):
    """Audit the request state machine inside the serving loop.

    Monkeypatches ``engine.submit`` (to learn which requests exist) and
    ``engine.step`` so after every step, for every request ever submitted:

    * a terminal request (``RequestState.TERMINAL``) has ``done`` set, sits
      in no slot and not in the queue, and — for FAILED / TIMED_OUT — carries
      a machine-readable ``error`` code;
    * a request live in a slot is PREFILL or DECODE;
    * a queued request is QUEUED or PREEMPTED.

    The chaos suite runs whole fault schedules under this, so any exit path
    that forgets its bookkeeping fails at the step that broke it.
    """
    from repro.launch.serve import RequestState

    seen: list = []
    orig_submit = engine.submit
    orig_step = engine.step

    def tracked_submit(req, *args, **kwargs):
        if all(req is not r for r in seen):
            seen.append(req)
        return orig_submit(req, *args, **kwargs)

    def audit() -> None:
        in_slots = [r for r in engine.slots if r is not None]
        in_queue = list(engine.queue)
        for req in seen:
            rid = req.request_id
            if req.status in RequestState.TERMINAL:
                if not req.done:
                    raise SanitizerError(
                        f"lifecycle sanitizer: {rid} is {req.status} but not done"
                    )
                if any(req is r for r in in_slots) or any(req is r for r in in_queue):
                    raise SanitizerError(
                        f"lifecycle sanitizer: terminal request {rid} "
                        f"({req.status}) still held by a slot or the queue"
                    )
                if req.status in (RequestState.FAILED, RequestState.TIMED_OUT) \
                        and not req.error:
                    raise SanitizerError(
                        f"lifecycle sanitizer: {rid} is {req.status} with no "
                        "error reason code"
                    )
            elif any(req is r for r in in_slots):
                if req.status not in (RequestState.PREFILL, RequestState.DECODE):
                    raise SanitizerError(
                        f"lifecycle sanitizer: slot-resident request {rid} is "
                        f"{req.status}, expected PREFILL/DECODE"
                    )
            elif any(req is r for r in in_queue):
                if req.status not in (RequestState.QUEUED, RequestState.PREEMPTED):
                    raise SanitizerError(
                        f"lifecycle sanitizer: queued request {rid} is "
                        f"{req.status}, expected QUEUED/PREEMPTED"
                    )

    def checked_step(*args, **kwargs):
        out = orig_step(*args, **kwargs)
        audit()
        return out

    engine.submit = tracked_submit
    engine.step = checked_step
    try:
        yield engine
        audit()
    finally:
        engine.submit = orig_submit
        engine.step = orig_step
