"""Pallas kernel-hygiene rules (PK...): BlockSpec index maps, divisibility
guards, pinned-panel constants, and kernel-body host-op bans.

All rules are pure stdlib-``ast`` analyses over the kernel WRAPPER functions
(the ones containing a ``pl.pallas_call``) and the kernel bodies they launch.
The rules resolve the file's own import aliases (``pl``, ``pltpu``, ``jnp``,
``np``) instead of hard-coding names.
"""

from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.core import Finding, ModuleAliases, rule

__all__ = ["pk001_index_maps", "pk002_divisibility", "pk003_pinned_specs", "pk004_kernel_body"]


# ---------------------------------------------------------------------------
# shared structural helpers
# ---------------------------------------------------------------------------


def _parents(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    par: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            par[child] = node
    return par


def _enclosing_functions(node: ast.AST, parents: dict) -> list[ast.FunctionDef]:
    """Innermost-first chain of functions containing ``node``."""
    chain = []
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            chain.append(cur)
        cur = parents.get(cur)
    return chain


def _is_attr_call(call: ast.Call, aliases: ModuleAliases, canon: str, attr: str) -> bool:
    f = call.func
    return (
        isinstance(f, ast.Attribute) and f.attr == attr and aliases.is_(f.value, canon)
    )


def _blockspec_calls(fn: ast.AST, aliases: ModuleAliases) -> list[ast.Call]:
    return [
        n
        for n in ast.walk(fn)
        if isinstance(n, ast.Call) and _is_attr_call(n, aliases, "pallas", "BlockSpec")
    ]


def _pallas_calls(fn: ast.AST, aliases: ModuleAliases) -> list[ast.Call]:
    return [
        n
        for n in ast.walk(fn)
        if isinstance(n, ast.Call) and _is_attr_call(n, aliases, "pallas", "pallas_call")
    ]


def _kw(call: ast.Call, name: str) -> Optional[ast.expr]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _grid_rank(fn: ast.AST, aliases: ModuleAliases) -> Optional[int]:
    """Grid rank from literal ``grid=`` tuples in the function's pallas_call
    launches; None when absent, non-literal, or ambiguous."""
    ranks = set()
    for pc in _pallas_calls(fn, aliases):
        grid = _kw(pc, "grid")
        if isinstance(grid, ast.Tuple):
            ranks.add(len(grid.elts))
        else:
            return None
    return ranks.pop() if len(ranks) == 1 else None


def _index_map(spec: ast.Call) -> Optional[ast.expr]:
    if len(spec.args) >= 2:
        return spec.args[1]
    return _kw(spec, "index_map")


def _block_shape(spec: ast.Call) -> Optional[ast.expr]:
    if spec.args:
        return spec.args[0]
    return _kw(spec, "block_shape")


def _wrapper_functions(tree: ast.AST, aliases: ModuleAliases) -> list[ast.AST]:
    """Functions that launch a pallas_call AND are not nested inside another
    launcher (the launch site's own function is the wrapper)."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a launch inside a nested def belongs to the nested function
            direct = [
                pc
                for pc in _pallas_calls(node, aliases)
                if not any(
                    pc in set(ast.walk(inner))
                    for inner in ast.walk(node)
                    if isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and inner is not node
                )
            ]
            if direct:
                out.append(node)
    return out


# ---------------------------------------------------------------------------
# PK001: index_map purity + grid-rank/block-rank agreement
# ---------------------------------------------------------------------------


@rule("PK001")
def pk001_index_maps(tree: ast.AST, src: str, path: str) -> list[Finding]:
    """Every BlockSpec index_map must be a pure lambda whose arity matches
    the launch grid rank and whose returned tuple matches the block rank.

    Pure means: parameters, constants, arithmetic/comparison/conditional
    expressions, ``jnp.where``, and subscripts whose base is a lambda
    parameter (scalar-prefetched operands — ``PrefetchScalarGridSpec``
    appends them to the index-map arguments precisely so maps can read
    them) — no other calls, no attribute access, no subscripts of free
    names, no side effects. Impure index maps are re-evaluated by the
    pipeline emitter and silently break block prefetch.
    """
    aliases = ModuleAliases(tree)
    jnp_names = aliases.names_for("jnp")
    findings: list[Finding] = []

    for fn in _wrapper_functions(tree, aliases):
        rank = _grid_rank(fn, aliases)
        for spec in _blockspec_calls(fn, aliases):
            imap = _index_map(spec)
            if imap is None:
                continue
            if not isinstance(imap, ast.Lambda):
                findings.append(
                    Finding(
                        "PK001",
                        "BlockSpec index_map should be an inline lambda so its "
                        "purity is checkable",
                        path, imap.lineno, imap.col_offset,
                    )
                )
                continue
            nargs = len(imap.args.args)
            if imap.args.vararg is None and rank is not None and nargs != rank:
                findings.append(
                    Finding(
                        "PK001",
                        f"index_map takes {nargs} args but the launch grid has "
                        f"rank {rank}",
                        path, imap.lineno, imap.col_offset,
                    )
                )
            shape = _block_shape(spec)
            if isinstance(shape, ast.Tuple) and isinstance(imap.body, ast.Tuple):
                if len(imap.body.elts) != len(shape.elts):
                    findings.append(
                        Finding(
                            "PK001",
                            f"index_map returns {len(imap.body.elts)} block "
                            f"coordinates for a rank-{len(shape.elts)} block shape",
                            path, imap.lineno, imap.col_offset,
                        )
                    )
            findings.extend(_purity_findings(imap, jnp_names, path))
    return findings


def _purity_findings(lam: ast.Lambda, jnp_names: set[str], path: str) -> list[Finding]:
    allowed_attrs: set[ast.AST] = set()
    params = {a.arg for a in lam.args.args}
    if lam.args.vararg is not None:
        params.add(lam.args.vararg.arg)
    findings: list[Finding] = []
    for node in ast.walk(lam.body):
        if isinstance(node, ast.Call):
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and f.attr == "where"
                and isinstance(f.value, ast.Name)
                and f.value.id in jnp_names
            ):
                allowed_attrs.add(f)
                continue
            findings.append(
                Finding(
                    "PK001",
                    f"impure index_map: call to "
                    f"`{ast.unparse(node.func)}` (only jnp.where is allowed)",
                    path, node.lineno, node.col_offset,
                )
            )
            # the call is already reported; don't double-report its func
            # expression in the attribute pass below
            allowed_attrs.update(
                n for n in ast.walk(node.func) if isinstance(n, ast.Attribute)
            )
        elif isinstance(node, ast.Subscript):
            # subscripting a lambda PARAMETER is the scalar-prefetch idiom
            # (PrefetchScalarGridSpec passes the prefetched refs as trailing
            # index-map arguments); anything else stays banned
            if isinstance(node.value, ast.Name) and node.value.id in params:
                continue
            findings.append(
                Finding(
                    "PK001",
                    "impure index_map: only subscripts of lambda parameters "
                    "(scalar-prefetched operands) are allowed, got "
                    f"`{ast.unparse(node)}`",
                    path, node.lineno, node.col_offset,
                )
            )
    for node in ast.walk(lam.body):
        if isinstance(node, ast.Attribute) and node not in allowed_attrs:
            findings.append(
                Finding(
                    "PK001",
                    f"impure index_map: attribute access "
                    f"`{ast.unparse(node)}` (only jnp.where is allowed)",
                    path, node.lineno, node.col_offset,
                )
            )
    return findings


# ---------------------------------------------------------------------------
# PK002: integer-division block shapes need a divisibility guard
# ---------------------------------------------------------------------------


def _has_contract_call(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            name = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None
            )
            if name and (
                name.startswith("validate_") or name in ("divisible", "check_vmem")
            ):
                return True
    return False


def _mod_guard_exists(fn: ast.AST, left: ast.expr, right: ast.expr) -> bool:
    want = (ast.unparse(left), ast.unparse(right))
    for node in ast.walk(fn):
        if isinstance(node, ast.Assert):
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
                    if (ast.unparse(sub.left), ast.unparse(sub.right)) == want:
                        return True
    return False


@rule("PK002")
def pk002_divisibility(tree: ast.AST, src: str, path: str) -> list[Finding]:
    """Every integer division in a BlockSpec shape, ``grid=``, or scratch
    shape needs an explicit divisibility guard in the wrapper: either an
    ``assert ... X % Y ...`` or a ``validate_*`` / ``divisible`` /
    ``check_vmem`` contract call. An unguarded ``X // Y`` that does not
    divide evenly silently truncates the block and corrupts grid coverage.
    """
    aliases = ModuleAliases(tree)
    findings: list[Finding] = []
    for fn in _wrapper_functions(tree, aliases):
        shape_exprs: list[ast.expr] = []
        for spec in _blockspec_calls(fn, aliases):
            shape = _block_shape(spec)
            if shape is not None:
                shape_exprs.append(shape)
        for pc in _pallas_calls(fn, aliases):
            for kw_name in ("grid", "scratch_shapes"):
                v = _kw(pc, kw_name)
                if v is not None:
                    shape_exprs.append(v)
        guarded = _has_contract_call(fn)
        for expr in shape_exprs:
            for node in ast.walk(expr):
                if isinstance(node, ast.BinOp) and isinstance(node.op, ast.FloorDiv):
                    if guarded or _mod_guard_exists(fn, node.left, node.right):
                        continue
                    findings.append(
                        Finding(
                            "PK002",
                            f"unguarded integer division `{ast.unparse(node)}` in "
                            "a block/grid/scratch shape: add an assert "
                            f"`{ast.unparse(node.left)} % "
                            f"{ast.unparse(node.right)} == 0` or a validate_* "
                            "contract call to the wrapper",
                            path, node.lineno, node.col_offset,
                        )
                    )
    return findings


# ---------------------------------------------------------------------------
# PK003: pinned-panel BlockSpecs must be constant-zero index maps
# ---------------------------------------------------------------------------


@rule("PK003")
def pk003_pinned_specs(tree: ast.AST, src: str, path: str) -> list[Finding]:
    """An index_map that ignores every grid coordinate pins its operand
    resident in VMEM — and must then be the all-zeros map. A parameter-free
    index map returning a nonzero or non-constant block index addresses a
    fixed block other than the operand's origin: almost certainly a bug
    (the resident-panel kernels rely on ``lambda ...: (0, 0)``).
    """
    aliases = ModuleAliases(tree)
    findings: list[Finding] = []
    for fn in _wrapper_functions(tree, aliases):
        for spec in _blockspec_calls(fn, aliases):
            imap = _index_map(spec)
            if not isinstance(imap, ast.Lambda):
                continue
            params = {a.arg for a in imap.args.args}
            uses_param = any(
                isinstance(n, ast.Name) and n.id in params
                for n in ast.walk(imap.body)
            )
            if uses_param:
                continue
            elts = (
                imap.body.elts if isinstance(imap.body, ast.Tuple) else [imap.body]
            )
            for e in elts:
                if not (isinstance(e, ast.Constant) and e.value == 0):
                    findings.append(
                        Finding(
                            "PK003",
                            "pinned-panel BlockSpec (index_map ignores all grid "
                            f"coordinates) must return zeros, got "
                            f"`{ast.unparse(imap.body)}`",
                            path, imap.lineno, imap.col_offset,
                        )
                    )
                    break
    return findings


# ---------------------------------------------------------------------------
# PK004: no host ops / Python-float accumulation inside kernel bodies
# ---------------------------------------------------------------------------


def _kernel_functions(tree: ast.AST, aliases: ModuleAliases) -> list[ast.AST]:
    """Kernel bodies: functions whose first parameter is a ``*_ref``, plus
    whatever a ``pl.pallas_call`` launches (resolved through plain names and
    ``functools.partial(fn, ...)`` assignments in enclosing scopes)."""
    parents = _parents(tree)
    kernels: dict[ast.AST, None] = {}

    defs_by_scope: dict[Optional[ast.AST], dict[str, ast.AST]] = {}
    partial_by_scope: dict[Optional[ast.AST], dict[str, str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scope = next(
                (f for f in _enclosing_functions(node, parents)), None
            )
            defs_by_scope.setdefault(scope, {})[node.name] = node
            if node.args.args and node.args.args[0].arg.endswith("_ref"):
                kernels[node] = None
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt, val = node.targets[0], node.value
            if (
                isinstance(tgt, ast.Name)
                and isinstance(val, ast.Call)
                and (
                    (isinstance(val.func, ast.Attribute) and val.func.attr == "partial")
                    or (isinstance(val.func, ast.Name) and val.func.id == "partial")
                )
                and val.args
                and isinstance(val.args[0], ast.Name)
            ):
                scope = next(
                    (f for f in _enclosing_functions(node, parents)), None
                )
                partial_by_scope.setdefault(scope, {})[tgt.id] = val.args[0].id

    def resolve(name: str, scope_chain: list) -> Optional[ast.AST]:
        seen = set()
        scopes = scope_chain + [None]
        while name not in seen:
            seen.add(name)
            for s in scopes:
                if name in defs_by_scope.get(s, {}):
                    return defs_by_scope[s][name]
            for s in scopes:
                if name in partial_by_scope.get(s, {}):
                    name = partial_by_scope[s][name]
                    break
            else:
                return None
        return None

    for pc in [
        n
        for n in ast.walk(tree)
        if isinstance(n, ast.Call) and _is_attr_call(n, aliases, "pallas", "pallas_call")
    ]:
        if pc.args and isinstance(pc.args[0], ast.Name):
            target = resolve(pc.args[0].id, _enclosing_functions(pc, parents))
            if target is not None:
                kernels[target] = None
    return list(kernels)


@rule("PK004")
def pk004_kernel_body(tree: ast.AST, src: str, path: str) -> list[Finding]:
    """Kernel bodies must stay on-device: no host numpy ops, no ``.item()``
    or ``block_until_ready`` syncs, no ``print``, and no accumulation into a
    Python float (which silently hoists the loop to trace-time host math).
    """
    aliases = ModuleAliases(tree)
    np_names = aliases.names_for("np")
    findings: list[Finding] = []
    for kfn in _kernel_functions(tree, aliases):
        float_inits: set[str] = set()
        for node in ast.walk(kfn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if (
                    isinstance(tgt, ast.Name)
                    and isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, float)
                ):
                    float_inits.add(tgt.id)
        for node in ast.walk(kfn):
            if isinstance(node, ast.Attribute) and (
                isinstance(node.value, ast.Name) and node.value.id in np_names
            ):
                findings.append(
                    Finding(
                        "PK004",
                        f"host numpy op `{ast.unparse(node)}` inside a kernel "
                        "body (use jnp / jax.lax)",
                        path, node.lineno, node.col_offset,
                    )
                )
            elif isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr in (
                    "item",
                    "block_until_ready",
                ):
                    findings.append(
                        Finding(
                            "PK004",
                            f"host sync `.{f.attr}()` inside a kernel body",
                            path, node.lineno, node.col_offset,
                        )
                    )
                elif isinstance(f, ast.Name) and f.id == "print":
                    findings.append(
                        Finding(
                            "PK004",
                            "print() inside a kernel body (use pl.debug_print)",
                            path, node.lineno, node.col_offset,
                        )
                    )
                elif (
                    isinstance(f, ast.Name)
                    and f.id == "float"
                    and node.args
                    and not isinstance(node.args[0], ast.Constant)
                ):
                    findings.append(
                        Finding(
                            "PK004",
                            "float(...) on a traced value inside a kernel body",
                            path, node.lineno, node.col_offset,
                        )
                    )
            elif isinstance(node, ast.AugAssign):
                tgt = node.target
                if isinstance(tgt, ast.Name) and tgt.id in float_inits:
                    findings.append(
                        Finding(
                            "PK004",
                            f"Python-float accumulation into `{tgt.id}` inside a "
                            "kernel body (initialize with jnp.zeros and "
                            "accumulate in a VMEM scratch or fori_loop carry)",
                            path, node.lineno, node.col_offset,
                        )
                    )
    return findings
