"""quantcheck: repo-specific static analyzer + runtime sanitizers.

``python -m repro.analysis src/`` runs the stdlib-``ast`` rule catalog
(Pallas kernel hygiene PK001-PK004, engine hygiene EN001-EN002) over a file
tree — self-contained, no jax import. The runtime sanitizers (recompile /
transfer-guard / page-invariant) live in :mod:`repro.analysis.sanitizers`
and are imported explicitly by tests.
"""

from repro.analysis.core import (
    Finding,
    all_rules,
    analyze_paths,
    analyze_source,
    render_human,
    render_json,
)

__all__ = [
    "Finding",
    "all_rules",
    "analyze_paths",
    "analyze_source",
    "render_human",
    "render_json",
]
