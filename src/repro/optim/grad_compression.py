"""int8 gradient compression for the DP all-reduce (distributed-optimization
trick, DESIGN.md §5).

Quantize each gradient leaf to int8 with a per-leaf scale **before** the
data-parallel reduction and keep the quantization residual in an
error-feedback accumulator so the compression error is corrected on the next
step (EF-SGD). 4x less DP all-reduce traffic.

Usage in the train step (inside shard_map over the dp axes, or under jit the
psum is implicit): grads come back already averaged; here we expose the
quantize/dequantize pair + the EF state so the launcher can wrap the
reduction explicitly when collective bytes dominate the roofline.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def compress_grads_int8(grads: Any, ef: Optional[Any] = None):
    """Returns (q_grads int8, scales, new_ef). Dequantize with q * scale."""
    if ef is None:
        ef = jax.tree.map(jnp.zeros_like, grads)

    def comp(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        amax = jnp.max(jnp.abs(g32))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        resid = g32 - q.astype(jnp.float32) * scale
        return q, scale, resid.astype(g.dtype)

    out = jax.tree.map(comp, grads, ef)

    def is3(x):
        return isinstance(x, tuple)

    q = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    s = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_ef = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return q, s, new_ef


def decompress_grads_int8(q: Any, scales: Any):
    return jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss, q, scales)
