"""AdamW (decoupled weight decay) as a pure pytree transformation.

Moments are stored in f32 by default; ``moment_dtype=bfloat16`` halves
optimizer-state HBM (used by the deepseek-671b configs to fit 16 GB/chip —
see EXPERIMENTS.md §Dry-run). States inherit the parameter shardings, i.e.
ZeRO-style sharded optimizer state comes for free from param FSDP specs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    clip_norm: Optional[float] = 1.0

    def init(self, params: Any) -> AdamWState:
        def z(p):
            return jnp.zeros(p.shape, self.moment_dtype)

        return AdamWState(
            mu=jax.tree.map(z, params),
            nu=jax.tree.map(z, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(self, grads: Any, state: AdamWState, params: Any):
        count = state.count + 1
        if self.clip_norm is not None:
            gn = jnp.sqrt(
                sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
            )
            factor = jnp.minimum(1.0, self.clip_norm / (gn + 1e-12))
            grads = jax.tree.map(lambda g: g * factor, grads)

        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu32 = self.b1 * mu.astype(jnp.float32) + (1 - self.b1) * g32
            nu32 = self.b2 * nu.astype(jnp.float32) + (1 - self.b2) * g32 * g32
            step = (mu32 / b1c) / (jnp.sqrt(nu32 / b2c) + self.eps)
            decay = self.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms
            new_p = p.astype(jnp.float32) - self.lr * (step + decay * p.astype(jnp.float32))
            return (
                new_p.astype(p.dtype),
                mu32.astype(self.moment_dtype),
                nu32.astype(self.moment_dtype),
            )

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(mu=new_mu, nu=new_nu, count=count)
