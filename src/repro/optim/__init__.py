from repro.optim.adamw import AdamW, AdamWState  # noqa: F401
from repro.optim.grad_compression import compress_grads_int8  # noqa: F401
