"""Mesh context for mesh-aware layers (MoE expert parallelism, KV-sequence
sharding). The launcher sets this before tracing; smoke tests leave it unset
and layers take their collective-free local paths.

This is deliberately a trace-time (static) context, not a traced value:
the presence/size of mesh axes changes the *program structure* (shard_map
blocks, all_to_all), which must be decided at trace time anyway.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

from jax.sharding import Mesh

__all__ = ["MeshContext", "set_mesh_context", "get_mesh_context", "mesh_context"]


@dataclasses.dataclass(frozen=True)
class MeshContext:
    mesh: Optional[Mesh] = None
    dp_axes: tuple[str, ...] = ()  # batch / FSDP axes ("pod", "data")
    tp_axis: Optional[str] = None  # tensor-parallel axis ("model")
    ep_axis: Optional[str] = None  # expert-parallel axis (usually == tp_axis)
    fsdp_axes: tuple[str, ...] = ()  # parameter-sharding axes for ZeRO-3
    seq_axis: Optional[str] = None  # KV/sequence sharding axis for long decode

    @property
    def ep_size(self) -> int:
        if self.mesh is None or self.ep_axis is None:
            return 1
        return self.mesh.shape[self.ep_axis]

    @property
    def token_axes(self) -> tuple[str, ...]:
        """Axes the flattened token dim is sharded over for MoE dispatch."""
        axes = tuple(self.dp_axes)
        if self.ep_axis:
            axes = axes + (self.ep_axis,)
        return axes


_CTX = MeshContext()


def set_mesh_context(ctx: MeshContext) -> None:
    global _CTX
    _CTX = ctx


def get_mesh_context() -> MeshContext:
    return _CTX


@contextlib.contextmanager
def mesh_context(ctx: MeshContext):
    global _CTX
    prev = _CTX
    _CTX = ctx
    try:
        yield
    finally:
        _CTX = prev
