"""xLSTM: chunkwise-parallel mLSTM blocks + recurrent sLSTM blocks.

mLSTM (matrix memory, exponential gating) is computed in the stabilized
chunkwise form for train/prefill — a scan over chunks carrying
(C (dqk,dv), n (dqk,), m (log-stabilizer)) per head, with attention-like
intra-chunk computation — and in the O(1) recurrent form for decode. sLSTM
(scalar memory with block-diagonal recurrence) is inherently sequential and
scans over time, which is why the architecture uses it sparsely
(``slstm_every``). Layers are grouped into segments of
(slstm_every-1 mLSTM + 1 sLSTM) so both stacks scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import common as C

# chunk-size choice (§Perf cell A): per-token state traffic scales as
# H*dqk*dv/chunk while intra-chunk compute/bytes scale as chunk — for the
# 1.3b dims the crossover is ~1k, so long sequences use 1024-token chunks
CHUNK = 1024
CHUNK_MIN = 256


def _pick_chunk(s: int) -> int:
    return CHUNK if s % CHUNK == 0 and s >= CHUNK else CHUNK_MIN


def _dims(cfg: ModelConfig):
    d_inner = int(cfg.d_model * cfg.xlstm_proj_factor)
    h = cfg.n_heads
    return d_inner, h, d_inner // h


def _qk_dim(cfg: ModelConfig) -> int:
    # official xLSTM uses qk_dim_factor 0.5 (halves the matrix-memory state)
    d_inner, h, dh = _dims(cfg)
    return max(2, dh // 2)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _mlstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, h, dh = _dims(cfg)
    k = jax.random.split(key, 8)
    return {
        "ln": jnp.ones((d,), C.DTYPE),
        "up": C.dense_init(k[0], d, 2 * d_inner),
        "conv": (jax.random.normal(k[1], (4, d_inner)) * 0.1).astype(C.DTYPE),
        "wq": C.dense_init(k[2], d_inner, _qk_dim(cfg) * h),
        "wk": C.dense_init(k[3], d_inner, _qk_dim(cfg) * h),
        "wv": C.dense_init(k[4], d_inner, d_inner),
        "wif": C.dense_init(k[5], d_inner, 2 * h),  # input+forget gates per head
        "gn": jnp.ones((d_inner,), C.DTYPE),
        "down": C.dense_init(k[6], d_inner, d),
    }


def _slstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    k = jax.random.split(key, 4)
    return {
        "ln": jnp.ones((d,), C.DTYPE),
        "w": C.dense_init(k[0], d, 4 * d),  # i, f, z, o pre-activations
        "r": (jax.random.normal(k[1], (h, dh, 4 * dh)) * (1.0 / dh**0.5)).astype(C.DTYPE),
        "gn": jnp.ones((d,), C.DTYPE),
        "ln2": jnp.ones((d,), C.DTYPE),
        "ffn": C.mlp_init(k[2], d, 2 * d),
    }


def _segments(cfg: ModelConfig) -> tuple[int, int]:
    if cfg.slstm_every <= 0:
        return 0, cfg.n_layers
    n_seg = cfg.n_layers // cfg.slstm_every
    m_per = cfg.slstm_every - 1
    assert n_seg * cfg.slstm_every == cfg.n_layers, "n_layers % slstm_every != 0"
    return n_seg, m_per


def init_params(cfg: ModelConfig, key) -> dict:
    ke, km, ks, kh = jax.random.split(key, 4)
    n_seg, m_per = _segments(cfg)
    p = {"embed": C.embed_init(ke, cfg.padded_vocab, cfg.d_model),
         "ln_f": jnp.ones((cfg.d_model,), C.DTYPE),
         "head": C.dense_init(kh, cfg.d_model, cfg.padded_vocab)}
    if n_seg == 0:
        keys = jax.random.split(km, cfg.n_layers)
        p["m_layers"] = jax.vmap(lambda k: _mlstm_init(k, cfg))(keys)
    else:
        mkeys = jax.random.split(km, n_seg * m_per).reshape(n_seg, m_per, 2)
        p["m_layers"] = jax.vmap(jax.vmap(lambda k: _mlstm_init(k, cfg)))(mkeys)
        p["s_layers"] = jax.vmap(lambda k: _slstm_init(k, cfg))(jax.random.split(ks, n_seg))
    return p


# ---------------------------------------------------------------------------
# mLSTM core (chunkwise, stabilized)
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv, kernel 4. x: (B, S, D); w: (4, D)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state  # (B, k-1, D) from previous steps
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1) :, :]
    return out, new_state


def _mlstm_chunkwise(q, k, v, i_raw, f_raw, state):
    """q,k: (B, S, H, dqk); v: (B, S, H, dv); i_raw,f_raw: (B, S, H).

    state: dict(C (B,H,dqk,dv), n (B,H,dqk), m (B,H)).
    """
    b, s, h, dqk = q.shape
    dv = v.shape[-1]
    l = _pick_chunk(s)
    nc = s // l
    scale = 1.0 / (dqk**0.5)
    qc = (q * scale).reshape(b, nc, l, h, dqk).astype(jnp.float32)
    kc = k.reshape(b, nc, l, h, dqk).astype(jnp.float32)
    vc = v.reshape(b, nc, l, h, dv).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32)).reshape(b, nc, l, h)
    ii = i_raw.astype(jnp.float32).reshape(b, nc, l, h)

    def chunk_step(carry, xs):
        Cst, nst, mst = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qq, kk, vv, lff, iii = xs  # (B,l,H,dh) etc.
        F = jnp.cumsum(lff, axis=1)  # (B,l,H) inclusive decay-to-t
        # intra-chunk log weights D[t,s] = F_t - F_s + i_s (s<=t)
        Dlog = F[:, :, None, :] - F[:, None, :, :] + iii[:, None, :, :]  # (B,t,s,H)
        tri = jnp.tril(jnp.ones((l, l), bool))[None, :, :, None]
        Dlog = jnp.where(tri, Dlog, -jnp.inf)
        b_t = F + mst[:, None, :]  # (B,l,H) inter-chunk log coefficient
        m_t = jnp.maximum(jnp.max(Dlog, axis=2), b_t)  # (B,l,H)
        m_t = jax.lax.stop_gradient(m_t)
        w_intra = jnp.exp(Dlog - m_t[:, :, None, :])  # (B,t,s,H)
        c_inter = jnp.exp(b_t - m_t)  # (B,l,H)

        scores = jnp.einsum("blhd,bshd->blsh", qq, kk) * w_intra
        num = jnp.einsum("blsh,bshd->blhd", scores, vv)
        num = num + c_inter[..., None] * jnp.einsum("blhd,bhde->blhe", qq, Cst)
        den = jnp.sum(scores, axis=2) + c_inter * jnp.einsum("blhd,bhd->blh", qq, nst)
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # state update
        g = F[:, -1]  # (B,H) total chunk decay
        wk = jnp.exp(g[:, None, :] - F + iii)  # (B,l,H) per-key weight (unstab.)
        m_new = jnp.maximum(g + mst, jnp.max(jnp.log(jnp.maximum(wk, 1e-38)), axis=1))
        m_new = jax.lax.stop_gradient(m_new)
        wk_st = jnp.exp(g[:, None, :] - F + iii - m_new[:, None, :])
        decay = jnp.exp(g + mst - m_new)
        C_new = decay[:, :, None, None] * Cst + jnp.einsum("blhd,blhe,blh->bhde", kk, vv, wk_st)
        n_new = decay[:, :, None] * nst + jnp.einsum("blhd,blh->bhd", kk, wk_st)
        return (C_new, n_new, m_new), hout

    xs = (
        qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
        lf.transpose(1, 0, 2, 3), ii.transpose(1, 0, 2, 3),
    )
    (Cst, nst, mst), hs = jax.lax.scan(chunk_step, (state["C"], state["n"], state["m"]), xs)
    h_out = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
    return h_out.astype(q.dtype), {"C": Cst, "n": nst, "m": mst}


def _mlstm_step(q, k, v, i_raw, f_raw, state):
    """Single-token recurrent mLSTM. q,k: (B,1,H,dqk); v: (B,1,H,dv)."""
    b, _, h, dqk = q.shape
    scale = 1.0 / (dqk**0.5)
    qq = (q[:, 0] * scale).astype(jnp.float32)
    kk = k[:, 0].astype(jnp.float32)
    vv = v[:, 0].astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_raw[:, 0].astype(jnp.float32))  # (B,H)
    ii = i_raw[:, 0].astype(jnp.float32)
    m_new = jnp.maximum(lf + state["m"], ii)
    f_st = jnp.exp(lf + state["m"] - m_new)
    i_st = jnp.exp(ii - m_new)
    C_new = f_st[:, :, None, None] * state["C"] + i_st[:, :, None, None] * jnp.einsum(
        "bhd,bhe->bhde", kk, vv
    )
    n_new = f_st[:, :, None] * state["n"] + i_st[:, :, None] * kk
    num = jnp.einsum("bhd,bhde->bhe", qq, C_new)
    den = jnp.einsum("bhd,bhd->bh", qq, n_new)
    hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return hout[:, None].astype(q.dtype), {"C": C_new, "n": n_new, "m": m_new}


def _mlstm_block(lp, x, cfg, state=None, conv_state=None, step=False):
    """Full mLSTM block. Returns (out, new_state, new_conv_state)."""
    d_inner, h, dh = _dims(cfg)
    b, s, _ = x.shape
    hin = C.rmsnorm(x, lp["ln"], cfg.norm_eps)
    up = C.linear(lp["up"], hin)
    xm, z = up[..., :d_inner], up[..., d_inner:]
    xc, conv_state = _causal_conv(xm, lp["conv"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dqk = _qk_dim(cfg)
    q = C.linear(lp["wq"], xc).reshape(b, s, h, dqk)
    k = C.linear(lp["wk"], xc).reshape(b, s, h, dqk)
    v = C.linear(lp["wv"], xm).reshape(b, s, h, dh)
    gates = C.linear(lp["wif"], xc).reshape(b, s, h, 2)
    i_raw, f_raw = gates[..., 0], gates[..., 1] + 3.0  # forget-gate bias init
    if state is None:
        state = {
            "C": jnp.zeros((b, h, dqk, dh), jnp.float32),
            "n": jnp.zeros((b, h, dqk), jnp.float32),
            "m": jnp.full((b, h), -1e30, jnp.float32),
        }
    core = _mlstm_step if step else _mlstm_chunkwise
    hcell, state = core(q, k, v, i_raw, f_raw, state)
    hcell = C.rmsnorm(hcell.reshape(b, s, d_inner), lp["gn"], cfg.norm_eps)
    out = C.linear(lp["down"], hcell * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    return x + out, state, conv_state


# ---------------------------------------------------------------------------
# sLSTM core (sequential scan)
# ---------------------------------------------------------------------------


def _slstm_cell(lp, x, cfg, state=None, step=False):
    """x: (B, S, D). Scalar-memory LSTM with exp gating + block-diag recurrence."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    pre_all = C.linear(lp["w"], x).astype(jnp.float32)  # (B,S,4D)
    r = lp["r"].astype(jnp.float32)  # (H, dh, 4dh)
    if state is None:
        state = {
            "h": jnp.zeros((b, d), jnp.float32),
            "c": jnp.zeros((b, d), jnp.float32),
            "n": jnp.ones((b, d), jnp.float32),
            "m": jnp.zeros((b, d), jnp.float32),
        }

    def cell(st, pre_t):
        hp = st["h"].reshape(b, h, dh)
        rec = jnp.einsum("bhd,hde->bhe", hp, r).reshape(b, 4 * d)
        # interleave: pre_t (B,4D) ordered [i,f,z,o] along last dim blocks of D
        pre = pre_t + rec.reshape(b, 4, d).reshape(b, 4 * d)
        i_t, f_t, z_t, o_t = jnp.split(pre, 4, axis=-1)
        m_new = jnp.maximum(jax.nn.log_sigmoid(f_t) + st["m"], i_t)
        i_st = jnp.exp(i_t - m_new)
        f_st = jnp.exp(jax.nn.log_sigmoid(f_t) + st["m"] - m_new)
        c_new = f_st * st["c"] + i_st * jnp.tanh(z_t)
        n_new = f_st * st["n"] + i_st
        h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
        return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}, h_new

    if step:
        state, h_out = cell(state, pre_all[:, 0])
        return h_out[:, None].astype(x.dtype), state
    # rec applies per step: recurrent weights make this sequential
    pre_seq = pre_all.transpose(1, 0, 2).reshape(s, b, 4, d).reshape(s, b, 4 * d)
    state, hs = jax.lax.scan(cell, state, pre_seq)
    return hs.transpose(1, 0, 2).astype(x.dtype), state


def _slstm_cell_sharded(lp, x, cfg):
    """Train-path sLSTM under shard_map over the batch (dp) axes.

    Without this, autodiff of the time scan places the recurrent-weight
    gradient all-reduce INSIDE the per-timestep loop (measured 412 GB/device
    of collectives at 4k seq — §Perf cell A iteration 3); shard_map keeps the
    recurrence batch-local and psums parameter gradients once at the exit."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.models.context import get_mesh_context

    ctx = get_mesh_context()
    if ctx.mesh is None or not ctx.dp_axes or x.shape[0] % ctx.mesh.shape[ctx.dp_axes[0]] != 0:
        return _slstm_cell(lp, x, cfg)[0]
    dp = tuple(ctx.dp_axes)

    def body(lp_, x_):
        return _slstm_cell(lp_, x_, cfg)[0]

    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(jax.tree.map(lambda _: P(), lp), P(dp, None, None)),
        out_specs=P(dp, None, None),
        check_rep=False,
    )(lp, x)


def _slstm_block(lp, x, cfg, state=None, step=False):
    hin = C.rmsnorm(x, lp["ln"], cfg.norm_eps)
    if not step and state is None:
        hcell = _slstm_cell_sharded(lp, hin, cfg)
    else:
        hcell, state = _slstm_cell(lp, hin, cfg, state, step)
    hcell = C.rmsnorm(hcell, lp["gn"], cfg.norm_eps)
    x = x + hcell
    x = x + C.mlp_apply(lp["ffn"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return x, state


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def _trunk(params, cfg: ModelConfig, x, pad_to_chunk=True):
    """Run all blocks (training/prefill, fresh state). Returns hidden."""
    b, s, d = x.shape
    chunk = _pick_chunk(max(s, CHUNK_MIN))
    pad = (-s) % (CHUNK_MIN if s < CHUNK else chunk)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    n_seg, m_per = _segments(cfg)

    def m_body(x, lp):
        out, _, _ = _mlstm_block(lp, x, cfg)
        return out, None

    if cfg.remat:
        m_body = jax.checkpoint(m_body)

    if n_seg == 0:
        x, _ = jax.lax.scan(m_body, x, params["m_layers"])
    else:
        def seg_body(x, seg_params):
            mls, sls = seg_params
            x, _ = jax.lax.scan(m_body, x, mls)
            x, _ = _slstm_block(sls, x, cfg)
            return x, None

        if cfg.remat:
            seg_body = jax.checkpoint(seg_body)
        x, _ = jax.lax.scan(seg_body, x, (params["m_layers"], params["s_layers"]))
    return x[:, :s]


def forward(params, cfg: ModelConfig, tokens):
    x = C.embed_lookup(params["embed"], tokens)
    x = _trunk(params, cfg, x)
    x = C.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return C.linear(params["head"], x)


def loss_fn(params, cfg: ModelConfig, batch):
    x = C.embed_lookup(params["embed"], batch["tokens"])
    h = C.rmsnorm(_trunk(params, cfg, x), params["ln_f"], cfg.norm_eps)
    return C.cross_entropy_chunked(
        h[:, :-1], batch["labels"][:, 1:], lambda xc: C.linear(params["head"], xc)
    )


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=C.DTYPE):
    """Recurrent state — O(1) in sequence length (the long_500k enabler)."""
    d_inner, h, dh = _dims(cfg)
    n_seg, m_per = _segments(cfg)
    n_m = cfg.n_layers if n_seg == 0 else n_seg * m_per
    mshape = (n_seg, m_per) if n_seg else (n_m,)
    dqk = _qk_dim(cfg)
    st = {
        "mC": jnp.zeros((*mshape, batch, h, dqk, dh), jnp.float32),
        "mn": jnp.zeros((*mshape, batch, h, dqk), jnp.float32),
        "mm": jnp.full((*mshape, batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((*mshape, batch, 3, d_inner), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if n_seg:
        st.update(
            sh=jnp.zeros((n_seg, batch, cfg.d_model), jnp.float32),
            sc=jnp.zeros((n_seg, batch, cfg.d_model), jnp.float32),
            sn=jnp.ones((n_seg, batch, cfg.d_model), jnp.float32),
            sm=jnp.zeros((n_seg, batch, cfg.d_model), jnp.float32),
        )
    return st


def decode_step(params, cfg: ModelConfig, state, tokens):
    """tokens (B,1) single-step decode through the recurrent states."""
    x = C.embed_lookup(params["embed"], tokens)
    n_seg, m_per = _segments(cfg)

    def m_body(x, lp_st):
        lp, Cst, nst, mst, conv = lp_st
        out, new_st, new_conv = _mlstm_block(
            lp, x, cfg, {"C": Cst, "n": nst, "m": mst}, conv, step=True
        )
        return out, (new_st["C"], new_st["n"], new_st["m"], new_conv)

    if n_seg == 0:
        x, (mC, mn, mm, conv) = jax.lax.scan(
            m_body, x, (params["m_layers"], state["mC"], state["mn"], state["mm"], state["conv"])
        )
        new_state = {**state, "mC": mC, "mn": mn, "mm": mm, "conv": conv, "pos": state["pos"] + 1}
    else:
        def seg_body(x, seg):
            mls, mC, mn, mm, conv, sls, sh, sc, sn, sm = seg
            x, (mC, mn, mm, conv) = jax.lax.scan(m_body, x, (mls, mC, mn, mm, conv))
            sst = {"h": sh, "c": sc, "n": sn, "m": sm}
            x, sst = _slstm_block(sls, x, cfg, sst, step=True)
            return x, (mC, mn, mm, conv, sst["h"], sst["c"], sst["n"], sst["m"])

        x, (mC, mn, mm, conv, sh, sc, sn, sm) = jax.lax.scan(
            seg_body, x,
            (params["m_layers"], state["mC"], state["mn"], state["mm"], state["conv"],
             params["s_layers"], state["sh"], state["sc"], state["sn"], state["sm"]),
        )
        new_state = {"mC": mC, "mn": mn, "mm": mm, "conv": conv,
                     "sh": sh, "sc": sc, "sn": sn, "sm": sm, "pos": state["pos"] + 1}
    x = C.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return C.linear(params["head"], x), new_state


# slot (batch) axis per decode-state leaf, negative from the trailing dims —
# broadcast target for the pad-validity mask in bucketed prefill
_B_AXIS = {"mC": -4, "mn": -3, "mm": -2, "conv": -3,
           "sh": -2, "sc": -2, "sn": -2, "sm": -2, "pos": -1}


def prefill(params, cfg: ModelConfig, tokens, state, length=None):
    """Prefill = run the chunkwise trunk, then capture final states by
    replaying the last partial chunk... For simplicity and exactness we run
    the sequence through decode_step via scan when capturing state is needed;
    the serving path uses prefill for logits and decode for continuation.

    ``length`` (B,) marks the real prompt length under bucket padding: logits
    come from position length-1 and recurrent-state updates are gated off for
    pad steps (the state is not page-addressable, so pads must not touch it)."""
    # chunkwise trunk for logits; state capture via per-chunk final states
    x = C.embed_lookup(params["embed"], tokens)
    h = _trunk(params, cfg, x)
    h = C.rmsnorm(C.select_at_length(h, length), params["ln_f"], cfg.norm_eps)
    logits = C.linear(params["head"], h)

    def step(st, t_i):
        t, i = t_i
        lg, new = decode_step(params, cfg, st, t[:, None])
        if length is not None:
            valid = i < jnp.asarray(length, jnp.int32).reshape(-1)
            new = C.gate_state_update(new, st, valid, _B_AXIS)
        return new, ()

    s = tokens.shape[1]
    state, _ = jax.lax.scan(step, state, (tokens.T, jnp.arange(s)))
    return logits, state


def count_params(cfg: ModelConfig):
    d = cfg.d_model
    d_inner, h, dh = _dims(cfg)
    dqk = _qk_dim(cfg)
    m_layer = (d * 2 * d_inner + 4 * d_inner + 2 * d_inner * dqk * h
               + d_inner * d_inner + d_inner * 2 * h + d_inner * d + 2 * d_inner + d)
    s_layer = 4 * d * d + h * (d // h) * 4 * (d // h) + 3 * d * 2 * d + 4 * d
    n_seg, m_per = _segments(cfg)
    n_m = cfg.n_layers if n_seg == 0 else n_seg * m_per
    n_s = 0 if n_seg == 0 else n_seg
    total = n_m * m_layer + n_s * s_layer + cfg.padded_vocab * d * 2 + d
    return total, total
