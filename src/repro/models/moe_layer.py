"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch,
and expert parallelism via shard_map + all_to_all over the EP mesh axis.

Two execution paths, numerically equivalent when the mesh is trivial:

* **local** (no mesh context): all experts resident, sort-based dispatch,
  no collectives — used by CPU smoke tests and single-device examples.
* **EP** (mesh context set): tokens sharded over (dp_axes + ep_axis), expert
  weights sharded over ep_axis; each shard routes its local tokens, packs
  per-expert capacity buffers, exchanges them with a single
  ``jax.lax.all_to_all`` (the jax-native analogue of the NCCL a2a the GPU
  systems use), runs its resident experts, and reverses the exchange.
  FSDP'd expert weights are all-gathered over the fsdp axes inside the shard
  (ZeRO-3 semantics, explicit).

The einsum-one-hot GShard formulation is deliberately NOT used: at
DeepSeek-V3 scale its dispatch tensor is O(T·E·C) and its einsum FLOPs exceed
the expert FLOPs by orders of magnitude (see DESIGN.md §5).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs import ModelConfig
from repro.models import common as C
from repro.models.context import get_mesh_context


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    f = cfg.d_ff_expert
    e = cfg.n_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    std = 1.0 / (d**0.5)
    p = {
        "router": (jax.random.normal(kr, (d, e)) * std).astype(jnp.float32),
        "gate": {"w": (jax.random.normal(kg, (e, d, f)) * std).astype(C.DTYPE)},
        "up": {"w": (jax.random.normal(ku, (e, d, f)) * std).astype(C.DTYPE)},
        "down": {"w": (jax.random.normal(kd, (e, f, d)) * (1.0 / f**0.5)).astype(C.DTYPE)},
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared"] = C.mlp_init(ks, d, fs)
    return p


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def route(router_w: jax.Array, x: jax.Array, cfg: ModelConfig):
    """x (T, D) -> (top_w (T,k) f32, top_i (T,k) i32, aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    # GShard/Switch load-balancing aux loss
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    one_hot = jax.nn.one_hot(top_i[:, 0], e)  # fraction by top-1 assignment
    fe = jnp.mean(one_hot, axis=0)
    aux = e * jnp.sum(fe * me)
    return top_w, top_i.astype(jnp.int32), aux


def _dispatch_indices(top_i: jax.Array, k: int, E: int, C: int):
    """Sort-based capacity assignment. Returns (slot (T*k,), tok (T*k,), keep)."""
    fe = top_i.reshape(-1)  # (T*k,)
    order = jnp.argsort(fe, stable=True)
    fe_s = fe[order]
    tok_s = order // k
    counts = jnp.bincount(fe_s, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(fe_s.shape[0], dtype=jnp.int32) - starts[fe_s].astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, fe_s * C + pos, E * C)  # overflow -> scratch row
    return slot, tok_s, order, keep


_EXPERT_KEYS = ("gate", "up", "gate_up", "down")


def _expert_ffn(p: dict, xb: jax.Array) -> jax.Array:
    """xb (E_loc, Cap, D) -> (E_loc, Cap, D); bf16 or quantized experts."""
    if "gate" in p and "w" in p["gate"]:
        g = jnp.einsum("ecd,edf->ecf", xb, p["gate"]["w"].astype(xb.dtype))
        u = jnp.einsum("ecd,edf->ecf", xb, p["up"]["w"].astype(xb.dtype))
        h = C.swiglu(g, u)
        return jnp.einsum("ecf,efd->ecd", h, p["down"]["w"].astype(xb.dtype))
    # quantized experts: vmap the linear dispatcher over the expert dim;
    # gate/up share the expert input, so they run as one fused launch
    def one(pe, xe):
        g, u = C.linear_group(pe, ("gate", "up"), "gate_up", xe)
        return C.linear(pe["down"], C.swiglu(g, u))

    return jax.vmap(one)(p, xb)


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


# ---------------------------------------------------------------------------
# local (collective-free) path
# ---------------------------------------------------------------------------


def _moe_local(p: dict, x: jax.Array, cfg: ModelConfig):
    t, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    Cp = _capacity(t, cfg)
    top_w, top_i, aux = route(p["router"], x, cfg)
    slot, tok_s, order, keep = _dispatch_indices(top_i, k, E, Cp)
    buf = jnp.zeros((E * Cp + 1, d), x.dtype).at[slot].set(x[tok_s])
    experts = {kk: p[kk] for kk in _EXPERT_KEYS if kk in p}
    yb = _expert_ffn(experts, buf[: E * Cp].reshape(E, Cp, d))
    yb = jnp.concatenate([yb.reshape(E * Cp, d), jnp.zeros((1, d), x.dtype)], axis=0)
    w_s = top_w.reshape(-1)[order].astype(x.dtype)
    contrib = yb[slot] * (w_s * keep.astype(x.dtype))[:, None]
    out = jnp.zeros((t, d), x.dtype).at[tok_s].add(contrib)
    return out, aux


# ---------------------------------------------------------------------------
# expert-parallel path (shard_map over the mesh)
# ---------------------------------------------------------------------------


def _moe_shard_body(x, router_w, gate, up, down, *, cfg: ModelConfig, ep_axis: str,
                    ep_size: int, fsdp_axes: tuple[str, ...], all_axes: tuple[str, ...]):
    """Per-shard body. x: (T_loc, D); experts: (E_loc, ...) local slices."""
    ep = ep_size  # static mesh extent (jax.lax.axis_size is newer-jax-only)
    for ax in fsdp_axes:  # ZeRO-3: gather the fsdp-sharded expert dims
        gate = jax.lax.all_gather(gate, ax, axis=1, tiled=True)
        up = jax.lax.all_gather(up, ax, axis=1, tiled=True)
        down = jax.lax.all_gather(down, ax, axis=2, tiled=True)
    t, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    e_loc = E // ep
    Cp = _capacity(t, cfg)

    top_w, top_i, aux = route(router_w, x, cfg)
    slot, tok_s, order, keep = _dispatch_indices(top_i, k, E, Cp)
    buf = jnp.zeros((E * Cp + 1, d), x.dtype).at[slot].set(x[tok_s])
    buf = buf[: E * Cp].reshape(ep, e_loc, Cp, d)
    # exchange: shard i sends its buffer slice for shard j's experts to j
    buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0, tiled=False)
    xin = buf.transpose(1, 0, 2, 3).reshape(e_loc, ep * Cp, d)
    y = _expert_ffn({"gate": {"w": gate}, "up": {"w": up}, "down": {"w": down}}, xin)
    y = y.reshape(e_loc, ep, Cp, d).transpose(1, 0, 2, 3)
    y = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0, tiled=False)
    yb = jnp.concatenate([y.reshape(E * Cp, d), jnp.zeros((1, d), x.dtype)], axis=0)
    w_s = top_w.reshape(-1)[order].astype(x.dtype)
    contrib = yb[slot] * (w_s * keep.astype(x.dtype))[:, None]
    out = jnp.zeros((t, d), x.dtype).at[tok_s].add(contrib)
    return out, jax.lax.pmean(aux, all_axes)[None]


def _token_axes_for(ctx, t: int) -> tuple[str, ...]:
    """Largest prefix of (dp + ep) axes whose product divides the token count
    (decode steps may have fewer tokens than devices)."""
    axes = []
    prod = 1
    for ax in ctx.token_axes:
        size = ctx.mesh.shape[ax]
        if t % (prod * size) != 0:
            break
        axes.append(ax)
        prod *= size
    return tuple(axes)


def _moe_ep(p: dict, x: jax.Array, cfg: ModelConfig):
    ctx = get_mesh_context()
    mesh = ctx.mesh
    tok_axes = _token_axes_for(ctx, x.shape[0])
    ep_axis = ctx.ep_axis
    fsdp = tuple(ax for ax in ctx.fsdp_axes if ax != ep_axis)
    fs = fsdp if fsdp else None

    def body(xx, rw, g, u, dn):
        return _moe_shard_body(
            xx, rw, g, u, dn, cfg=cfg, ep_axis=ep_axis, ep_size=mesh.shape[ep_axis],
            fsdp_axes=fsdp, all_axes=tok_axes or (ep_axis,)
        )
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(tok_axes, None),
            P(None, None),
            P(ep_axis, fs, None),  # gate (E, D, F): E over ep, D over fsdp
            P(ep_axis, fs, None),  # up
            P(ep_axis, None, fs),  # down (E, F, D): D over fsdp
        ),
        out_specs=(P(tok_axes, None), P(None)),
        check_rep=False,
    )(x, p["router"], p["gate"]["w"], p["up"]["w"], p["down"]["w"])
    return out, jnp.mean(aux)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig):
    """x (B, S, D) -> (B, S, D), aux_loss. Chooses EP vs local path by mesh
    context; adds the shared-expert branch (DeepSeek-style) if present."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    ctx = get_mesh_context()
    use_ep = (
        ctx.mesh is not None
        and ctx.ep_size > 1
        and cfg.n_experts % ctx.ep_size == 0
        # EP shard_map path is bf16-experts only (for now)
        and "gate" in p and "w" in p["gate"]
    )
    if use_ep:
        out, aux = _moe_ep(p, xf, cfg)
    else:
        out, aux = _moe_local(p, xf, cfg)
    if "shared" in p:
        out = out + C.mlp_apply(p["shared"], xf)
    return out.reshape(b, s, d), aux
