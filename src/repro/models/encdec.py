"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, n_frames, d_model). LayerNorm + GELU MLP
(whisper convention). Sinusoidal positions on both sides — whisper's learned
decoder positions cap at 448, which cannot express the assigned decode_32k
shape, so we substitute sinusoidal (recorded in DESIGN.md §9).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import common as C


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(C.DTYPE)


def _ln_init(d):
    return {"w": jnp.ones((d,), C.DTYPE), "b": jnp.zeros((d,), C.DTYPE)}


def _gelu_mlp_init(key, d, f):
    k1, k2 = jax.random.split(key)
    return {"up": C.dense_init(k1, d, f, bias=True), "down": C.dense_init(k2, f, d, bias=True)}


def _gelu_mlp(p, x):
    h = jax.nn.gelu(C.linear(p["up"], x).astype(jnp.float32)).astype(x.dtype)
    return C.linear(p["down"], h)


def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn": C.attn_init(k1, cfg),
        "mlp": _gelu_mlp_init(k2, cfg.d_model, cfg.d_ff),
        "ln1": _ln_init(cfg.d_model),
        "ln2": _ln_init(cfg.d_model),
    }


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn": C.attn_init(k1, cfg),
        "xattn": C.attn_init(k2, cfg),
        "mlp": _gelu_mlp_init(k3, cfg.d_model, cfg.d_ff),
        "ln1": _ln_init(cfg.d_model),
        "ln2": _ln_init(cfg.d_model),
        "ln3": _ln_init(cfg.d_model),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    ke, k1, k2 = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(jax.random.split(k1, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(jax.random.split(k2, cfg.n_layers))
    return {
        "embed": C.embed_init(ke, cfg.padded_vocab, cfg.d_model),
        "enc_layers": enc,
        "dec_layers": dec,
        "ln_enc": _ln_init(cfg.d_model),
        "ln_f": _ln_init(cfg.d_model),
    }


def _ln(p, x, eps):
    return C.layernorm(x, p["w"], p["b"], eps)


def _mha(p, q_in, kv_in, cfg, mask):
    b, sq, _ = q_in.shape
    sk = kv_in.shape[1]
    h, hd = cfg.n_heads, cfg.head_dim
    if q_in is kv_in:  # self-attention: q/k/v share the input -> one launch
        q, k, v = C.linear_group(p, ("q", "k", "v"), "qkv", q_in)
    else:  # cross-attention: k/v share the encoder states -> one launch
        q = C.linear(p["q"], q_in)
        k, v = C.linear_group(p, ("k", "v"), "kv", kv_in)
    q = q.reshape(b, sq, h, hd)
    k = k.reshape(b, sk, h, hd)
    v = v.reshape(b, sk, h, hd)
    out = C._sdpa(q, k, v, mask)
    return C.linear(p["o"], out.reshape(b, sq, h * hd))


def encode(params: dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, n_frames, D) stub embeddings -> encoder states."""
    b, s, d = frames.shape
    x = frames.astype(C.DTYPE) + _sinusoid(jnp.arange(s)[None, :], d)
    full = jnp.ones((1, s, s), bool)

    def body(x, lp):
        h_in = _ln(lp["ln1"], x, cfg.norm_eps)
        x = x + _mha(lp["attn"], h_in, h_in, cfg, full)
        return x + _gelu_mlp(lp["mlp"], _ln(lp["ln2"], x, cfg.norm_eps)), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(params["ln_enc"], x, cfg.norm_eps)


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, frames: jax.Array):
    """Teacher-forced decoder over encoded frames. Returns (B, S, V) logits."""
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    x = C.embed_lookup(params["embed"], tokens) + _sinusoid(jnp.arange(s)[None, :], cfg.d_model)
    full = jnp.ones((1, s, enc.shape[1]), bool)

    def body(x, lp):
        h_in = _ln(lp["ln1"], x, cfg.norm_eps)
        hh, hd = cfg.n_heads, cfg.head_dim
        qq, kk, vv = C.linear_group(lp["attn"], ("q", "k", "v"), "qkv", h_in)
        qq = qq.reshape(b, s, hh, hd)
        kk = kk.reshape(b, s, hh, hd)
        vv = vv.reshape(b, s, hh, hd)
        x = x + C.linear(lp["attn"]["o"], C.sdpa_causal(qq, kk, vv).reshape(b, s, hh * hd))
        x = x + _mha(lp["xattn"], _ln(lp["ln2"], x, cfg.norm_eps), enc, cfg, full)
        return x + _gelu_mlp(lp["mlp"], _ln(lp["ln3"], x, cfg.norm_eps)), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(params["ln_f"], x, cfg.norm_eps)
    # tied head
    return jnp.einsum("bsd,vd->bsv", x, C.embed_attend(params["embed"]).astype(x.dtype))


def _hidden(params: dict, cfg: ModelConfig, tokens: jax.Array, frames: jax.Array):
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    x = C.embed_lookup(params["embed"], tokens) + _sinusoid(jnp.arange(s)[None, :], cfg.d_model)
    full = jnp.ones((1, s, enc.shape[1]), bool)

    def body(x, lp):
        h_in = _ln(lp["ln1"], x, cfg.norm_eps)
        hh, hd = cfg.n_heads, cfg.head_dim
        qq, kk, vv = C.linear_group(lp["attn"], ("q", "k", "v"), "qkv", h_in)
        qq = qq.reshape(b, s, hh, hd)
        kk = kk.reshape(b, s, hh, hd)
        vv = vv.reshape(b, s, hh, hd)
        x = x + C.linear(lp["attn"]["o"], C.sdpa_causal(qq, kk, vv).reshape(b, s, hh * hd))
        x = x + _mha(lp["xattn"], _ln(lp["ln2"], x, cfg.norm_eps), enc, cfg, full)
        return x + _gelu_mlp(lp["mlp"], _ln(lp["ln3"], x, cfg.norm_eps)), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return _ln(params["ln_f"], x, cfg.norm_eps)


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    h = _hidden(params, cfg, batch["tokens"], batch["frames"])

    def head(xc):
        return jnp.einsum("bsd,vd->bsv", xc, C.embed_attend(params["embed"]).astype(xc.dtype))

    return C.cross_entropy_chunked(h[:, :-1], batch["labels"][:, 1:], head)


# ---------------------------------------------------------------------------
# serving: cross-attention K/V computed once at prefill; decoder self-KV cached
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=C.DTYPE):
    h, hd = cfg.n_heads, cfg.head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, h, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, h, hd), dtype),
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.n_frames, h, hd), dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.n_frames, h, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, state: dict,
            frames: jax.Array = None, length=None):
    """``length`` (B,) marks the real prompt length when tokens are padded to
    a bucket (causal self-attention keeps real positions exact; logits and
    ``pos`` come from position length-1)."""
    enc = encode(params, cfg, frames)
    b = enc.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim

    def xkv(lp):
        k, v = C.linear_group(lp["xattn"], ("k", "v"), "kv", enc)
        return k.reshape(b, -1, h, hd), v.reshape(b, -1, h, hd)

    xk, xv = jax.vmap(xkv)(params["dec_layers"])
    s = tokens.shape[1]
    x = C.embed_lookup(params["embed"], tokens) + _sinusoid(jnp.arange(s)[None, :], cfg.d_model)
    full = jnp.ones((1, s, enc.shape[1]), bool)

    def body(x, lp_x):
        lp, xk_l, xv_l = lp_x
        h_in = _ln(lp["ln1"], x, cfg.norm_eps)
        q, k, v = C.linear_group(lp["attn"], ("q", "k", "v"), "qkv", h_in)
        q = q.reshape(b, s, h, hd)
        k = k.reshape(b, s, h, hd)
        v = v.reshape(b, s, h, hd)
        x = x + C.linear(lp["attn"]["o"], C.sdpa_causal(q, k, v).reshape(b, s, h * hd))
        q2 = C.linear(lp["xattn"]["q"], _ln(lp["ln2"], x, cfg.norm_eps)).reshape(b, s, h, hd)
        x = x + C.linear(lp["xattn"]["o"], C._sdpa(q2, xk_l, xv_l, full).reshape(b, s, h * hd))
        x = x + _gelu_mlp(lp["mlp"], _ln(lp["ln3"], x, cfg.norm_eps))
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["dec_layers"], xk, xv))
    state = {
        "k": jax.lax.dynamic_update_slice(state["k"], ks.astype(state["k"].dtype), (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(state["v"], vs.astype(state["v"].dtype), (0, 0, 0, 0, 0)),
        "xk": xk.astype(state["xk"].dtype),
        "xv": xv.astype(state["xv"].dtype),
        "pos": C.prefill_pos(length, b, s),
    }
    x = _ln(params["ln_f"], C.select_at_length(x, length), cfg.norm_eps)
    return jnp.einsum("bsd,vd->bsv", x, C.embed_attend(params["embed"]).astype(x.dtype)), state


def decode_step(params: dict, cfg: ModelConfig, state: dict, tokens: jax.Array):
    b = tokens.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    pos = C.slot_positions(state["pos"], b)[:, 0]  # (B,) per-slot positions
    x = C.embed_lookup(params["embed"], tokens) + _sinusoid(pos[:, None], cfg.d_model)
    paged = "bt" in state  # self-attn K/V paged; xk/xv stay per-slot state

    def body(x, lp_cache):
        lp, kc, vc, xk_l, xv_l = lp_cache
        if paged:
            kc = C.gather_pages(kc, state["bt"])
            vc = C.gather_pages(vc, state["bt"])
        h_in = _ln(lp["ln1"], x, cfg.norm_eps)
        q, k, v = C.linear_group(lp["attn"], ("q", "k", "v"), "qkv", h_in)
        q = q.reshape(b, 1, h, hd)
        k = k.reshape(b, 1, h, hd)
        v = v.reshape(b, 1, h, hd)
        kc = C.update_cache_slot(kc, k, pos)
        vc = C.update_cache_slot(vc, v, pos)
        s_max = kc.shape[1]
        mask = jnp.arange(s_max)[None, None, :] <= pos[:, None, None]
        x = x + C.linear(lp["attn"]["o"], C._sdpa(q, kc, vc, mask).reshape(b, 1, h * hd))
        full = jnp.ones((b, 1, xk_l.shape[1]), bool)
        q2 = C.linear(lp["xattn"]["q"], _ln(lp["ln2"], x, cfg.norm_eps)).reshape(b, 1, h, hd)
        x = x + C.linear(lp["xattn"]["o"], C._sdpa(q2, xk_l, xv_l, full).reshape(b, 1, h * hd))
        x = x + _gelu_mlp(lp["mlp"], _ln(lp["ln3"], x, cfg.norm_eps))
        return x, (k, v) if paged else (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], state["k"], state["v"], state["xk"], state["xv"])
    )
    x = _ln(params["ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, C.embed_attend(params["embed"]).astype(x.dtype))
    if paged:
        # ks/vs are the one-token lines (L, B, 1, H, hd): scatter into pages
        new_state = {
            **state,
            "k": C.scatter_token_pages(state["k"], ks, state["bt"], pos),
            "v": C.scatter_token_pages(state["v"], vs, state["bt"], pos),
            "pos": pos + 1,
        }
    else:
        new_state = {**state, "k": ks, "v": vs, "pos": pos + 1}
    return logits, new_state


def count_params(cfg: ModelConfig):
    d, f, h, hd = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.head_dim
    attn = 4 * d * h * hd
    enc_l = attn + 2 * d * f + 4 * d
    dec_l = 2 * attn + 2 * d * f + 6 * d
    total = cfg.n_enc_layers * enc_l + cfg.n_layers * dec_l + cfg.padded_vocab * d + 4 * d
    return total, total
