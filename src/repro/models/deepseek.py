"""DeepSeek-V3: MLA attention + (first_k_dense dense layers, then MoE layers
with 1 shared + 256 routed experts, top-8) + optional MTP head.

Two scan-stacked parameter groups (dense_layers / moe_layers) keep the HLO
size depth-independent while honoring the heterogeneous layer stack.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import common as C
from repro.models.mla import (
    mla_decode,
    mla_decode_paged,
    mla_init,
    mla_init_cache,
    mla_prefill_layer,
    mla_train,
)
from repro.models.moe_layer import moe_ffn, moe_init


def _dense_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn": mla_init(k1, cfg),
        "mlp": C.mlp_init(k2, cfg.d_model, cfg.d_ff),
        "ln1": jnp.ones((cfg.d_model,), C.DTYPE),
        "ln2": jnp.ones((cfg.d_model,), C.DTYPE),
    }


def _moe_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn": mla_init(k1, cfg),
        "moe": moe_init(k2, cfg),
        "ln1": jnp.ones((cfg.d_model,), C.DTYPE),
        "ln2": jnp.ones((cfg.d_model,), C.DTYPE),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kd, km, kh, kt = jax.random.split(key, 5)
    nd = cfg.first_k_dense
    nm = cfg.n_layers - nd
    dense_layers = jax.vmap(lambda k: _dense_layer_init(k, cfg))(jax.random.split(kd, nd))
    moe_layers = jax.vmap(lambda k: _moe_layer_init(k, cfg))(jax.random.split(km, nm))
    p = {
        "embed": C.embed_init(ke, cfg.padded_vocab, cfg.d_model),
        "dense_layers": dense_layers,
        "moe_layers": moe_layers,
        "ln_f": jnp.ones((cfg.d_model,), C.DTYPE),
        "head": C.dense_init(kh, cfg.d_model, cfg.padded_vocab),
    }
    if cfg.mtp:
        k1, k2 = jax.random.split(kt)
        p["mtp"] = {
            "proj": C.dense_init(k1, 2 * cfg.d_model, cfg.d_model),
            "layer": _dense_layer_init(k2, cfg.replace(d_ff=cfg.d_ff_expert * 4)),
            "ln_in": jnp.ones((2 * cfg.d_model,), C.DTYPE),
        }
    return p


def _dense_block(lp, x, cfg):
    x = x + mla_train(lp["attn"], C.rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg)
    return x + C.mlp_apply(lp["mlp"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps))


def _moe_block(lp, x, aux, cfg):
    x = x + mla_train(lp["attn"], C.rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg)
    m, a = moe_ffn(lp["moe"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
    return x + m, aux + a


def _trunk(params: dict, cfg: ModelConfig, tokens: jax.Array):
    x = C.embed_lookup(params["embed"], tokens)

    def dbody(x, lp):
        return _dense_block(lp, x, cfg), None

    def mbody(carry, lp):
        x, aux = carry
        x, aux = _moe_block(lp, x, aux, cfg)
        return (x, aux), None

    if cfg.remat:
        dbody = jax.checkpoint(dbody)
        mbody = jax.checkpoint(mbody)
    x, _ = jax.lax.scan(dbody, x, params["dense_layers"])
    (x, aux), _ = jax.lax.scan(mbody, (x, jnp.zeros((), jnp.float32)), params["moe_layers"])
    return x, aux / max(1, cfg.n_layers - cfg.first_k_dense)


def _head(params):
    return lambda xc: C.linear(params["head"], xc)


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array):
    x, aux = _trunk(params, cfg, tokens)
    return _unembed(params, cfg, x), aux, x


def _unembed(params, cfg, x):
    x = C.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return C.linear(params["head"], x)


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    h_final, aux = _trunk(params, cfg, tokens)
    hn = C.rmsnorm(h_final, params["ln_f"], cfg.norm_eps)
    ce = C.cross_entropy_chunked(hn[:, :-1], labels[:, 1:], _head(params))
    loss = ce + cfg.router_aux_weight * aux
    if cfg.mtp and "mtp" in params:
        # Multi-token prediction: predict t+2 from (h_t, emb(tok_{t+1}))
        mp = params["mtp"]
        emb_next = C.embed_lookup(params["embed"], tokens[:, 1:])
        h = h_final[:, :-1]
        cat = jnp.concatenate([h, emb_next.astype(h.dtype)], axis=-1)
        cat = C.rmsnorm(cat, mp["ln_in"], cfg.norm_eps)
        h_mtp = C.linear(mp["proj"], cat)
        h_mtp = _dense_block(mp["layer"], h_mtp, cfg.replace(d_ff=cfg.d_ff_expert * 4))
        h_mtp = C.rmsnorm(h_mtp, params["ln_f"], cfg.norm_eps)
        ce_mtp = C.cross_entropy_chunked(h_mtp[:, :-1], labels[:, 2:], _head(params))
        loss = loss + 0.3 * ce_mtp
    return loss


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=C.DTYPE):
    return mla_init_cache(cfg, batch, max_len, cfg.n_layers, dtype)


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, state: dict,
            length=None, prefix=None):
    """Prompt prefill. ``length`` marks the real prompt length when tokens
    are bucket-padded; ``prefix`` = {"ckv": (L, B, m, kvr), "krope": ...} is
    a cached latent prefix (shared pages) — tokens then hold the suffix only
    and the expanded attention runs over [expanded prefix; causal suffix]."""
    x = C.embed_lookup(params["embed"], tokens)
    b, s, _ = x.shape
    nd = cfg.first_k_dense

    def dbody(x, lp_ctx):
        lp = lp_ctx if prefix is None else lp_ctx[0]
        pre = None if prefix is None else lp_ctx[1:]
        h = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        att, ckv, krope = mla_prefill_layer(lp["attn"], h, cfg, prefix=pre)
        x = x + att
        x = x + C.mlp_apply(lp["mlp"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, (ckv, krope)

    def mbody(x, lp_ctx):
        lp = lp_ctx if prefix is None else lp_ctx[0]
        pre = None if prefix is None else lp_ctx[1:]
        h = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        att, ckv, krope = mla_prefill_layer(lp["attn"], h, cfg, prefix=pre)
        x = x + att
        m, _ = moe_ffn(lp["moe"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x + m, (ckv, krope)

    if prefix is None:
        off = 0
        dxs, mxs = params["dense_layers"], params["moe_layers"]
    else:
        off = prefix["ckv"].shape[2]
        dxs = (params["dense_layers"], prefix["ckv"][:nd], prefix["krope"][:nd])
        mxs = (params["moe_layers"], prefix["ckv"][nd:], prefix["krope"][nd:])
    x, (ckv_d, kr_d) = jax.lax.scan(dbody, x, dxs)
    x, (ckv_m, kr_m) = jax.lax.scan(mbody, x, mxs)
    ckv = jnp.concatenate([ckv_d, ckv_m], axis=0)
    krope = jnp.concatenate([kr_d, kr_m], axis=0)
    state = {
        "ckv": jax.lax.dynamic_update_slice(
            state["ckv"], ckv.astype(state["ckv"].dtype), (0, 0, 0, 0)
        ),
        "krope": jax.lax.dynamic_update_slice(
            state["krope"], krope.astype(state["krope"].dtype), (0, 0, 0, 0)
        ),
        "pos": off + C.prefill_pos(length, b, s),
    }
    return _unembed(params, cfg, C.select_at_length(x, length)), state


def decode_step(params: dict, cfg: ModelConfig, state: dict, tokens: jax.Array):
    x = C.embed_lookup(params["embed"], tokens)
    pos = C.slot_positions(state["pos"], tokens.shape[0])[:, 0]
    nd = cfg.first_k_dense
    paged = "bt" in state

    def attend(lp, x, ckv, krope):
        h = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if paged:
            att, ckv_t, krope_t = mla_decode_paged(
                lp["attn"], h, cfg, ckv, krope, state["bt"], pos
            )
            return x + att, (ckv_t, krope_t)
        att, ckv, krope = mla_decode(lp["attn"], h, cfg, ckv, krope, pos)
        return x + att, (ckv, krope)

    def dbody(x, lp_cache):
        lp, ckv, krope = lp_cache
        x, carry = attend(lp, x, ckv, krope)
        x = x + C.mlp_apply(lp["mlp"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, carry

    def mbody(x, lp_cache):
        lp, ckv, krope = lp_cache
        x, carry = attend(lp, x, ckv, krope)
        m, _ = moe_ffn(lp["moe"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x + m, carry

    x, (ckv_d, kr_d) = jax.lax.scan(
        dbody, x, (params["dense_layers"], state["ckv"][:nd], state["krope"][:nd])
    )
    x, (ckv_m, kr_m) = jax.lax.scan(
        mbody, x, (params["moe_layers"], state["ckv"][nd:], state["krope"][nd:])
    )
    if paged:
        # scanned outputs are the one-token latent lines (L, B, 1, r):
        # one pool scatter each after the layer scans
        ckv_t = jnp.concatenate([ckv_d, ckv_m], axis=0)
        krope_t = jnp.concatenate([kr_d, kr_m], axis=0)
        new_state = {
            **state,
            "ckv": C.scatter_token_pages(state["ckv"], ckv_t, state["bt"], pos),
            "krope": C.scatter_token_pages(state["krope"], krope_t, state["bt"], pos),
            "pos": pos + 1,
        }
    else:
        new_state = {
            "ckv": jnp.concatenate([ckv_d, ckv_m], axis=0),
            "krope": jnp.concatenate([kr_d, kr_m], axis=0),
            "pos": pos + 1,
        }
    return _unembed(params, cfg, x), new_state


def count_params(cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    attn = d * qr + qr * h * (nope + rope) + d * (kvr + rope) + kvr * h * (nope + vd) + h * vd * d
    dense_mlp = 3 * d * cfg.d_ff
    expert = 3 * d * cfg.d_ff_expert
    nd, nm = cfg.first_k_dense, cfg.n_layers - cfg.first_k_dense
    shared = cfg.n_shared_experts * expert
    total = nd * (attn + dense_mlp) + nm * (
        attn + cfg.n_experts * expert + shared + d * cfg.n_experts
    )
    active = nd * (attn + dense_mlp) + nm * (attn + cfg.top_k * expert + shared + d * cfg.n_experts)
    emb = cfg.padded_vocab * d * 2
    return total + emb, active + emb
