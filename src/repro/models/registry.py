"""Model registry: family -> (init, loss, prefill, decode, counters).

Uniform protocol used by launch/{train,serve,dryrun}.py and the tests:

    init_params(cfg, key) -> params
    loss_fn(params, cfg, batch) -> scalar loss        (train_step lowers this)
    init_decode_state(cfg, batch, max_len) -> state
    prefill(params, cfg, tokens, state[, frontend, length, prefix]) -> (logits, state)
    decode_step(params, cfg, state, tokens) -> (logits, state)

Serving extensions (DESIGN.md §14): every family's ``prefill`` accepts
``length (B,)`` — the real prompt length when tokens are padded to a bucket
(causal attention keeps real positions exact; recurrent families gate state
updates past ``length``). Attention-KV families whose state is fully
page-addressable (dense/vlm via models.dense, moe, mla_moe) also accept
``prefix`` — already-cached prefix K/V (or latents) gathered from shared
pages, so a prefix-cache hit prefills only the suffix. ``decode_step``
transparently serves the paged state layout (``models.common.
init_paged_state``): the presence of a block table ``state["bt"]`` switches
the cache read/write to page gather/scatter at trace time.

Families whose attention state is page-addressable AND whose forward is a
plain GQA stack additionally expose ``ragged_step(params, cfg, state,
tokens, slot, pos, ctx, logit_idx)`` — the unified chunked-prefill + decode
step the ragged engine mode uses (docs/serving.md). Families without the
attribute fall back to bucketed prefill + lock-step decode.
"""

from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import dense, deepseek, encdec, mamba_hybrid, olmoe, xlstm


def _vlm_loss(params, cfg, batch):
    return dense.loss_fn(params, cfg, batch)


def _vlm_prefill(params, cfg, tokens, state, patches=None, length=None, prefix=None):
    return dense.prefill(params, cfg, tokens, state, patches=patches,
                         length=length, prefix=prefix)


_DENSE = SimpleNamespace(
    init_params=dense.init_params,
    loss_fn=dense.loss_fn,
    forward=dense.forward,
    init_decode_state=dense.init_decode_state,
    prefill=dense.prefill,
    decode_step=dense.decode_step,
    ragged_step=dense.ragged_step,
    count_params=dense.count_params,
)

_VLM = SimpleNamespace(
    init_params=dense.init_params,
    loss_fn=_vlm_loss,
    forward=dense.forward,
    init_decode_state=dense.init_decode_state,
    prefill=_vlm_prefill,
    decode_step=dense.decode_step,
    count_params=dense.count_params,
)

_FAMILIES = {
    "dense": _DENSE,
    "vlm": _VLM,
    "moe": SimpleNamespace(
        init_params=olmoe.init_params,
        loss_fn=olmoe.loss_fn,
        forward=olmoe.forward,
        init_decode_state=olmoe.init_decode_state,
        prefill=olmoe.prefill,
        decode_step=olmoe.decode_step,
        ragged_step=olmoe.ragged_step,
        count_params=olmoe.count_params,
    ),
    "mla_moe": SimpleNamespace(
        init_params=deepseek.init_params,
        loss_fn=deepseek.loss_fn,
        forward=deepseek.forward,
        init_decode_state=deepseek.init_decode_state,
        prefill=deepseek.prefill,
        decode_step=deepseek.decode_step,
        count_params=deepseek.count_params,
    ),
    "encdec": SimpleNamespace(
        init_params=encdec.init_params,
        loss_fn=encdec.loss_fn,
        forward=encdec.forward,
        init_decode_state=encdec.init_decode_state,
        prefill=encdec.prefill,
        decode_step=encdec.decode_step,
        count_params=encdec.count_params,
    ),
    "xlstm": SimpleNamespace(
        init_params=xlstm.init_params,
        loss_fn=xlstm.loss_fn,
        forward=xlstm.forward,
        init_decode_state=xlstm.init_decode_state,
        prefill=xlstm.prefill,
        decode_step=xlstm.decode_step,
        count_params=xlstm.count_params,
    ),
    "mamba_hybrid": SimpleNamespace(
        init_params=mamba_hybrid.init_params,
        loss_fn=mamba_hybrid.loss_fn,
        forward=mamba_hybrid.forward,
        init_decode_state=mamba_hybrid.init_decode_state,
        prefill=mamba_hybrid.prefill,
        decode_step=mamba_hybrid.decode_step,
        count_params=mamba_hybrid.count_params,
    ),
}


def get_model(cfg: ModelConfig) -> SimpleNamespace:
    return _FAMILIES[cfg.family]


def count_total_params(cfg: ModelConfig) -> int:
    return int(get_model(cfg).count_params(cfg)[0])


def count_active_params(cfg: ModelConfig) -> int:
    return int(get_model(cfg).count_params(cfg)[1])


# ---------------------------------------------------------------------------
# input specs: ShapeDtypeStruct stand-ins for every model input per shape
# (the dry-run deliverable). No device allocation.
# ---------------------------------------------------------------------------

SHAPE_SETS = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def input_specs(cfg: ModelConfig, shape_name: str, reduced: bool = False) -> dict:
    """ShapeDtypeStructs for the given (arch x shape) cell's step function."""
    spec = SHAPE_SETS[shape_name]
    b, s = spec["batch"], spec["seq"]
    if reduced:
        b, s = min(b, 2), min(s, 2 * 256)
    i32 = jnp.int32
    out = {}
    if spec["kind"] == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    elif spec["kind"] == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    else:  # decode: one token, cache of length s
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
    return out


def applicable_shapes(cfg: ModelConfig) -> dict[str, str]:
    """shape -> 'run' | reason-for-skip, per the assignment rules."""
    out = {}
    for name in SHAPE_SETS:
        if name == "long_500k" and not cfg.sub_quadratic:
            out[name] = "skip: full-attention arch; 500k decode needs sub-quadratic attention"
        else:
            out[name] = "run"
    return out
