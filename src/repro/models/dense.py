"""Dense decoder-only transformer (qwen2 / stablelm / phi4 / internlm2 /
llama3 / qwen3) and the VLM variant (internvl2: stub patch embeddings
prepended to the token sequence).

Layers are scan-stacked: params["layers"] holds (L, ...) arrays and the
forward pass is a single jax.lax.scan over layers — essential to keep HLO
size and SPMD-partitioning time flat in depth for the 512-device dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import common as C


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn": C.attn_init(k1, cfg),
        "mlp": C.mlp_init(k2, cfg.d_model, cfg.d_ff),
        "ln1": jnp.ones((cfg.d_model,), C.DTYPE),
        "ln2": jnp.ones((cfg.d_model,), C.DTYPE),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    p = {
        "embed": C.embed_init(ke, cfg.padded_vocab, cfg.d_model),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), C.DTYPE),
    }
    if not cfg.tie_embeddings:
        p["head"] = C.dense_init(kh, cfg.d_model, cfg.padded_vocab)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _block_train(lp: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = x + C.attention_train(lp["attn"], C.rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg)
    return h + C.mlp_apply(lp["mlp"], C.rmsnorm(h, lp["ln2"], cfg.norm_eps))


def _embed(params, cfg: ModelConfig, tokens: jax.Array, patches=None) -> jax.Array:
    x = C.embed_lookup(params["embed"], tokens)
    if patches is not None:  # VLM: prepend stub patch embeddings
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    return x


def head_fn(params, cfg: ModelConfig):
    """Chunk-applicable unembed: (B, c, D) -> (B, c, V)."""
    if cfg.tie_embeddings:
        return lambda xc: jnp.einsum(
            "bsd,vd->bsv", xc, C.embed_attend(params["embed"]).astype(xc.dtype)
        )
    return lambda xc: C.linear(params["head"], xc)


def _unembed(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return head_fn(params, cfg)(C.rmsnorm(x, params["ln_f"], cfg.norm_eps))


def hidden_states(params: dict, cfg: ModelConfig, tokens: jax.Array, patches=None) -> jax.Array:
    x = _embed(params, cfg, tokens, patches)

    def body(x, lp):
        return _block_train(lp, x, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return C.rmsnorm(x, params["ln_f"], cfg.norm_eps)


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, patches=None) -> jax.Array:
    """tokens (B, S) -> logits (B, S[, +P], padded_vocab)."""
    return head_fn(params, cfg)(hidden_states(params, cfg, tokens, patches))


def forward_with_taps(params: dict, cfg: ModelConfig, tokens: jax.Array):
    """Forward that also returns per-layer calibration activations:
    {'attn': (L, T, D) ln1 outputs, 'mlp': (L, T, D) ln2 outputs} — the
    inputs seen by the q/k/v and gate/up linears (the paper's per-layer
    calibration set)."""
    x = _embed(params, cfg, tokens)

    def body(x, lp):
        h1 = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        h = x + C.attention_train(lp["attn"], h1, cfg)
        h2 = C.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        out = h + C.mlp_apply(lp["mlp"], h2)
        b, s, d = h1.shape
        return out, (h1.reshape(b * s, d), h2.reshape(b * s, d))

    x, (t1, t2) = jax.lax.scan(body, x, params["layers"])
    logits = _unembed(params, cfg, x)
    return logits, {"attn": t1, "mlp": t2}


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    patches = batch.get("patches")
    h = hidden_states(params, cfg, batch["tokens"], patches)
    if patches is not None:
        h = h[:, patches.shape[1] :]  # loss on the text positions only
    return C.cross_entropy_chunked(h[:, :-1], batch["labels"][:, 1:], head_fn(params, cfg))


# ---------------------------------------------------------------------------
# serving (prefill + decode with KV cache)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=C.DTYPE) -> dict:
    return C.init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype)


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, state: dict,
            patches=None, length=None, prefix=None):
    """Run the prompt, filling the cache. Returns (last_logits, state).

    ``length`` (B,) marks the real prompt length when ``tokens`` is padded to
    a bucket (launch/serve.py's prompt bucketing): attention is causal so pad
    tokens at the tail cannot perturb real positions, and the returned logits
    / ``pos`` come from position ``length-1`` instead of the pad tail.

    ``prefix`` = {"k": (L, B, m, KV, hd), "v": ...} is an already-cached
    (post-RoPE) prompt prefix (the engine's prefix cache, gathered from shared
    pages): ``tokens`` then holds only the SUFFIX, every suffix query attends
    [prefix; causal suffix], positions are offset by m, and the returned
    cache rows contain the suffix only (the engine maps the shared pages)."""
    x = _embed(params, cfg, tokens, patches)
    b, s, _ = x.shape
    off = 0 if prefix is None else prefix["k"].shape[2]
    positions = (off + jnp.arange(s))[None, :] * jnp.ones((b, 1), jnp.int32)
    mask = None if prefix is None else C.prefix_attn_mask(s, off)

    def body(x, lp_ctx):
        lp = lp_ctx if prefix is None else lp_ctx[0]
        h = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        att, k, v = C.gqa_prefill_attn(
            lp["attn"], h, cfg, positions,
            prefix_kv=None if prefix is None else lp_ctx[1:], mask=mask,
        )
        x = x + att
        x = x + C.mlp_apply(lp["mlp"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, (k, v)

    xs = params["layers"] if prefix is None else (params["layers"], prefix["k"], prefix["v"])
    x, (ks, vs) = jax.lax.scan(body, x, xs)
    # VLM: the patch tokens prepended to the sequence are all real
    eff = None if length is None else (
        jnp.asarray(length, jnp.int32).reshape(-1) + (s - tokens.shape[1])
    )
    state = {
        "k": jax.lax.dynamic_update_slice(state["k"], ks.astype(state["k"].dtype), (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(state["v"], vs.astype(state["v"].dtype), (0, 0, 0, 0, 0)),
        "pos": off + C.prefill_pos(eff, b, s),
    }
    return _unembed(params, cfg, C.select_at_length(x, eff)), state


def decode_step(params: dict, cfg: ModelConfig, state: dict, tokens: jax.Array):
    """tokens (B, sq) -> (logits (B, sq, V), new state). ``sq`` new tokens per
    slot (sq == 1 plain decode; sq > 1 stacks speculative draft rows, paged
    state only) with a KV cache of max_len (the `decode_*` / `long_*` shapes
    lower THIS). state["pos"] is per-slot (B,): slots at different timeline
    offsets decode in lock-step (continuous batching).

    The layer scan reads the cache READ-ONLY and emits each layer's (k_t,
    v_t) rows; the cache is updated with a single batched scatter after the
    scan — per-step cache write traffic is O(L·B·sq·KV·hd), not
    O(L·B·S·KV·hd) (§Perf cell C iteration 2). The paged branch routes the
    in-kernel block-table attention (kind ``paged_decode``): no
    ``gather_pages`` dense view is materialized on this path."""
    x = C.embed_lookup(params["embed"], tokens)
    b, sq = tokens.shape
    pos = C.slot_positions(state["pos"], b)[:, 0]
    paged = "bt" in state  # paged pool + block table vs dense per-slot cache

    def body(x, lp_cache):
        lp, kc, vc = lp_cache
        h = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if paged:
            att, kt, vt = C.paged_attn(lp["attn"], h, cfg, kc, vc, state["bt"], pos)
        else:
            att, kt, vt = C.attention_decode_ro(lp["attn"], h, cfg, kc, vc, pos)
        x = x + att
        x = x + C.mlp_apply(lp["mlp"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, (kt, vt)

    x, (kts, vts) = jax.lax.scan(body, x, (params["layers"], state["k"], state["v"]))
    if paged:
        slot = jnp.repeat(jnp.arange(b, dtype=jnp.int32), sq)
        rows = C.slot_positions(pos, b, sq).reshape(-1)
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        new_state = {
            **state,
            "k": C.scatter_rows_pages(
                state["k"], kts.reshape(cfg.n_layers, b * sq, kvh, hd),
                state["bt"], slot, rows),
            "v": C.scatter_rows_pages(
                state["v"], vts.reshape(cfg.n_layers, b * sq, kvh, hd),
                state["bt"], slot, rows),
            "pos": pos + sq,
        }
    else:
        new_state = {
            "k": C.update_cache_slot_stacked(state["k"], kts, pos),
            "v": C.update_cache_slot_stacked(state["v"], vts, pos),
            "pos": pos + sq,
        }
    return _unembed(params, cfg, x), new_state


def ragged_step(params: dict, cfg: ModelConfig, state: dict, tokens: jax.Array,
                slot: jax.Array, pos: jax.Array, ctx: jax.Array,
                logit_idx: jax.Array):
    """One unified ragged engine step: prefill chunks + decode tokens of all
    live slots in a single launch over a flat (T,) token batch.

    ``tokens/slot/pos (T,)`` are the ragged rows (``slot == B`` marks
    padding), ``ctx (B,)`` each slot's committed cache length at step start,
    ``logit_idx (B,)`` the row whose logits each slot wants back (its decode
    token, or the last prompt token of a chunk that completes the prompt —
    garbage for idle slots, the engine ignores those). Requires the paged
    state ("bt" + page pools): chunked prefill is exact because a token's
    K/V depend only on tokens at positions <= its own, all of which are
    either committed pages or earlier rows of this same batch. Returns
    (logits (B, V), new_state); new pos is ctx + per-slot scheduled counts.
    """
    x = C.embed_lookup(params["embed"], tokens[None, :])

    def body(x, lp_cache):
        lp, kc, vc = lp_cache
        h = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        att, kt, vt = C.ragged_attn(
            lp["attn"], h, cfg, kc, vc, state["bt"], slot, pos, ctx
        )
        x = x + att
        x = x + C.mlp_apply(lp["mlp"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, (kt, vt)

    x, (kts, vts) = jax.lax.scan(body, x, (params["layers"], state["k"], state["v"]))
    b = ctx.shape[0]
    counts = jnp.sum(
        slot[None, :] == jnp.arange(b, dtype=jnp.int32)[:, None], axis=1
    )
    new_state = {
        **state,
        "k": C.scatter_rows_pages(state["k"], kts, state["bt"], slot, pos),
        "v": C.scatter_rows_pages(state["v"], vts, state["bt"], slot, pos),
        "pos": ctx.astype(jnp.int32) + counts.astype(jnp.int32),
    }
    return _unembed(params, cfg, x[0][logit_idx][None])[0], new_state


# ---------------------------------------------------------------------------
# parameter counting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig) -> tuple[int, int]:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    mlp = 3 * d * f
    per_layer = attn + mlp + 2 * d
    emb = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    total = cfg.n_layers * per_layer + emb + d
    return total, total
