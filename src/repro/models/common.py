"""Shared model substrate: norms, RoPE, linears (with quantized dispatch),
GQA attention with KV cache, embedding/init helpers.

Parameters are plain nested dicts of jnp arrays (scan-stacked per layer).
Every matmul in the network goes through :func:`linear`, which dispatches on
the parameter keys:

    {"w" [, "b"]}                          -> bf16 dense
    {"wp", "ws" [, "b"]}                   -> W4A16 weight-only (packed int4)
    {"up","us","vp","vs","rp","rs" [,"b"]} -> TwinQuant dual-component W4A4/W4A8

so TwinQuant is a first-class precision mode of the whole framework, not a
bolt-on — quantize_model() rewrites the params pytree and every architecture
(dense/MoE/MLA/SSM/...) picks it up through this one dispatcher.

Sibling projections that consume the SAME activation (q/k/v, gate/up,
wq_a/wkv_a) go through :func:`linear_group`, which merges packed
dual-component siblings into ONE fused launch (kernels/dispatch.fused_linear)
— either from a pre-merged pack produced by ``core.twinquant.fuse_params``
(key ``qkv`` / ``gate_up`` / ``wqkv_a``; checkpoints stay unfused on disk) or
by fusing the sibling packs at trace time — and falls back to per-sibling
:func:`linear` for every other precision mode.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig

DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# logits tap + finite guard (serving fault tolerance)
# ---------------------------------------------------------------------------

# Host-side observation/injection point for freshly-downloaded logits. The
# serving engine routes every sync-point logits download through
# ``logits_tap`` so fault-injection harnesses (launch/faults.py) can corrupt
# one slot's row deterministically, and checks ``nonfinite_rows`` right after
# to quarantine slots whose logits went NaN/Inf. Identity (zero-cost) unless
# a tap is installed.
_logits_tap: Optional[Callable] = None


def set_logits_tap(fn: Optional[Callable]) -> Optional[Callable]:
    """Install ``fn(last, tag) -> last`` as the host logits tap (``None`` to
    remove). ``last`` is the host np.ndarray just downloaded at a sync point;
    ``tag`` names the call site (``"prefill"`` / ``"decode"`` / ``"ragged"``).
    Returns the previously-installed tap so callers can restore it."""
    global _logits_tap
    prev = _logits_tap
    _logits_tap = fn
    return prev


def logits_tap(last: np.ndarray, tag: str) -> np.ndarray:
    """Route a freshly-downloaded host logits array through the installed
    tap, if any. Called by the engine at every sync-point download."""
    if _logits_tap is None:
        return last
    return _logits_tap(last, tag)


def nonfinite_rows(last: np.ndarray, vocab: int) -> list:
    """Indices of rows of ``last (..., V)`` holding any NaN/Inf inside the
    first ``vocab`` columns (padded tail columns are ignored). The engine's
    finite-logits guard: a non-empty result quarantines those slots."""
    finite = np.isfinite(last[..., :vocab]).all(axis=-1)
    return [int(i) for i in np.flatnonzero(~finite.reshape(-1))]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, bias: bool = False, scale: float = 1.0):
    std = scale / (d_in**0.5)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * std).astype(DTYPE)}
    if bias:
        p["b"] = jnp.zeros((d_out,), DTYPE)
    return p


def embed_init(key, vocab: int, d: int):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(DTYPE)


def _cs(x: jax.Array, *spec_dims) -> jax.Array:
    """Context-aware sharding constraint (no-op without a mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models.context import get_mesh_context

    ctx = get_mesh_context()
    if ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*spec_dims)))


def embed_attend(embed: jax.Array) -> jax.Array:
    """Constrain the embedding table at its use site. Without this the SPMD
    partitioner materializes replicated f32 embed gradients when the table is
    used by both the input gather and a tied head (measured 4x temp blowup —
    EXPERIMENTS §Perf iteration log)."""
    from repro.models.context import get_mesh_context

    ctx = get_mesh_context()
    if ctx.mesh is None:
        return embed
    fsdp = tuple(ctx.fsdp_axes) or None
    return _cs(embed, ctx.tp_axis, fsdp)


def embed_lookup(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    """Sharded token-embedding gather: (B, S) -> (B, S, D)."""
    from repro.models.context import get_mesh_context

    ctx = get_mesh_context()
    x = embed_attend(embed)[tokens]
    if ctx.mesh is None:
        return x
    dp = tuple(ctx.dp_axes) or None
    return _cs(x, dp, *([None] * (x.ndim - 1)))


# ---------------------------------------------------------------------------
# linear dispatch (bf16 / w4a16 / twinquant)
# ---------------------------------------------------------------------------


def linear(p: dict, x: jax.Array) -> jax.Array:
    """Apply a (possibly quantized) linear layer; x: (..., K) -> (..., N)."""
    if "w" in p:
        y = jnp.einsum("...k,kn->...n", x, p["w"].astype(x.dtype))
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        return y
    if "r_dq" in p:  # quantized-numerics simulation (benchmarks; exact W4Ax math)
        from repro.core.quantization import QuantConfig, fake_quant

        xh = x / p["lam"].astype(x.dtype)
        if "Q" in p:
            xh = jnp.einsum("...k,kq->...q", xh, p["Q"].astype(x.dtype))
        a_bits = p["abits"].shape[-1]
        if a_bits < 16:
            k = xh.shape[-1]
            xh = fake_quant(xh, QuantConfig(bits=a_bits, group_size=min(128, k), axis=-1))
        w_eff = p["r_dq"].astype(x.dtype)
        y = jnp.einsum("...k,kn->...n", xh, w_eff)
        if "u_dq" in p:
            h = jnp.einsum("...k,kr->...r", xh, p["u_dq"].astype(x.dtype))
            if a_bits < 16:  # H requantization (the fused kernel's s_H step)
                r = h.shape[-1]
                h = fake_quant(h, QuantConfig(bits=a_bits, group_size=min(128, r), axis=-1))
            y = y + jnp.einsum("...r,rn->...n", h, p["v_dq"].astype(x.dtype))
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        return y
    if "rp" in p:  # TwinQuant dual-component pack
        from repro.kernels.dispatch import quant_linear
        from repro.kernels.ref import TwinQuantWeights

        # static metadata is encoded in (static) shapes: scale-group sizes
        # from packed-vs-scale row ratios, activation bits from the `abits`
        # marker array's length — keeps the params pytree jit-pure
        w = TwinQuantWeights(
            up=p["up"], us=p["us"], vp=p["vp"], vs=p["vs"], rp=p["rp"], rs=p["rs"],
            group=p["rp"].shape[-2] * 2 // p["rs"].shape[-2],
            rgroup=p["vp"].shape[-2] * 2 // p["vs"].shape[-2],
            a_bits=p["abits"].shape[-1],
        )
        # routed by shape regime (prefill / decode / ref) at trace time; on
        # CPU the routed schedule executes with oracle numerics (dispatch.py)
        return quant_linear(x, w, p.get("b")).astype(x.dtype)
    if "wp" in p:  # W4A16 weight-only pack
        from repro.kernels.dispatch import w4a16_linear

        return w4a16_linear(
            x, p["wp"], p["ws"], p.get("b"),
            group=p["wp"].shape[-2] * 2 // p["ws"].shape[-2],
        ).astype(x.dtype)
    raise KeyError(f"unrecognized linear params: {sorted(p)}")


def _group_weights_of(fp: dict):
    """Fused-pack param dict ({up,us,rp,rs,abits,vp0,vs0,...}) -> group pack.

    Like the single-pack branch of :func:`linear`, all static metadata is
    recovered from (static) array shapes so the params pytree stays jit-pure.
    """
    from repro.kernels.ref import TwinQuantGroupWeights

    vps, vss = [], []
    while f"vp{len(vps)}" in fp:
        vps.append(fp[f"vp{len(vps)}"])
        vss.append(fp[f"vs{len(vss)}"])
    return TwinQuantGroupWeights(
        up=fp["up"], us=fp["us"], vps=tuple(vps), vss=tuple(vss),
        rp=fp["rp"], rs=fp["rs"],
        group=fp["rp"].shape[-2] * 2 // fp["rs"].shape[-2],
        rgroups=tuple(
            vp.shape[-2] * 2 // vs.shape[-2] for vp, vs in zip(vps, vss)
        ),
        a_bits=fp["abits"].shape[-1],
    )


def _fusable_packs(ps) -> bool:
    """Sibling param dicts that can merge into one fused launch: all packed
    dual-component (unstacked at this call site), same K, scale group, and
    activation bits — derived from static shapes only."""
    if not all(isinstance(pp, dict) and "rp" in pp for pp in ps):
        return False
    base = ps[0]
    group = base["rp"].shape[-2] * 2 // base["rs"].shape[-2]
    return all(
        pp["rp"].ndim == 2
        and pp["rp"].shape[0] == base["rp"].shape[0]
        and pp["rp"].shape[-2] * 2 // pp["rs"].shape[-2] == group
        and pp["abits"].shape == base["abits"].shape
        for pp in ps
    )


def linear_group(p: dict, names: tuple, fused_key: str, x: jax.Array) -> tuple:
    """Apply sibling projections of ONE activation as a fused launch.

    Resolution order:
      1. ``p[fused_key]`` exists (quantization-time pack merging via
         ``core.twinquant.fuse_params`` — checkpoints stay unfused on disk,
         the in-memory tree carries the merged pack): one fused launch.
         This is the serving configuration (the engine pre-merges).
      2. the siblings ``p[name]`` are fusable dual-component packs and
         fusion is enabled: fuse at trace time and launch once. The
         concatenation runs INSIDE the traced step (packs are jit arguments,
         not constants), so this path pays an extra copy of each fused
         weight pack per execution — correct everywhere, but hot loops
         should pre-merge with ``fuse_params`` instead.
      3. otherwise (bf16, w4a16, sim dicts, mixed precision, fusion
         disabled): one :func:`linear` per sibling — the pre-fusion path.
         ``set_fusion(False)`` also forces a pre-merged pack (case 1) to
         execute per segment, so the A/B toggle is honest for both layouts.

    Returns one output per sibling, in ``names`` order.
    """
    from repro.kernels.dispatch import fused_linear, fusion_enabled, quant_linear

    fp = p.get(fused_key)
    if fp is not None:
        gw = _group_weights_of(fp)
        biases = gw.split(fp["b"]) if "b" in fp else (None,) * gw.n_segments
        if not fusion_enabled():  # A/B lane: per-segment launches
            return tuple(
                quant_linear(x, gw.segment(j), biases[j]).astype(x.dtype)
                for j in range(gw.n_segments)
            )
        return tuple(
            y.astype(x.dtype) for y in fused_linear(x, gw, biases)
        )
    ps = [p[n] for n in names]
    if fusion_enabled() and _fusable_packs(ps):
        from repro.kernels.ref import TwinQuantWeights

        ws = [
            TwinQuantWeights(
                up=pp["up"], us=pp["us"], vp=pp["vp"], vs=pp["vs"],
                rp=pp["rp"], rs=pp["rs"],
                group=pp["rp"].shape[-2] * 2 // pp["rs"].shape[-2],
                rgroup=pp["vp"].shape[-2] * 2 // pp["vs"].shape[-2],
                a_bits=pp["abits"].shape[-1],
            )
            for pp in ps
        ]
        ys = fused_linear(x, ws, biases=[pp.get("b") for pp in ps])
        return tuple(y.astype(x.dtype) for y in ys)
    return tuple(linear(pp, x) for pp in ps)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    norm = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return norm * w.astype(x.dtype) + b.astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


# ---------------------------------------------------------------------------
# RoPE (partial-fraction aware)
# ---------------------------------------------------------------------------


def rope_tables(positions: jax.Array, head_dim: int, fraction: float, theta: float):
    """cos/sin tables for the rotated sub-dimension. positions: (...,)"""
    rot = int(head_dim * fraction) // 2 * 2
    if rot == 0 or theta <= 0:
        return None
    freqs = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., rot/2)
    return jnp.cos(ang), jnp.sin(ang), rot


def apply_rope(x: jax.Array, tables) -> jax.Array:
    """x: (B, S, H, hd); tables from rope_tables with positions (B, S)."""
    if tables is None:
        return x
    cos, sin, rot = tables
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1) if xp.shape[-1] else yr


# ---------------------------------------------------------------------------
# GQA attention (train / prefill / decode-with-cache)
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, mask) -> jax.Array:
    """q: (B,Sq,H,hd), k: (B,Sk,KV,hd), v: (B,Sk,KV,hd_v); GQA via head
    grouping; qk and v head dims may differ (MLA). f32 softmax."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    logits = logits / (hd**0.5)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])


# memory-efficient causal attention: never materializes the (Sq, Sk) score
# matrix — online-softmax over KV blocks (flash-attention recurrence), with
# fully-masked blocks skipped via lax.cond. Used by every train/prefill path
# once S exceeds _ATTN_CHUNK; without it the 4k/32k shapes need O(S^2) temp
# (hundreds of GB/device at 32k — see EXPERIMENTS.md §Perf iteration log).
_ATTN_CHUNK = 512


def _shard_heads(x: jax.Array, head_axis: int) -> jax.Array:
    """Constrain an attention tensor's head dim over the TP axis (when it
    divides) and its batch dim over dp. Without this the SPMD partitioner
    re-gathers the full stacked K/V per flash step (measured 12 TB/device on
    deepseek prefill — §Perf cell B iteration 1)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models.context import get_mesh_context

    ctx = get_mesh_context()
    if ctx.mesh is None or ctx.tp_axis is None:
        return x
    tp = ctx.mesh.shape[ctx.tp_axis]
    spec = [None] * x.ndim
    dp = tuple(ctx.dp_axes)
    dpn = 1
    for a in dp:
        dpn *= ctx.mesh.shape[a]
    if dp and x.shape[0] % dpn == 0:
        spec[0] = dp
    if x.shape[head_axis] % tp == 0:
        spec[head_axis] = ctx.tp_axis
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*spec)))


def _sdpa_causal_chunked(q, k, v, chunk: int = _ATTN_CHUNK) -> jax.Array:
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    hv = v.shape[-1]
    if s % chunk != 0 or s <= chunk:
        causal = jnp.tril(jnp.ones((s, s), bool))[None]
        return _sdpa(q, k, v, causal)
    n = s // chunk
    scale = hd**-0.5
    q = _shard_heads(q, 2)
    k = _shard_heads(k, 2)
    v = _shard_heads(v, 2)
    qb = (q * scale).reshape(b, n, chunk, kv, g, hd)
    kb = _shard_heads(k.reshape(b, n, chunk, kv, hd), 3)
    vb = _shard_heads(v.reshape(b, n, chunk, kv, hv), 3)

    def q_block(_, qi_and_q):
        qi, qq = qi_and_q  # qq (B, cq, KV, G, hd)

        def kv_step(carry, kj_and_kv):
            kj, kk, vv = kj_and_kv

            def compute(carry):
                m, l, acc = carry
                logits = jnp.einsum("bqkgh,bskh->bkgqs", qq, kk).astype(jnp.float32)
                qpos = qi * chunk + jnp.arange(chunk)
                kpos = kj * chunk + jnp.arange(chunk)
                causal = qpos[:, None] >= kpos[None, :]
                logits = jnp.where(causal[None, None, None], logits, -1e30)
                m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
                p = jnp.exp(logits - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bkgqs,bskh->bkgqh", p.astype(vv.dtype), vv
                ).astype(jnp.float32)
                return m_new, l_new, acc_new

            carry = jax.lax.cond(kj <= qi, compute, lambda c: c, carry)
            return carry, None

        init = (
            jnp.full((b, kv, g, chunk), -1e30, jnp.float32),
            jnp.zeros((b, kv, g, chunk), jnp.float32),
            jnp.zeros((b, kv, g, chunk, hv), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init,
            (jnp.arange(n), kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, cq, hv)
        return None, out.transpose(0, 3, 1, 2, 4).astype(v.dtype)  # (B, cq, KV, G, hv)

    _, blocks = jax.lax.scan(
        q_block, None, (jnp.arange(n), qb.transpose(1, 0, 2, 3, 4, 5))
    )
    # blocks: (n, B, cq, KV, G, hv)
    return blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hv)


def sdpa_causal(q, k, v) -> jax.Array:
    """Causal attention, memory-efficient for long sequences."""
    return _sdpa_causal_chunked(q, k, v)


def attention_train(p: dict, x: jax.Array, cfg: ModelConfig, positions=None,
                    segment_mask: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence causal attention (training / prefill)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = linear_group(p, ("q", "k", "v"), "qkv", x)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if positions is None:
        positions = jnp.arange(s)[None, :].astype(jnp.int32) * jnp.ones((b, 1), jnp.int32)
    tables = rope_tables(positions, hd, cfg.rope_fraction, cfg.rope_theta)
    q = apply_rope(q, tables)
    k = apply_rope(k, tables)
    if segment_mask is not None:
        causal = jnp.tril(jnp.ones((s, s), bool))[None] & segment_mask
        out = _sdpa(q, k, v, causal)
    else:
        out = sdpa_causal(q, k, v)
    return linear(p["o"], out.reshape(b, s, h * hd))


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=DTYPE) -> dict:
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((n_layers, batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, kvh, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# paged KV cache (DESIGN.md §14)
#
# The dense decode state keeps one (..., B, S_max, ...) cache per slot, so
# memory scales with slots x worst-case length. The paged layout replaces
# every sequence-carrying leaf with a global page pool
# (..., n_pages, page_size, ...) shared by all slots, plus one per-slot block
# table ``bt (B, max_pages)`` of page indices (-1 = unmapped). Decode gathers
# a slot's pages through its block-table row and scatters the new token into
# the slot's tail page; the host-side allocator in launch/serve.py owns the
# free list / refcounts. Which leaves become pools is decided STRUCTURALLY
# (paged_layout): a leaf whose shape changes with max_len carries the
# sequence axis and gets paged; everything else (recurrent SSM/xLSTM states,
# encoder K/V, pos) stays per-slot, exactly like the engine's slot-axis
# inference.
# ---------------------------------------------------------------------------


def paged_layout(init_fn, cfg: ModelConfig, max_len: int) -> dict:
    """Classify decode-state leaves: key -> (slot_axis, seq_axis | None).

    The slot axis comes from a batch-2 vs batch-1 ``eval_shape`` diff, the
    sequence axis from a max_len vs 2*max_len diff. Page pools require the
    canonical (..., B, S, ...) layout (seq axis right after the slot axis) —
    every family in the registry satisfies it, and a violation fails loudly
    here rather than corrupting pages later.
    """
    s2 = jax.eval_shape(lambda: init_fn(cfg, 2, max_len))
    s1 = jax.eval_shape(lambda: init_fn(cfg, 1, max_len))
    sl = jax.eval_shape(lambda: init_fn(cfg, 1, 2 * max_len))
    if not isinstance(s1, dict):
        raise TypeError("paged serving requires a flat dict decode state")
    out = {}
    for key in s1:
        slot = [i for i, (a, b) in enumerate(zip(s2[key].shape, s1[key].shape)) if a != b]
        seq = [i for i, (a, b) in enumerate(zip(s1[key].shape, sl[key].shape)) if a != b]
        if len(slot) != 1 or len(seq) > 1:
            raise ValueError(f"cannot classify state leaf {key!r}: "
                             f"{s2[key].shape} vs {s1[key].shape} vs {sl[key].shape}")
        # the paged runtime hard-codes (lead, B, S, ...) for pools: slot axis
        # 1, seq axis 2 (page writer / token scatter index the pool at axis 1)
        if seq and (slot[0] != 1 or seq[0] != 2):
            raise ValueError(f"page pools need (lead, B, S, ...) layout, got "
                             f"{key!r} with slot axis {slot[0]}, seq axis {seq[0]}")
        out[key] = (slot[0], seq[0] if seq else None)
    return out


def init_paged_state(init_fn, cfg: ModelConfig, batch: int, max_len: int,
                     page_size: int, n_pages: int) -> dict:
    """Paged decode state: sequence-carrying leaves become global page pools
    (lead, n_pages, page_size, trail); per-slot leaves are kept verbatim; a
    block table ``bt (B, ceil(max_len/page_size))`` maps slot timelines to
    pages. Families with no sequence leaves (pure recurrent state) get their
    dense state back unchanged — there is nothing to page."""
    layout = paged_layout(init_fn, cfg, max_len)
    st = dict(init_fn(cfg, batch, max_len))
    pooled = False
    for key, (slot, seq) in layout.items():
        if seq is None:
            continue
        sh = st[key].shape
        st[key] = jnp.zeros(sh[:slot] + (n_pages, page_size) + sh[seq + 1:], st[key].dtype)
        pooled = True
    if pooled:
        max_pages = -(-max_len // page_size)
        st["bt"] = jnp.full((batch, max_pages), -1, jnp.int32)
    return st


def gather_pages(pool_l: jax.Array, bt: jax.Array) -> jax.Array:
    """One layer's pool (P, page, ...) + block table (B, maxp) -> the dense
    per-slot view (B, maxp*page, ...). Unmapped (-1) entries read page 0;
    callers mask those rows with the per-slot ``pos`` prefix mask, exactly as
    the dense path masks rows >= pos."""
    b, maxp = bt.shape
    pages = pool_l[jnp.maximum(bt, 0)]  # (B, maxp, page, ...)
    return pages.reshape(b, maxp * pool_l.shape[1], *pool_l.shape[2:])


def scatter_token_pages(pool: jax.Array, t: jax.Array, bt: jax.Array,
                        pos: jax.Array) -> jax.Array:
    """Scatter each slot's one-token line into its tail page.

    pool (lead, P, page, ...), t (lead, B, 1, ...), bt (B, maxp), pos (B,).
    The target is page ``bt[b, pos_b // page]`` row ``pos_b % page``; slots
    whose target is unmapped (bt -1, e.g. an evicted slot decoding garbage in
    lock-step) or past the block table are dropped, mirroring the dense
    path's drop-not-clamp rule. The invalid sentinel is ``n_pages`` (one past
    the pool), NOT -1: negative indices are canonicalized NumPy-style before
    ``mode="drop"`` applies, so -1 would silently wrap into the LAST page and
    corrupt whichever slot owns it."""
    page = pool.shape[2]
    n_pages = pool.shape[1]
    b, maxp = bt.shape
    pi = pos // page
    page_id = bt[jnp.arange(b), jnp.minimum(pi, maxp - 1)]
    page_id = jnp.where((pi < maxp) & (page_id >= 0), page_id, n_pages)
    return pool.at[:, page_id, pos % page].set(t[:, :, 0].astype(pool.dtype), mode="drop")


def scatter_rows_pages(pool: jax.Array, t: jax.Array, bt: jax.Array,
                       slot: jax.Array, pos: jax.Array) -> jax.Array:
    """Scatter a ragged step's T rows into their slots' pages.

    pool (lead, P, page, ...), t (lead, T, ...), bt (B, maxp), slot (T,)
    with pad sentinel >= B, pos (T,). Row ``i`` lands in page
    ``bt[slot_i, pos_i // page]`` at offset ``pos_i % page``; pad rows,
    rows past the block table, and unmapped pages are dropped through the
    same ``n_pages`` OOB sentinel as :func:`scatter_token_pages` (-1 would
    wrap into the last page)."""
    page = pool.shape[2]
    n_pages = pool.shape[1]
    b, maxp = bt.shape
    pi = pos // page
    page_id = bt[jnp.clip(slot, 0, b - 1), jnp.minimum(pi, maxp - 1)]
    ok = (slot < b) & (pi < maxp) & (page_id >= 0)
    page_id = jnp.where(ok, page_id, n_pages)
    return pool.at[:, page_id, pos % page].set(t.astype(pool.dtype), mode="drop")


def ragged_attn(p: dict, h: jax.Array, cfg: ModelConfig, kp: jax.Array,
                vp: jax.Array, bt: jax.Array, slot: jax.Array,
                pos: jax.Array, ctx: jax.Array):
    """One layer's attention over a ragged mixed prefill/decode token batch.

    ``h (1, T, D)`` holds every live request's scheduled tokens for this
    engine step, flat; ``slot/pos (T,)`` map each row to its engine slot and
    absolute position (``slot == B`` is padding), ``ctx (B,)`` is each
    slot's committed cache length and ``kp/vp (P, page, KV, hd)`` are one
    layer's page pools behind the block tables ``bt (B, maxp)``. The fused
    q/k/v group launch runs ONCE over all T rows (prefill chunks and decode
    tokens share it — the engine-level analog of the dual-GEMM fusion), then
    the routed ragged-attention kernel attends cache prefix + same-slot
    in-batch causal prefix. Returns (out (1, T, D), k_t (T, KV, hd), v_t)
    with k_t/v_t post-RoPE, ready for the page scatter."""
    from repro.kernels.dispatch import ragged_attention

    _, t, _ = h.shape
    hh, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = linear_group(p, ("q", "k", "v"), "qkv", h)
    q = q.reshape(1, t, hh, hd)
    k = k.reshape(1, t, kvh, hd)
    v = v.reshape(1, t, kvh, hd)
    tables = rope_tables(pos[None, :], hd, cfg.rope_fraction, cfg.rope_theta)
    q = apply_rope(q, tables)
    k = apply_rope(k, tables)
    out = ragged_attention(q[0], kp, vp, k[0], v[0], bt, slot, pos, ctx)
    return linear(p["o"], out.reshape(1, t, hh * hd)), k[0], v[0]


def paged_attn(p: dict, x: jax.Array, cfg: ModelConfig, kp: jax.Array,
               vp: jax.Array, bt: jax.Array, pos: jax.Array):
    """One layer's decode attention straight over paged KV pools.

    ``x (B, sq, D)`` holds each slot's decode rows (``sq == 1`` plain decode,
    ``sq > 1`` speculative draft stacks), ``kp/vp (P, page, KV, hd)`` one
    layer's page pools behind the block tables ``bt (B, maxp)``, and ``pos
    (B,)`` each slot's committed prefix length. Routes the in-kernel
    block-table path (``kernels/dispatch.paged_decode``): pages stream
    through the kernel, so no dense ``gather_pages`` view of the cache is
    ever materialized. Returns (out (B, sq, D), k_t (B, sq, KV, hd), v_t)
    with k_t/v_t post-RoPE, ready for the caller's post-scan page commit
    (``commit=False`` — the scan-stacked families batch one scatter per
    layer after the scan)."""
    from repro.kernels.dispatch import paged_decode

    b, sq, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = linear_group(p, ("q", "k", "v"), "qkv", x)
    q = q.reshape(b, sq, h, hd)
    k = k.reshape(b, sq, kvh, hd)
    v = v.reshape(b, sq, kvh, hd)
    positions = slot_positions(pos, b, sq)
    tables = rope_tables(positions, hd, cfg.rope_fraction, cfg.rope_theta)
    q = apply_rope(q, tables)
    k = apply_rope(k, tables)
    out = paged_decode(q, kp, vp, k, v, bt, pos, commit=False)
    return linear(p["o"], out.reshape(b, sq, h * hd)), k, v


def select_at_length(x: jax.Array, length) -> jax.Array:
    """Last REAL position of each row: x (B, S, D), length (B,) or scalar ->
    (B, 1, D). ``length=None`` means the whole row is real (no padding)."""
    if length is None:
        return x[:, -1:]
    idx = jnp.clip(jnp.asarray(length, jnp.int32).reshape(-1) - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)


def prefill_pos(length, batch: int, s: int) -> jax.Array:
    """Per-slot position vector after a prefill of s (possibly padded) tokens
    of which ``length`` are real."""
    if length is None:
        return jnp.full((batch,), s, jnp.int32)
    return jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1), (batch,))


def gate_state_update(new_state: dict, old_state: dict, valid: jax.Array,
                      b_axis: dict) -> dict:
    """Keep each slot's state update only where ``valid`` (B,) is True —
    bucketed prefill gates recurrent-state updates off for pad steps.

    ``b_axis`` maps each state key to its slot (batch) axis as a NEGATIVE
    offset from the trailing dims, which is uniform across a family's
    stacked-layout variants (e.g. mamba_hybrid's n_seg/rest groupings)."""
    out = {}
    for key, new in new_state.items():
        ax = b_axis[key] % new.ndim
        shape = [1] * new.ndim
        shape[ax] = valid.shape[0]
        out[key] = jnp.where(valid.reshape(shape), new, old_state[key])
    return out


def prefix_attn_mask(s: int, off: int) -> jax.Array:
    """(1, s, off+s) mask for suffix prefill over a cached prefix: every
    suffix query sees the whole prefix plus the causal part of the suffix."""
    return jnp.concatenate(
        [jnp.ones((1, s, off), bool), jnp.tril(jnp.ones((s, s), bool))[None]], axis=-1
    )


def gqa_prefill_attn(p: dict, h: jax.Array, cfg: ModelConfig, positions: jax.Array,
                     prefix_kv=None, mask=None):
    """One layer's prefill attention (fused q/k/v projection + RoPE), causal
    or — given ``prefix_kv`` = (pk (B, m, KV, hd), pv) from cached pages plus
    the matching ``prefix_attn_mask`` — over [prefix; causal suffix].
    Returns (attn_out, k, v); shared by the dense-style families' prefill
    bodies so the prefix-cache suffix path exists exactly once."""
    b, s, _ = h.shape
    hh, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = linear_group(p, ("q", "k", "v"), "qkv", h)
    q = q.reshape(b, s, hh, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    tables = rope_tables(positions, hd, cfg.rope_fraction, cfg.rope_theta)
    q = apply_rope(q, tables)
    k = apply_rope(k, tables)
    if prefix_kv is None:
        att = sdpa_causal(q, k, v)
    else:
        pk, pv = prefix_kv
        kf = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        vf = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        att = _sdpa(q, kf, vf, mask)
    return linear(p["o"], att.reshape(b, s, hh * hd)), k, v


def slot_positions(pos: jax.Array, b: int, sq: int = 1) -> jax.Array:
    """Per-slot decode positions (B, sq) from a per-slot ``pos`` vector (B,).

    A scalar ``pos`` (legacy single-sequence callers) broadcasts to all slots.
    """
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((b,), pos, jnp.int32)
    return pos[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]


def update_cache_slot(cache: jax.Array, t: jax.Array, pos: jax.Array) -> jax.Array:
    """Scatter a one-token slice at each slot's own offset.

    cache (B, S, ...), t (B, 1, ...), pos (B,). Out-of-range positions
    (a slot past its max_len) are dropped, not clamped, so an overflowing
    slot can never corrupt row S-1."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b), pos].set(t[:, 0].astype(cache.dtype), mode="drop")


def update_cache_slot_stacked(cache: jax.Array, t: jax.Array, pos: jax.Array) -> jax.Array:
    """Layer-stacked variant: cache (L, B, S, ...), t (L, B, 1, ...), pos (B,)."""
    b = cache.shape[1]
    return cache.at[:, jnp.arange(b), pos].set(t[:, :, 0].astype(cache.dtype), mode="drop")


def attention_decode_ro(p: dict, x: jax.Array, cfg: ModelConfig, k_cache, v_cache,
                        pos: jax.Array):
    """Read-only-cache decode attention (§Perf optimization).

    ``pos`` is a per-slot position vector (B,) — every batch slot carries its
    own timeline, so sequences of different lengths (continuous batching)
    decode in lock-step without sharing a global step counter. Each slot
    attends over its own cache prefix [0, pos_b) plus the current token.

    The naive formulation updates the cache INSIDE the layer scan, which
    makes the scan write every layer's full (B, S, KV, hd) cache slice back
    per token (2 x cache bytes of HBM write traffic per step). Here the scan
    reads the cache read-only and attends over [cache(<pos), current token];
    the caller batches ONE one-token scatter per layer after the scan.
    Returns (out, k_t, v_t)."""
    b, sq, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, kt, vt = linear_group(p, ("q", "k", "v"), "qkv", x)
    q = q.reshape(b, sq, h, hd)
    kt = kt.reshape(b, sq, kvh, hd)
    vt = vt.reshape(b, sq, kvh, hd)
    positions = slot_positions(pos, b, sq)
    pos_v = positions[:, 0]  # (B,)
    tables = rope_tables(positions, hd, cfg.rope_fraction, cfg.rope_theta)
    q = apply_rope(q, tables)
    kt = apply_rope(kt, tables)

    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s_max = k_cache.shape[1]
    logits_c = jnp.einsum("bskgh,btkh->bkgst", qg, k_cache).astype(jnp.float32)
    logits_c = logits_c / (hd**0.5)
    # strict per-slot prefix mask: self handled below
    mask = jnp.arange(s_max)[None, None, None, None, :] < pos_v[:, None, None, None, None]
    logits_c = jnp.where(mask, logits_c, -1e30)
    logit_s = jnp.einsum("bskgh,bskh->bkgs", qg, kt).astype(jnp.float32)[..., None] / (hd**0.5)
    m = jnp.maximum(jnp.max(logits_c, axis=-1, keepdims=True), logit_s)
    pc = jnp.exp(logits_c - m)
    ps = jnp.exp(logit_s - m)
    den = jnp.sum(pc, axis=-1, keepdims=True) + ps
    out = jnp.einsum("bkgst,btkh->bskgh", (pc / den).astype(v_cache.dtype), v_cache)
    self_w = (ps / den)[..., 0][..., None].transpose(0, 3, 1, 2, 4).astype(vt.dtype)
    out = out + self_w * vt[:, :, :, None, :]
    out = out.reshape(b, sq, h, hd)
    return linear(p["o"], out.reshape(b, sq, h * hd)), kt, vt


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, f),
        "up": dense_init(k2, d, f),
        "down": dense_init(k3, f, d),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    gate, up = linear_group(p, ("gate", "up"), "gate_up", x)
    return linear(p["down"], swiglu(gate, up))


def attn_init(key, cfg: ModelConfig, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "q": dense_init(k1, d, h * hd, bias=cfg.qkv_bias),
        "k": dense_init(k2, d, kvh * hd, bias=cfg.qkv_bias),
        "v": dense_init(k3, d, kvh * hd, bias=cfg.qkv_bias),
        "o": dense_init(k4, h * hd, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Mean CE over tokens; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def _shard_logits(x: jax.Array) -> jax.Array:
    """Constrain chunk logits to (dp, None, model) — without this the SPMD
    partitioner replicates the f32 logits over the model axis (measured:
    2 full-vocab copies = 40 GB/device at 4k seq; EXPERIMENTS §Perf)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models.context import get_mesh_context

    ctx = get_mesh_context()
    if ctx.mesh is None or ctx.tp_axis is None:
        return x
    spec = P(tuple(ctx.dp_axes) or None, None, ctx.tp_axis)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


_CE_CHUNK = 256


def cross_entropy_chunked(hidden: jax.Array, labels: jax.Array, unembed_fn,
                          chunk: int = _CE_CHUNK) -> jax.Array:
    """Memory-bounded CE: unembed + log-softmax one sequence-chunk at a time
    (rematerialized in backward), so full-sequence f32 logits never exist.

    hidden: (B, S, D) post-final-norm; unembed_fn: (B, c, D) -> (B, c, V).
    """
    b, s, d = hidden.shape
    if s % chunk != 0 or s <= chunk:
        logits = _shard_logits(unembed_fn(hidden).astype(jnp.float32))
        return cross_entropy(logits, labels, 0)
    n = s // chunk
    hs = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        xc, lc = xs
        logits = _shard_logits(unembed_fn(xc).astype(jnp.float32))
        mask = lc >= 0
        safe = jnp.where(mask, lc, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return (carry[0] + jnp.sum(nll * mask), carry[1] + jnp.sum(mask)), None

    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ls)
    )
    return tot / jnp.maximum(cnt, 1)
