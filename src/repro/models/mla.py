"""Multi-head Latent Attention (DeepSeek-V3).

Train/prefill use the expanded form; decode uses the **absorbed** form that
attends directly over the compressed latent cache (kv_lora + rope dims per
position — MLA's memory advantage), absorbing the k-up-projection into the
query and the v-up-projection into the output. This is the standard MLA
decode optimization and is what makes the 32k-decode shape's KV bytes small.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import common as C


def mla_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "wq_a": C.dense_init(k1, d, qr),
        "q_norm": jnp.ones((qr,), C.DTYPE),
        "wq_b": C.dense_init(k2, qr, h * (nope + rope)),
        "wkv_a": C.dense_init(k3, d, kvr + rope),
        "kv_norm": jnp.ones((kvr,), C.DTYPE),
        "wkv_b": C.dense_init(k4, kvr, h * (nope + vd)),
        "o": C.dense_init(k5, h * vd, d),
    }


def _rope_1head(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """RoPE on a (B, S, r) tensor (shared key rope path, one 'head')."""
    tables = C.rope_tables(positions, x.shape[-1], 1.0, theta)
    return C.apply_rope(x[:, :, None, :], tables)[:, :, 0, :]


def _down_projs(p: dict, x: jax.Array):
    """The two latent down-projections of x — wq_a and wkv_a share the layer
    input, so when quantized they run as ONE fused launch (pre-merged
    ``wqkv_a`` pack or trace-time fusion). Returns (cq_raw, ckv_full)."""
    return C.linear_group(p, ("wq_a", "wkv_a"), "wqkv_a", x)


def mla_train(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Expanded-form causal MLA (training / prefill math)."""
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.arange(s)[None, :] * jnp.ones((b, 1), jnp.int32)

    cq_raw, ckv_full = _down_projs(p, x)
    cq = C.rmsnorm(cq_raw, p["q_norm"], cfg.norm_eps)
    q = C.linear(p["wq_b"], cq).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    tables = C.rope_tables(positions, rope, 1.0, cfg.rope_theta)
    q_rope = C.apply_rope(q_rope, tables)

    ckv = C.rmsnorm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = _rope_1head(ckv_full[..., cfg.kv_lora_rank :], positions, cfg.rope_theta)
    kv = C.linear(p["wkv_b"], ckv).reshape(b, s, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rope))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = C.sdpa_causal(q_full, k, v)  # kv heads == heads here
    return C.linear(p["o"], out.reshape(b, s, h * vd))


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype=C.DTYPE):
    return {
        "ckv": jnp.zeros((n_layers, batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((n_layers, batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _expand_latent(p: dict, ckv: jax.Array, cfg: ModelConfig, dtype):
    """Latent (B, m, kvr) -> expanded (k_nope (B,m,H,nope), v (B,m,H,vd))."""
    b, m, _ = ckv.shape
    h = cfg.n_heads
    nope, vd = cfg.qk_nope_dim, cfg.v_head_dim
    kv = C.linear(p["wkv_b"], ckv.astype(dtype)).reshape(b, m, h, nope + vd)
    return kv[..., :nope], kv[..., nope:]


def mla_prefill_layer(p: dict, x: jax.Array, cfg: ModelConfig, prefix=None):
    """Expanded attention + return the latent cache lines for this layer.

    ``prefix`` = (ckv_pre (B, m, kvr), krope_pre (B, m, rope)): a cached
    (post-RoPE-krope) prompt prefix; x then holds only the suffix, whose
    queries attend [expanded prefix; causal suffix] with positions offset by
    m — the engine's prefix-cache suffix prefill."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    off = 0 if prefix is None else prefix[0].shape[1]
    positions = (off + jnp.arange(s))[None, :] * jnp.ones((b, 1), jnp.int32)

    cq_raw, ckv_full = _down_projs(p, x)
    cq = C.rmsnorm(cq_raw, p["q_norm"], cfg.norm_eps)
    q = C.linear(p["wq_b"], cq).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    tables = C.rope_tables(positions, rope, 1.0, cfg.rope_theta)
    q_rope = C.apply_rope(q_rope, tables)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    ckv = C.rmsnorm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = _rope_1head(ckv_full[..., cfg.kv_lora_rank :], positions, cfg.rope_theta)
    kv = C.linear(p["wkv_b"], ckv).reshape(b, s, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rope))], axis=-1
    )
    if prefix is None:
        out = C.sdpa_causal(q_full, k, v)
    else:
        ckv_pre, krope_pre = prefix
        pk_nope, pv = _expand_latent(p, ckv_pre, cfg, x.dtype)
        pk = jnp.concatenate(
            [pk_nope,
             jnp.broadcast_to(krope_pre.astype(x.dtype)[:, :, None, :], (b, off, h, rope))],
            axis=-1,
        )
        kf = jnp.concatenate([pk, k], axis=1)
        vf = jnp.concatenate([pv, v], axis=1)
        out = C._sdpa(q_full, kf, vf, C.prefix_attn_mask(s, off))
    return C.linear(p["o"], out.reshape(b, s, h * vd)), ckv, k_rope


def mla_decode(p: dict, x: jax.Array, cfg: ModelConfig, ckv_cache, krope_cache, pos):
    """Absorbed-form single-token decode over the latent cache.

    x: (B, 1, D); ckv_cache: (B, S_max, kvr); krope_cache: (B, S_max, rope);
    pos: per-slot positions (B,) — each slot attends to its own prefix.
    """
    out, ckv_cache, krope_cache, _, _ = _mla_decode_core(
        p, x, cfg, ckv_cache, krope_cache, pos
    )
    return out, ckv_cache, krope_cache


def mla_decode_paged(p: dict, x: jax.Array, cfg: ModelConfig, ckv_pool, krope_pool,
                     bt, pos):
    """Paged-cache decode: gather this layer's latent pages through the block
    table into the dense per-slot view, run the identical absorbed-form math
    on the (temporary) view, and hand the new token's latent lines back for
    the caller's one post-scan pool scatter."""
    ckv_view = C.gather_pages(ckv_pool, bt)
    krope_view = C.gather_pages(krope_pool, bt)
    out, _, _, ckv_t, krope_t = _mla_decode_core(p, x, cfg, ckv_view, krope_view, pos)
    return out, ckv_t, krope_t


def _mla_decode_core(p: dict, x: jax.Array, cfg: ModelConfig, ckv_cache, krope_cache, pos):
    b, sq, d = x.shape
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    positions = C.slot_positions(pos, b, sq)
    pos_v = positions[:, 0]

    cq_raw, ckv_full = _down_projs(p, x)
    cq = C.rmsnorm(cq_raw, p["q_norm"], cfg.norm_eps)
    q = C.linear(p["wq_b"], cq).reshape(b, sq, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    tables = C.rope_tables(positions, rope, 1.0, cfg.rope_theta)
    q_rope = C.apply_rope(q_rope, tables)

    # update latent cache with this step's compressed kv (per-slot offsets)
    ckv_t = C.rmsnorm(ckv_full[..., :kvr], p["kv_norm"], cfg.norm_eps)
    krope_t = _rope_1head(ckv_full[..., kvr:], positions, cfg.rope_theta)
    ckv_cache = C.update_cache_slot(ckv_cache, ckv_t, pos_v)
    krope_cache = C.update_cache_slot(krope_cache, krope_t, pos_v)

    # absorb W_uk into q: q_eff (B, 1, H, kvr)
    wkv_b = p["wkv_b"]["w"].reshape(kvr, h, nope + vd)
    w_k = wkv_b[..., :nope]  # (kvr, H, nope)
    w_v = wkv_b[..., nope:]  # (kvr, H, vd)
    q_eff = jnp.einsum("bqhn,khn->bqhk", q_nope, w_k.astype(x.dtype))

    s_max = ckv_cache.shape[1]
    logits = jnp.einsum("bqhk,btk->bhqt", q_eff, ckv_cache).astype(jnp.float32)
    logits = logits + jnp.einsum("bqhr,btr->bhqt", q_rope, krope_cache).astype(jnp.float32)
    logits = logits / ((nope + rope) ** 0.5)
    mask = jnp.arange(s_max)[None, None, None, :] <= pos_v[:, None, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqt,btk->bqhk", probs, ckv_cache)
    out = jnp.einsum("bqhk,khv->bqhv", ctx, w_v.astype(x.dtype))
    return (
        C.linear(p["o"], out.reshape(b, sq, h * vd)),
        ckv_cache, krope_cache, ckv_t, krope_t,
    )
