"""Zamba2-style hybrid: Mamba2 (SSD) trunk + a weight-shared attention/MLP
block invoked every ``shared_attn_every`` layers on concat(hidden, embed0),
projected back through a per-invocation adapter (the Zamba re-injection).

Mamba2 uses the chunked SSD algorithm for train/prefill (scan over chunks
carrying the (H, P, N) state) and the O(1) recurrence for decode — which is
what makes the long_500k decode shape runnable for this family. The shared
attention block keeps an ordinary KV cache per invocation; at 500k decode the
cache's sequence dim is sharded over the mesh (plain einsum ops — XLA SPMD
partitions the masked softmax reductions, no shard_map needed).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import common as C

# §Perf cell A' napkin math: SSD state traffic/token ~ H*P*N/chunk, intra-
# chunk bytes/token ~ chunk — crossover for (P=N=64) is ~128
CHUNK = 128


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _mamba_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner_ssm
    n = cfg.ssm_state
    h = cfg.n_ssm_heads
    k = jax.random.split(key, 4)
    return {
        "ln": jnp.ones((d,), C.DTYPE),
        "in_proj": C.dense_init(k[0], d, 2 * di + 2 * n + h),
        "conv": (jax.random.normal(k[1], (cfg.ssm_conv, di + 2 * n)) * 0.1).astype(C.DTYPE),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus(-2) ~ 0.12
        "gn": jnp.ones((di,), C.DTYPE),
        "out_proj": C.dense_init(k[2], di, d),
    }


def _shared_block_init(key, cfg: ModelConfig) -> dict:
    d2 = 2 * cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim
    k = jax.random.split(key, 7)
    return {
        "ln1": jnp.ones((d2,), C.DTYPE),
        "q": C.dense_init(k[0], d2, h * hd),
        "k": C.dense_init(k[1], d2, h * hd),
        "v": C.dense_init(k[2], d2, h * hd),
        "o": C.dense_init(k[3], h * hd, h * hd),
        "ln2": jnp.ones((d2,), C.DTYPE),
        "mlp": {
            "gate": C.dense_init(k[4], d2, cfg.d_ff),
            "up": C.dense_init(k[5], d2, cfg.d_ff),
            "down": C.dense_init(k[6], cfg.d_ff, h * hd),
        },
    }


def _segments(cfg: ModelConfig):
    every = cfg.shared_attn_every
    if every <= 0:
        return 0, cfg.n_layers, cfg.n_layers
    n_seg = cfg.n_layers // every
    rest = cfg.n_layers - n_seg * every
    return n_seg, every, rest


def init_params(cfg: ModelConfig, key) -> dict:
    ke, km, ks, ka, kr, kh = jax.random.split(key, 6)
    n_seg, every, rest = _segments(cfg)
    p = {
        "embed": C.embed_init(ke, cfg.padded_vocab, cfg.d_model),
        "ln_f": jnp.ones((cfg.d_model,), C.DTYPE),
        "head": C.dense_init(kh, cfg.d_model, cfg.padded_vocab),
    }
    if n_seg == 0:
        p["m_layers"] = jax.vmap(lambda k: _mamba_init(k, cfg))(jax.random.split(km, cfg.n_layers))
    else:
        mkeys = jax.random.split(km, n_seg * every).reshape(n_seg, every, 2)
        p["m_layers"] = jax.vmap(jax.vmap(lambda k: _mamba_init(k, cfg)))(mkeys)
        p["shared"] = _shared_block_init(ks, cfg)
        adapters = jax.vmap(
            lambda k: C.dense_init(k, cfg.n_heads * cfg.head_dim, cfg.d_model)
        )(jax.random.split(ka, n_seg))
        p["adapters"] = adapters
        if rest:
            p["rest_layers"] = jax.vmap(lambda k: _mamba_init(k, cfg))(jax.random.split(kr, rest))
    return p


# ---------------------------------------------------------------------------
# Mamba2 SSD core
# ---------------------------------------------------------------------------


def _ssd_chunkwise(x, dt, A, Bm, Cm, state):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N); state: (B,H,P,N)."""
    b, s, h, pdim = x.shape
    n = Bm.shape[-1]
    nc = s // CHUNK
    l = CHUNK
    xf = x.astype(jnp.float32).reshape(b, nc, l, h, pdim)
    dtf = dt.reshape(b, nc, l, h)
    Bf = Bm.astype(jnp.float32).reshape(b, nc, l, n)
    Cf = Cm.astype(jnp.float32).reshape(b, nc, l, n)
    la = dtf * A[None, None, None, :]  # (B,nc,l,H) log decay (<= 0)

    def chunk_step(st, xs):
        xx, dd, bb, cc, ll = xs  # (B,l,H,P), (B,l,H), (B,l,N), (B,l,N), (B,l,H)
        F = jnp.cumsum(ll, axis=1)  # (B,l,H)
        # intra-chunk: y_t = sum_{s<=t} exp(F_t - F_s) dt_s (C_t . B_s) x_s
        w = F[:, :, None, :] - F[:, None, :, :]  # (B,t,s,H)
        tri = jnp.tril(jnp.ones((l, l), bool))[None, :, :, None]
        # mask in log-space BEFORE exp: masked entries have F_t - F_s > 0 and
        # exp overflows, poisoning the where() gradient with 0*inf
        w = jnp.exp(jnp.where(tri, w, -1e30))
        cb = jnp.einsum("btn,bsn->bts", cc, bb)[:, :, :, None]  # (B,t,s,1)
        scores = cb * w * dd[:, None, :, :]  # (B,t,s,H)
        y = jnp.einsum("btsh,bshp->bthp", scores, xx)
        # inter-chunk
        y = y + jnp.exp(F)[..., None] * jnp.einsum("btn,bhpn->bthp", cc, st)
        # state update
        g = F[:, -1]  # (B,H)
        wk = jnp.exp(g[:, None, :] - F) * dd  # (B,l,H)
        st_new = jnp.exp(g)[:, :, None, None] * st + jnp.einsum(
            "blhp,bln,blh->bhpn", xx, bb, wk
        )
        return st_new, y

    xs = (
        xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
        Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3), la.transpose(1, 0, 2, 3),
    )
    state, ys = jax.lax.scan(chunk_step, state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, pdim)
    return y, state


def _ssd_step(x, dt, A, Bm, Cm, state):
    """Single-step recurrence. x: (B,1,H,P); state: (B,H,P,N)."""
    xf = x[:, 0].astype(jnp.float32)
    dd = dt[:, 0]
    bb = Bm[:, 0].astype(jnp.float32)
    cc = Cm[:, 0].astype(jnp.float32)
    decay = jnp.exp(dd * A[None, :])  # (B,H)
    state = decay[:, :, None, None] * state + jnp.einsum(
        "bhp,bn,bh->bhpn", xf, bb, dd
    )
    y = jnp.einsum("bn,bhpn->bhp", cc, state)
    return y[:, None], state


def _mamba_block(lp, x, cfg: ModelConfig, state=None, conv_state=None, step=False):
    b, s, d = x.shape
    di, n, h = cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads
    pdim = cfg.ssm_head_dim
    hin = C.rmsnorm(x, lp["ln"], cfg.norm_eps)
    proj = C.linear(lp["in_proj"], hin)
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * n]
    dt_raw = proj[..., 2 * di + 2 * n :].astype(jnp.float32)  # (B,S,H)
    # causal depthwise conv over [x, B, C]
    k = lp["conv"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((b, k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)
    xbc = sum(xp[:, i : i + s, :] * lp["conv"][i][None, None, :] for i in range(k))
    new_conv = xp[:, -(k - 1) :, :]
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :di].reshape(b, s, h, pdim)
    Bm = xbc[..., di : di + n]
    Cm = xbc[..., di + n :]
    dt = jax.nn.softplus(dt_raw + lp["dt_bias"][None, None, :])
    A = -jnp.exp(lp["A_log"])
    if state is None:
        state = jnp.zeros((b, h, pdim, n), jnp.float32)
    core = _ssd_step if step else _ssd_chunkwise
    y, state = core(xs, dt, A, Bm, Cm, state)
    y = y + lp["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = C.rmsnorm(y, lp["gn"], cfg.norm_eps) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + C.linear(lp["out_proj"], y), state, new_conv


# ---------------------------------------------------------------------------
# shared attention block (Zamba re-injection)
# ---------------------------------------------------------------------------


def _shared_attn(sp, adapter, x, emb0, cfg: ModelConfig, cache=None, pos=None):
    """x: (B,S,D); emb0: (B,S,D) original embeddings.
    Returns (delta, new_kv, kv_t) — kv_t is this step's raw (k, v) line, which
    the paged decode path scatters into the page pool (new_kv is then just the
    updated temporary view)."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    cat = jnp.concatenate([x, emb0.astype(x.dtype)], axis=-1)
    hin = C.rmsnorm(cat, sp["ln1"], cfg.norm_eps)
    q = C.linear(sp["q"], hin).reshape(b, s, h, hd)
    k = C.linear(sp["k"], hin).reshape(b, s, h, hd)
    v = C.linear(sp["v"], hin).reshape(b, s, h, hd)
    positions = (
        jnp.arange(s)[None, :] * jnp.ones((b, 1), jnp.int32)
        if pos is None
        else C.slot_positions(pos, b, s)
    )
    tables = C.rope_tables(positions, hd, 1.0, 10000.0)
    q = C.apply_rope(q, tables)
    k = C.apply_rope(k, tables)
    if cache is None:
        att = C.sdpa_causal(q, k, v)
        new_kv = (k, v)
    else:
        kc, vc = cache
        assert s == 1, f"cached _shared_attn is single-token decode only, got s={s}"
        pos_v = positions[:, 0]  # (B,) per-slot write offsets
        kc = C.update_cache_slot(kc, k, pos_v)
        vc = C.update_cache_slot(vc, v, pos_v)
        mask = jnp.arange(kc.shape[1])[None, None, :] <= pos_v[:, None, None]
        att = C._sdpa(q, kc, vc, mask)
        new_kv = (kc, vc)
    y = C.linear(sp["o"], att.reshape(b, s, h * hd))
    h2 = C.rmsnorm(cat, sp["ln2"], cfg.norm_eps)
    gate, up = C.linear_group(sp["mlp"], ("gate", "up"), "gate_up", h2)
    y = y + C.linear(sp["mlp"]["down"], C.swiglu(gate, up))
    return C.linear(adapter, y), new_kv, (k, v)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, tokens):
    x = C.embed_lookup(params["embed"], tokens)
    emb0 = x
    b, s, d = x.shape
    pad = (-s) % CHUNK
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        emb0 = jnp.pad(emb0, ((0, 0), (0, pad), (0, 0)))
    n_seg, every, rest = _segments(cfg)

    def m_body(x, lp):
        out, _, _ = _mamba_block(lp, x, cfg)
        return out, None

    if cfg.remat:
        m_body = jax.checkpoint(m_body)

    if n_seg == 0:
        x, _ = jax.lax.scan(m_body, x, params["m_layers"])
    else:
        def seg_body(x, seg):
            mls, adapter = seg
            x, _ = jax.lax.scan(m_body, x, mls)
            delta, _, _ = _shared_attn(params["shared"], adapter, x, emb0, cfg)
            return x + delta, None

        if cfg.remat:
            seg_body = jax.checkpoint(seg_body)
        x, _ = jax.lax.scan(seg_body, x, (params["m_layers"], params["adapters"]))
        if rest:
            x, _ = jax.lax.scan(m_body, x, params["rest_layers"])
    x = x[:, :s]
    x = C.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return C.linear(params["head"], x)


def loss_fn(params, cfg: ModelConfig, batch):
    # trunk re-used from forward, but unembed is chunked
    tokens = batch["tokens"]
    x = C.embed_lookup(params["embed"], tokens)
    emb0 = x
    b, s, d = x.shape
    pad = (-s) % CHUNK
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        emb0 = jnp.pad(emb0, ((0, 0), (0, pad), (0, 0)))
    n_seg, every, rest = _segments(cfg)

    def m_body(x, lp):
        out, _, _ = _mamba_block(lp, x, cfg)
        return out, None

    if cfg.remat:
        m_body = jax.checkpoint(m_body)

    if n_seg == 0:
        x, _ = jax.lax.scan(m_body, x, params["m_layers"])
    else:
        def seg_body(x, seg):
            mls, adapter = seg
            x, _ = jax.lax.scan(m_body, x, mls)
            delta, _, _ = _shared_attn(params["shared"], adapter, x, emb0, cfg)
            return x + delta, None

        if cfg.remat:
            seg_body = jax.checkpoint(seg_body)
        x, _ = jax.lax.scan(seg_body, x, (params["m_layers"], params["adapters"]))
        if rest:
            x, _ = jax.lax.scan(m_body, x, params["rest_layers"])
    h = C.rmsnorm(x[:, :s], params["ln_f"], cfg.norm_eps)
    return C.cross_entropy_chunked(
        h[:, :-1], batch["labels"][:, 1:], lambda xc: C.linear(params["head"], xc)
    )


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=C.DTYPE):
    n_seg, every, rest = _segments(cfg)
    di, n, h_ssm = cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads
    pdim = cfg.ssm_head_dim
    kconv = cfg.ssm_conv
    mshape = (n_seg, every) if n_seg else (cfg.n_layers,)
    st = {
        "ssm": jnp.zeros((*mshape, batch, h_ssm, pdim, n), jnp.float32),
        "conv": jnp.zeros((*mshape, batch, kconv - 1, di + 2 * n), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if n_seg:
        h, hd = cfg.n_heads, cfg.head_dim
        st["shared_k"] = jnp.zeros((n_seg, batch, max_len, h, hd), dtype)
        st["shared_v"] = jnp.zeros((n_seg, batch, max_len, h, hd), dtype)
        if rest:
            st["ssm_rest"] = jnp.zeros((rest, batch, h_ssm, pdim, n), jnp.float32)
            st["conv_rest"] = jnp.zeros((rest, batch, kconv - 1, di + 2 * n), dtype)
    return st


def decode_step(params, cfg: ModelConfig, state, tokens):
    x = C.embed_lookup(params["embed"], tokens)
    emb0 = x
    pos = C.slot_positions(state["pos"], tokens.shape[0])[:, 0]
    n_seg, every, rest = _segments(cfg)
    # shared-attention K/V may be paged (page pool + block table); the
    # recurrent ssm/conv leaves are O(1) per slot and never paged
    paged = "bt" in state

    def m_body(x, lp_st):
        lp, sst, cst = lp_st
        out, sst, cst = _mamba_block(lp, x, cfg, sst, cst, step=True)
        return out, (sst, cst)

    if n_seg == 0:
        x, (ssm, conv) = jax.lax.scan(m_body, x, (params["m_layers"], state["ssm"], state["conv"]))
        new_state = {**state, "ssm": ssm, "conv": conv, "pos": pos + 1}
    else:
        def seg_body(x, seg):
            mls, ssm, conv, adapter, kc, vc = seg
            x, (ssm, conv) = jax.lax.scan(m_body, x, (mls, ssm, conv))
            if paged:
                kc = C.gather_pages(kc, state["bt"])
                vc = C.gather_pages(vc, state["bt"])
            delta, kv, kv_t = _shared_attn(
                params["shared"], adapter, x, emb0, cfg, cache=(kc, vc), pos=pos
            )
            return x + delta, (ssm, conv, *(kv_t if paged else kv))

        x, (ssm, conv, kc, vc) = jax.lax.scan(
            seg_body, x,
            (params["m_layers"], state["ssm"], state["conv"], params["adapters"],
             state["shared_k"], state["shared_v"]),
        )
        if paged:
            kc = C.scatter_token_pages(state["shared_k"], kc, state["bt"], pos)
            vc = C.scatter_token_pages(state["shared_v"], vc, state["bt"], pos)
        new_state = {**state, "ssm": ssm, "conv": conv, "shared_k": kc, "shared_v": vc,
                     "pos": pos + 1}
        if rest:
            x, (ssm_r, conv_r) = jax.lax.scan(
                m_body, x, (params["rest_layers"], state["ssm_rest"], state["conv_rest"])
            )
            new_state.update(ssm_rest=ssm_r, conv_rest=conv_r)
    x = C.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return C.linear(params["head"], x), new_state


# slot (batch) axis of every decode-state leaf, as a negative offset from the
# trailing dims (uniform across the n_seg/rest layout variants) — used to
# broadcast the per-slot pad-validity mask in bucketed prefill
_B_AXIS = {"ssm": -4, "ssm_rest": -4, "conv": -3, "conv_rest": -3,
           "shared_k": -4, "shared_v": -4, "pos": -1}


def prefill(params, cfg: ModelConfig, tokens, state, length=None):
    """``length`` (B,) marks the real prompt length under bucket padding:
    logits come from position length-1 (the padded forward is causal, so real
    positions are exact) and recurrent-state updates are gated off for pad
    steps so the SSM/conv/KV state equals the unpadded prefill's."""
    h = forward(params, cfg, tokens)
    logits = C.select_at_length(h, length)

    def step(st, t_i):
        t, i = t_i
        lg, new = decode_step(params, cfg, st, t[:, None])
        if length is not None:
            valid = i < jnp.asarray(length, jnp.int32).reshape(-1)
            new = C.gate_state_update(new, st, valid, _B_AXIS)
        return new, ()

    s = tokens.shape[1]
    state, _ = jax.lax.scan(step, state, (tokens.T, jnp.arange(s)))
    return logits, state


def count_params(cfg: ModelConfig):
    d, di, n, h_ssm = cfg.d_model, cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads
    m_layer = (
        d * (2 * di + 2 * n + h_ssm) + cfg.ssm_conv * (di + 2 * n) + 3 * h_ssm + di * d + di + d
    )
    n_seg, every, rest = _segments(cfg)
    d2, hhd = 2 * d, cfg.n_heads * cfg.head_dim
    shared = 3 * d2 * hhd + hhd * hhd + 2 * d2 * cfg.d_ff + cfg.d_ff * hhd + 2 * d2
    adapters = n_seg * hhd * d
    total = (
        cfg.n_layers * m_layer + (shared if n_seg else 0) + adapters + cfg.padded_vocab * d * 2 + d
    )
    return total, total
