"""OLMoE: dense GQA attention + MoE FFN in every layer (scan-stacked)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import common as C
from repro.models import dense as D
from repro.models.moe_layer import moe_ffn, moe_init


def layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn": C.attn_init(k1, cfg),
        "moe": moe_init(k2, cfg),
        "ln1": jnp.ones((cfg.d_model,), C.DTYPE),
        "ln2": jnp.ones((cfg.d_model,), C.DTYPE),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    return {
        "embed": C.embed_init(ke, cfg.padded_vocab, cfg.d_model),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), C.DTYPE),
        "head": C.dense_init(kh, cfg.d_model, cfg.padded_vocab),
    }


def _trunk(params: dict, cfg: ModelConfig, tokens: jax.Array):
    x = C.embed_lookup(params["embed"], tokens)

    def body(carry, lp):
        x, aux = carry
        h = x + C.attention_train(lp["attn"], C.rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg)
        m, a = moe_ffn(lp["moe"], C.rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
        return (h + m, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    return C.rmsnorm(x, params["ln_f"], cfg.norm_eps), aux / cfg.n_layers


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array):
    h, aux = _trunk(params, cfg, tokens)
    return D.head_fn(params, cfg)(h), aux


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    h, aux = _trunk(params, cfg, batch["tokens"])
    ce = C.cross_entropy_chunked(h[:, :-1], batch["labels"][:, 1:], D.head_fn(params, cfg))
    return ce + cfg.router_aux_weight * aux


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=C.DTYPE) -> dict:
    return C.init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype)


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, state: dict,
            length=None, prefix=None):
    """Prompt prefill; ``length``/``prefix`` as in models/dense.prefill
    (bucket padding and cached-prefix suffix prefill)."""
    x = C.embed_lookup(params["embed"], tokens)
    b, s, _ = x.shape
    off = 0 if prefix is None else prefix["k"].shape[2]
    positions = (off + jnp.arange(s))[None, :] * jnp.ones((b, 1), jnp.int32)
    mask = None if prefix is None else C.prefix_attn_mask(s, off)

    def body(x, lp_ctx):
        lp = lp_ctx if prefix is None else lp_ctx[0]
        h = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        att, k, v = C.gqa_prefill_attn(
            lp["attn"], h, cfg, positions,
            prefix_kv=None if prefix is None else lp_ctx[1:], mask=mask,
        )
        x = x + att
        m, _ = moe_ffn(lp["moe"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x + m, (k, v)

    xs = params["layers"] if prefix is None else (params["layers"], prefix["k"], prefix["v"])
    x, (ks, vs) = jax.lax.scan(body, x, xs)
    state = {
        "k": jax.lax.dynamic_update_slice(state["k"], ks.astype(state["k"].dtype), (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(state["v"], vs.astype(state["v"].dtype), (0, 0, 0, 0, 0)),
        "pos": off + C.prefill_pos(length, b, s),
    }
    return D._unembed(params, cfg, C.select_at_length(x, length)), state


def decode_step(params: dict, cfg: ModelConfig, state: dict, tokens: jax.Array):
    """tokens (B, sq) -> (logits (B, sq, V), new state); sq > 1 stacks
    speculative draft rows (paged state only). Paged decode routes the
    in-kernel block-table attention (kind ``paged_decode``) — see
    models/dense.decode_step."""
    x = C.embed_lookup(params["embed"], tokens)
    b, sq = tokens.shape
    pos = C.slot_positions(state["pos"], b)[:, 0]
    paged = "bt" in state

    def body(x, lp_cache):
        lp, kc, vc = lp_cache
        h = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if paged:
            att, kt, vt = C.paged_attn(lp["attn"], h, cfg, kc, vc, state["bt"], pos)
        else:
            att, kt, vt = C.attention_decode_ro(lp["attn"], h, cfg, kc, vc, pos)
        x = x + att
        m, _ = moe_ffn(lp["moe"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x + m, (kt, vt)

    x, (kts, vts) = jax.lax.scan(body, x, (params["layers"], state["k"], state["v"]))
    if paged:
        slot = jnp.repeat(jnp.arange(b, dtype=jnp.int32), sq)
        rows = C.slot_positions(pos, b, sq).reshape(-1)
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        new_state = {
            **state,
            "k": C.scatter_rows_pages(
                state["k"], kts.reshape(cfg.n_layers, b * sq, kvh, hd),
                state["bt"], slot, rows),
            "v": C.scatter_rows_pages(
                state["v"], vts.reshape(cfg.n_layers, b * sq, kvh, hd),
                state["bt"], slot, rows),
            "pos": pos + sq,
        }
    else:
        new_state = {
            "k": C.update_cache_slot_stacked(state["k"], kts, pos),
            "v": C.update_cache_slot_stacked(state["v"], vts, pos),
            "pos": pos + sq,
        }
    return D._unembed(params, cfg, x), new_state


def ragged_step(params: dict, cfg: ModelConfig, state: dict, tokens: jax.Array,
                slot: jax.Array, pos: jax.Array, ctx: jax.Array,
                logit_idx: jax.Array):
    """Unified ragged engine step for the MoE family; semantics as in
    models/dense.ragged_step. Pad rows route through the experts and consume
    expert capacity exactly like bucketed-prefill pad tokens (the documented
    PR-4 capacity caveat) — keep capacity_factor generous relative to the
    token budget when exact oracle equality matters."""
    x = C.embed_lookup(params["embed"], tokens[None, :])

    def body(x, lp_cache):
        lp, kc, vc = lp_cache
        h = C.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        att, kt, vt = C.ragged_attn(
            lp["attn"], h, cfg, kc, vc, state["bt"], slot, pos, ctx
        )
        x = x + att
        m, _ = moe_ffn(lp["moe"], C.rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x + m, (kt, vt)

    x, (kts, vts) = jax.lax.scan(body, x, (params["layers"], state["k"], state["v"]))
    b = ctx.shape[0]
    counts = jnp.sum(
        slot[None, :] == jnp.arange(b, dtype=jnp.int32)[:, None], axis=1
    )
    new_state = {
        **state,
        "k": C.scatter_rows_pages(state["k"], kts, state["bt"], slot, pos),
        "v": C.scatter_rows_pages(state["v"], vts, state["bt"], slot, pos),
        "pos": ctx.astype(jnp.int32) + counts.astype(jnp.int32),
    }
    return D._unembed(params, cfg, x[0][logit_idx][None])[0], new_state


def count_params(cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.head_dim
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    expert = 3 * d * cfg.d_ff_expert
    per_layer_total = attn + cfg.n_experts * expert + d * cfg.n_experts + 2 * d
    per_layer_active = attn + cfg.top_k * expert + d * cfg.n_experts + 2 * d
    emb = cfg.padded_vocab * d * 2
    return cfg.n_layers * per_layer_total + emb + d, cfg.n_layers * per_layer_active + emb + d
