from repro.models import common, context, registry  # noqa: F401
