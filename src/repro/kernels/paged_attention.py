"""Block-table paged decode attention — page-table indirection inside the
kernel, with the new tokens' KV scatter fused into the epilogue.

The PR-4 paged runtime used to materialize a dense ``(B, max_len, KV, hd)``
view of each slot's pages (``models/common.gather_pages``) before the decode
attention math, burning HBM bandwidth on every mapped page whether or not the
slot's prefix reaches it — and then issued a separate scatter to commit the
new token's K/V into the tail page. This kernel removes both:

* the block table is **scalar-prefetched** (``PrefetchScalarGridSpec``), so
  each grid step's BlockSpec index map fetches exactly one K/V page pair of
  the current slot straight from the pool — unmapped (-1) entries clamp to
  page 0 and are masked in-kernel;
* attention is an **online-softmax** (flash recurrence) sweep over the pages
  with f32 scratch, exactly like ``ragged_attention.py``;
* each slot contributes ``sq <= DECODE_M_MAX`` **query rows** (speculative
  verification stacks K draft tokens per slot), attending the committed
  prefix ``[0, pos_b)`` plus the earlier draft rows of the same slot
  (in-batch causal, including self);
* with ``commit=True`` the epilogue **scatters the new K/V rows into the
  slot's tail page(s) in the same launch**: the pool arrays are aliased
  input->output (``input_output_aliases``), the tail pages are streamed in
  during the two epilogue grid steps, copied through VMEM with the new rows
  folded in, and flushed back — no separate scatter launch, and only the
  tail pages are rewritten.

Grid: ``(B, max_pages + 2)`` (``+ 1`` without commit). Steps ``j <
max_pages`` stream cache pages; step ``j == max_pages`` folds the in-batch
rows and rewrites tail page 0; step ``j == max_pages + 1`` rewrites tail
page 1 (the draft span may straddle a page boundary). The output panel is
written once, at the last grid step.

Numerics: the jnp reference (:func:`paged_decode_ref`) reproduces the
sequential bucketed decode (``models/common.attention_decode_ro``)
rounding-for-rounding — it overwrites the dense cache view's rows at
``pos_b + i`` with the draft K/V (bf16, exactly the values a sequential
engine would have committed), computes one bf16-rounded cache dot per row
with a strict per-row prefix mask, and adds the separately-rounded self
term. Verification logits for row ``i`` are therefore bit-identical to what
the non-speculative engine would produce at position ``pos_b + i``, which
is what makes greedy speculative acceptance exact. The Pallas kernel
accumulates fused-f32 (flash recurrence); agreement with the ref is tested
to bf16 tolerance.

Commit-mode aliasing caveat: slots whose tail page is unmapped (idle slots
decoding garbage in lock-step: ``bt`` all -1) clamp their tail stream to
page 0 and flush back an unmodified copy of it. That copy is fetched and
flushed within the same slot's grid steps, so it is benign unless page 0 is
simultaneously the *valid* tail of a later slot in the same launch — callers
using ``commit=True`` should pass batches whose live slots all have mapped
tails (the serving engine's scan path commits post-scan instead and is not
affected).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.contracts import validate_paged_decode

# jax renamed TPUCompilerParams -> CompilerParams; support both vintages
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["paged_decode_kernel", "paged_decode_ref", "scatter_rows_pool"]

_NEG_INF = -1e30


def scatter_rows_pool(pool, t, bt, slot, pos):
    """Scatter flat rows into a single-layer page pool (the ref's commit).

    pool (P, page, KV, hd), t (R, KV, hd), bt (B, maxp), slot/pos (R,).
    Row ``i`` lands in page ``bt[slot_i, pos_i // page]`` at offset
    ``pos_i % page``; rows past the block table or into unmapped pages are
    dropped through the ``n_pages`` OOB sentinel (NOT -1, which would wrap
    into the last page — same rule as ``models/common.scatter_rows_pages``).
    """
    page = pool.shape[1]
    n_pages = pool.shape[0]
    b, maxp = bt.shape
    pi = pos // page
    page_id = bt[jnp.clip(slot, 0, b - 1), jnp.minimum(pi, maxp - 1)]
    ok = (slot < b) & (pi < maxp) & (page_id >= 0)
    page_id = jnp.where(ok, page_id, n_pages)
    return pool.at[page_id, pos % page].set(t.astype(pool.dtype), mode="drop")


def paged_decode_ref(q, kp, vp, kt, vt, bt, pos, *, commit: bool = True):
    """jnp oracle for paged multi-query decode attention.

    q (B, sq, H, hd) / kt, vt (B, sq, KV, hd): post-RoPE draft rows — row
    ``i`` of slot ``b`` sits at absolute position ``pos[b] + i``.
    kp, vp (P, page, KV, hd): one layer's paged K/V pools.
    bt (B, maxp) int32 block tables (-1 unmapped), pos (B,) int32 committed
    prefix lengths. Returns ``(out, kp_new, vp_new)`` with the draft rows
    committed to their tail pages, or just ``out`` when ``commit=False``.

    Numerics mirror ``models/common.attention_decode_ro`` per row: the dense
    cache view (with draft rows scattered in at their future positions) goes
    through ONE bf16-rounded value dot under a strict per-row prefix mask,
    the self term is rounded separately, and the two add in bf16 — so
    ``sq == 1`` is bit-identical to the pre-existing gather_pages decode
    path, and row ``i`` of a draft stack is bit-identical to what a
    sequential engine would compute at position ``pos[b] + i``.
    """
    b, sq, h, hd = q.shape
    kv = kt.shape[2]
    g = h // kv
    maxp = bt.shape[1]
    page = kp.shape[1]
    s_max = maxp * page

    # dense per-slot cache view (unmapped -> page 0, masked below), then
    # overwrite the draft span: the view now holds exactly the rows a
    # sequential engine's cache would hold at each verified position
    kc = kp[jnp.maximum(bt, 0)].reshape(b, s_max, kv, hd)
    vc = vp[jnp.maximum(bt, 0)].reshape(b, s_max, kv, hd)
    rows = pos[:, None].astype(jnp.int32) + jnp.arange(sq, dtype=jnp.int32)[None, :]
    ridx = jnp.where(rows < s_max, rows, s_max)  # OOB rows drop
    bi = jnp.arange(b)[:, None]
    kc = kc.at[bi, ridx].set(kt.astype(kc.dtype), mode="drop")
    vc = vc.at[bi, ridx].set(vt.astype(vc.dtype), mode="drop")

    qg = q.reshape(b, sq, kv, g, hd)
    logits_c = jnp.einsum("bskgh,btkh->bkgst", qg, kc).astype(jnp.float32)
    logits_c = logits_c / (hd**0.5)
    # strict per-ROW prefix mask: row i sees the committed prefix plus the
    # earlier draft rows (which now live in the view at pos_b..pos_b+i-1)
    mask = jnp.arange(s_max)[None, None, :] < rows[:, :, None]  # (B, sq, S)
    logits_c = jnp.where(mask[:, None, None, :, :], logits_c, _NEG_INF)
    logit_s = jnp.einsum("bskgh,bskh->bkgs", qg, kt).astype(jnp.float32)[..., None]
    logit_s = logit_s / (hd**0.5)
    m = jnp.maximum(jnp.max(logits_c, axis=-1, keepdims=True), logit_s)
    pc = jnp.exp(logits_c - m)
    ps = jnp.exp(logit_s - m)
    den = jnp.sum(pc, axis=-1, keepdims=True) + ps
    out = jnp.einsum("bkgst,btkh->bskgh", (pc / den).astype(vc.dtype), vc)
    self_w = (ps / den)[..., 0][..., None].transpose(0, 3, 1, 2, 4).astype(vt.dtype)
    out = out + self_w * vt[:, :, :, None, :]
    out = out.reshape(b, sq, h, hd)
    if not commit:
        return out
    slot_ids = jnp.repeat(jnp.arange(b, dtype=jnp.int32), sq)
    kp_new = scatter_rows_pool(kp, kt.reshape(b * sq, kv, hd), bt, slot_ids, rows.reshape(-1))
    vp_new = scatter_rows_pool(vp, vt.reshape(b * sq, kv, hd), bt, slot_ids, rows.reshape(-1))
    return out, kp_new, vp_new


def _fold(m_s, l_s, acc_s, h_i, hd, s, valid, vmat):
    """One online-softmax fold for head ``h_i``: s (T, S') raw f32 scores,
    valid (T, S') mask, vmat (S', hd) values. All-False rows are inert
    (``m`` stays, corr = exp(0) = 1, zero mass)."""
    m_old = m_s[:, h_i : h_i + 1]
    l_old = l_s[:, h_i : h_i + 1]
    a_old = acc_s[:, h_i * hd : (h_i + 1) * hd]
    s = jnp.where(valid, s, _NEG_INF)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_old - m_new)
    m_s[:, h_i : h_i + 1] = m_new
    l_s[:, h_i : h_i + 1] = l_old * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_s[:, h_i * hd : (h_i + 1) * hd] = a_old * corr + jax.lax.dot_general(
        p, vmat.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _paged_decode_fwd(
    # scalar prefetch
    bt_ref,  # (B, maxp) int32 — block tables, read by index maps + validity
    tails_ref,  # (B, 2) int32 — clamped tail PAGE ids (epilogue streams)
    posp_ref,  # (B,) int32 — committed prefix lengths (epilogue offsets)
    # inputs
    q_ref,  # (B*sq, H*hd) bf16 — whole query panel, resident
    kp_ref,  # (1, page, KV*hd) bf16 — one K page, streamed via bt / tails
    vp_ref,  # (1, page, KV*hd) bf16 — one V page, streamed via bt / tails
    kt_ref,  # (B*sq, KV*hd) bf16 — draft K rows, resident
    vt_ref,  # (B*sq, KV*hd) bf16 — draft V rows, resident
    kslot_ref,  # (sq, KV*hd) bf16 — current slot's draft K rows (BlockSpec slice)
    vslot_ref,  # (sq, KV*hd) bf16
    pos_c_ref,  # (B*sq, 1) int32 — per-row committed prefix length
    # outputs
    o_ref,  # (B*sq, H*hd) bf16
    kp_o_ref,  # (1, page, KV*hd) bf16 — tail page write-back (aliased to kp)
    vp_o_ref,  # (1, page, KV*hd) bf16 — tail page write-back (aliased to vp)
    # scratch (persist across the sequential grid)
    m_s,  # (B*sq, H) f32
    l_s,  # (B*sq, H) f32
    acc_s,  # (B*sq, H*hd) f32
    *,
    b_slots: int,
    sq: int,
    maxp: int,
    page: int,
    g: int,
    hd: int,
    h_total: int,
    scale: float,
    commit: bool,
):
    b = pl.program_id(0)
    j = pl.program_id(1)
    t2 = b_slots * sq
    last_j = maxp + 1 if commit else maxp

    @pl.when((b == 0) & (j == 0))
    def _init():
        m_s[...] = jnp.full(m_s.shape, _NEG_INF, jnp.float32)
        l_s[...] = jnp.zeros(l_s.shape, jnp.float32)
        acc_s[...] = jnp.zeros(acc_s.shape, jnp.float32)

    rid = jax.lax.broadcasted_iota(jnp.int32, (t2, 1), 0)  # row index column
    row_b = (rid // sq) == b  # (T2, 1): rows owned by the current slot

    @pl.when(j < maxp)
    def _cache_page():
        # committed prefix: one page of slot b's cache (fetched through the
        # block table by the BlockSpec index map; -1 clamps to page 0 and is
        # masked here)
        page_ok = bt_ref[b, j] >= 0
        kv_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        valid = row_b & (kv_pos < pos_c_ref[...]) & page_ok  # (T2, page)
        for h_i in range(h_total):
            kv_i = h_i // g
            qh = q_ref[:, h_i * hd : (h_i + 1) * hd]  # (T2, hd)
            kh = kp_ref[0][:, kv_i * hd : (kv_i + 1) * hd]  # (page, hd)
            s = jax.lax.dot_general(
                qh, kh, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * scale
            _fold(m_s, l_s, acc_s, h_i, hd, s, valid,
                  vp_ref[0][:, kv_i * hd : (kv_i + 1) * hd])

    @pl.when(j == maxp)
    def _in_batch():
        # draft rows: same-slot causal prefix, including self. Row order
        # inside a slot IS draft order, so the causal condition is col <= row.
        cid = jax.lax.broadcasted_iota(jnp.int32, (1, t2), 1)
        valid = row_b & ((cid // sq) == b) & (cid <= rid)  # (T2, T2)
        for h_i in range(h_total):
            kv_i = h_i // g
            qh = q_ref[:, h_i * hd : (h_i + 1) * hd]
            kh = kt_ref[:, kv_i * hd : (kv_i + 1) * hd]  # (T2, hd)
            s = jax.lax.dot_general(
                qh, kh, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * scale
            _fold(m_s, l_s, acc_s, h_i, hd, s, valid,
                  vt_ref[:, kv_i * hd : (kv_i + 1) * hd])

    if commit:

        @pl.when(j >= maxp)
        def _commit_tail():
            # fused scatter epilogue: rewrite this step's tail page (streamed
            # in through kp_ref/vp_ref by the same index map that the output
            # flushes back through) with the draft rows that land in it.
            # Step maxp handles the page holding pos_b, step maxp+1 the page
            # holding pos_b + sq - 1 (the span may straddle a boundary; when
            # it does not, both steps rewrite the same page identically).
            pos_b = posp_ref[b]
            this_col = jnp.where(j == maxp, pos_b // page, (pos_b + sq - 1) // page)
            off_iota = jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)
            k_acc = kp_ref[0]
            v_acc = vp_ref[0]
            for i in range(sq):
                abs_i = pos_b + i
                pi = abs_i // page
                mapped = bt_ref[b, jnp.where(pi < maxp, pi, 0)] >= 0
                ok = (pi == this_col) & (pi < maxp) & mapped
                sel = (off_iota == (abs_i - pi * page)) & ok  # (page, 1)
                k_acc = jnp.where(sel, kslot_ref[i : i + 1, :], k_acc)
                v_acc = jnp.where(sel, vslot_ref[i : i + 1, :], v_acc)
            kp_o_ref[0] = k_acc
            vp_o_ref[0] = v_acc

    @pl.when((b == b_slots - 1) & (j == last_j))
    def _finalize():
        # l can never be 0 here (every row at least sees itself), but keep
        # the guarded divide for uniformity with the ragged kernel
        for h_i in range(h_total):
            l_h = jnp.maximum(l_s[:, h_i : h_i + 1], 1e-30)
            o_ref[:, h_i * hd : (h_i + 1) * hd] = (
                acc_s[:, h_i * hd : (h_i + 1) * hd] / l_h
            ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("commit", "interpret"))
def paged_decode_kernel(q, kp, vp, kt, vt, bt, pos, *, commit: bool = True,
                        interpret: bool = False):
    """Pallas launch wrapper; same signature/semantics as the ref.

    With ``commit=True`` returns ``(out, kp_new, vp_new)`` where the pools
    are aliased in place (the caller's kp/vp buffers are donated); with
    ``commit=False`` returns ``out`` only and never touches the pools —
    the scan-stacked model paths use this and batch ONE page commit per
    layer after the scan.
    """
    b, sq, h, hd = q.shape
    kv = kt.shape[2]
    g = h // kv
    maxp = bt.shape[1]
    page = kp.shape[1]
    validate_paged_decode(b, sq, h, kv, hd, maxp, page)
    t2 = b * sq

    q2 = q.reshape(t2, h * hd)
    kp2 = kp.reshape(kp.shape[0], page, kv * hd)
    vp2 = vp.reshape(vp.shape[0], page, kv * hd)
    kt2 = kt.reshape(t2, kv * hd)
    vt2 = vt.reshape(t2, kv * hd)
    pos32 = pos.astype(jnp.int32)
    pos_c = jnp.repeat(pos32, sq).reshape(t2, 1)
    bt32 = bt.astype(jnp.int32)
    # clamped tail PAGE ids for the epilogue streams (invalid -> page 0,
    # reads are harmless and writes are predicated off in-kernel)
    sl = jnp.arange(b)
    pi0 = jnp.clip(pos32 // page, 0, maxp - 1)
    pi1 = jnp.clip((pos32 + sq - 1) // page, 0, maxp - 1)
    tails = jnp.stack(
        [jnp.maximum(bt32[sl, pi0], 0), jnp.maximum(bt32[sl, pi1], 0)], axis=-1
    )

    kernel = functools.partial(
        _paged_decode_fwd,
        b_slots=b, sq=sq, maxp=maxp, page=page, g=g, hd=hd, h_total=h,
        scale=hd**-0.5, commit=commit,
    )

    n_j = maxp + 2 if commit else maxp + 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_j),
        in_specs=[
            pl.BlockSpec((t2, h * hd), lambda bi, ji, bts, tls, pp: (0, 0)),
            # cache sweep reads bt[bi, ji] (unmapped -1 clamps to page 0,
            # masked in-kernel); the epilogue steps (ji >= maxp) stream the
            # pre-clamped tail pages so the commit can copy-modify-flush them
            pl.BlockSpec(
                (1, page, kv * hd),
                lambda bi, ji, bts, tls, pp: (
                    jnp.where(
                        ji < maxp,
                        jnp.where(
                            bts[bi, jnp.where(ji < maxp, ji, 0)] < 0,
                            0,
                            bts[bi, jnp.where(ji < maxp, ji, 0)],
                        ),
                        jnp.where(ji == maxp, tls[bi, 0], tls[bi, 1]),
                    ),
                    0,
                    0,
                ),
            ),
            pl.BlockSpec(
                (1, page, kv * hd),
                lambda bi, ji, bts, tls, pp: (
                    jnp.where(
                        ji < maxp,
                        jnp.where(
                            bts[bi, jnp.where(ji < maxp, ji, 0)] < 0,
                            0,
                            bts[bi, jnp.where(ji < maxp, ji, 0)],
                        ),
                        jnp.where(ji == maxp, tls[bi, 0], tls[bi, 1]),
                    ),
                    0,
                    0,
                ),
            ),
            pl.BlockSpec((t2, kv * hd), lambda bi, ji, bts, tls, pp: (0, 0)),
            pl.BlockSpec((t2, kv * hd), lambda bi, ji, bts, tls, pp: (0, 0)),
            # the current slot's own draft rows, sliced out by the BlockSpec
            # so the epilogue indexes them statically
            pl.BlockSpec((sq, kv * hd), lambda bi, ji, bts, tls, pp: (bi, 0)),
            pl.BlockSpec((sq, kv * hd), lambda bi, ji, bts, tls, pp: (bi, 0)),
            pl.BlockSpec((t2, 1), lambda bi, ji, bts, tls, pp: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t2, h * hd), lambda bi, ji, bts, tls, pp: (0, 0)),
        ] + ([
            pl.BlockSpec(
                (1, page, kv * hd),
                lambda bi, ji, bts, tls, pp: (
                    jnp.where(ji <= maxp, tls[bi, 0], tls[bi, 1]),
                    0,
                    0,
                ),
            ),
            pl.BlockSpec(
                (1, page, kv * hd),
                lambda bi, ji, bts, tls, pp: (
                    jnp.where(ji <= maxp, tls[bi, 0], tls[bi, 1]),
                    0,
                    0,
                ),
            ),
        ] if commit else []),
        scratch_shapes=[
            pltpu.VMEM((t2, h), jnp.float32),
            pltpu.VMEM((t2, h), jnp.float32),
            pltpu.VMEM((t2, h * hd), jnp.float32),
        ],
    )
    out_shape = [jax.ShapeDtypeStruct((t2, h * hd), vt.dtype)]
    if commit:
        out_shape += [
            jax.ShapeDtypeStruct(kp2.shape, kp2.dtype),
            jax.ShapeDtypeStruct(vp2.shape, vp2.dtype),
        ]
    if not commit:
        # trim the unused operands' bodies via a thin adapter: the body
        # signature keeps the full operand list, outputs simply lack the
        # tail write-backs
        def kernel_nc(bt_r, tl_r, pp_r, q_r, kp_r, vp_r, kt_r, vt_r, ks_r,
                      vs_r, pc_r, o_r, m_r, l_r, a_r):
            return kernel(bt_r, tl_r, pp_r, q_r, kp_r, vp_r, kt_r, vt_r,
                          ks_r, vs_r, pc_r, o_r, None, None, m_r, l_r, a_r)

        body = kernel_nc
    else:
        body = kernel
    res = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        # operand order: bt, tails, posp, q2, kp2, vp2, kt2, vt2, kslot,
        # vslot, pos_c -> kp2/vp2 are operands 4/5, aliased onto outputs 1/2
        input_output_aliases={4: 1, 5: 2} if commit else {},
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY, pltpu.ARBITRARY)
        ),
        interpret=interpret,
    )(bt32, tails, pos32, q2, kp2, vp2, kt2, vt2, kt2, vt2, pos_c)
    if commit:
        out, kp_new, vp_new = res
        return (
            out.reshape(b, sq, h, hd),
            kp_new.reshape(kp.shape),
            vp_new.reshape(vp.shape),
        )
    return res[0].reshape(b, sq, h, hd) if isinstance(res, (list, tuple)) else res.reshape(b, sq, h, hd)
