"""Fused dual-component W4A4/W4A8 GEMM — the paper's §4.3 kernel, TPU-native.

One ``pl.pallas_call`` computes

    Y = dq(Xq @ Rq)  +  dq( requant(dq(Xq @ Uq)) @ Vq )

for a TwinQuant-decomposed linear layer, with:

* activations quantized **in-kernel, once per M×K tile** (at the first N
  block) into a VMEM scratch and reused for both components and all N blocks
  — the paper's "quantize the input activation tile once";
* the two-stage low-rank path pipelined **entirely in VMEM**: the f32
  intermediate ``H = dq(Xq @ Uq)`` lives in a scratch accumulator across K
  steps, is re-quantized on the fly at the last K step of the first N block
  (scale ``s_H`` estimated from the accumulator, as in the paper), and is
  consumed by the second int GEMM without ever touching HBM;
* both component outputs merged in a **single epilogue** with one bf16
  write-back per output tile.

Grid is ``(M/bm, N/bn, K/bk)`` with K innermost
(``dimension_semantics = (parallel, arbitrary, arbitrary)``). HBM traffic:

* weights (U, V, R) move at 4 bits/value (group-split nibble packing — see
  kernels/ref.py for the layout invariant that keeps packed tiles local to
  their scale group);
* U is small (K×r/2 bytes) and is pinned whole in VMEM via a constant-index
  BlockSpec, so it is fetched exactly once per kernel invocation;
* X is fetched once per M block: its index map degenerates to block (m, 0)
  for n > 0, and Pallas skips refetches when the block index is unchanged.

The MXU consumes int8 (TPU has no int4 MMA — see DESIGN.md §3): packed
nibbles are sign-extended to int8 in VMEM by the VPU, and all dots accumulate
in int32 via ``preferred_element_type``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both vintages
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from repro.core.quantization import qmax_for_bits
from repro.kernels.contracts import validate_dual_gemm, validate_dual_gemm_group
from repro.kernels.ref import TwinQuantGroupWeights, TwinQuantWeights

__all__ = ["dual_gemm", "dual_gemm_group", "DEFAULT_BLOCKS"]

DEFAULT_BLOCKS = dict(block_m=128, block_n=256, block_k=512)


def _unpack_rows(p: jax.Array) -> jax.Array:
    """(G/2, w) packed int8 -> (G, w) int8 (group-split layout)."""
    p32 = p.astype(jnp.int32)
    lo = jnp.right_shift(jnp.left_shift(p32, 28), 28)
    hi = jnp.right_shift(jnp.left_shift(p32, 24), 28)
    return jnp.concatenate([lo, hi], axis=0).astype(jnp.int8)


def _int8_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _dual_gemm_kernel(
    # inputs
    x_ref,  # (bm, bk)   bf16 — block (m, k) when n==0 else (m, 0)
    up_ref,  # (K/2, r)  int8 packed — whole array, fetched once
    us_ref,  # (K/G, r)  f32
    vp_ref,  # (r/2, bn) int8 packed
    vs_ref,  # (r/gr, bn) f32
    rp_ref,  # (bk/2, bn) int8 packed
    rs_ref,  # (bk/G, bn) f32
    # output
    o_ref,  # (bm, bn)  bf16
    # scratch
    xq_s,  # (bm, K)    int8 — quantized activation row-panel
    xs_s,  # (bm, K/G)  f32  — its per-group scales
    h_s,  # (bm, r)     f32  — low-rank intermediate accumulator
    hq_s,  # (bm, r)    int8 — requantized H
    hs_s,  # (bm, r/gr) f32  — H scales
    acc_s,  # (bm, bn)  f32  — residual-component accumulator
    *,
    bk: int,
    G: int,
    gr: int,
    r: int,
    a_bits: int,
    n_k: int,
):
    n = pl.program_id(1)
    k = pl.program_id(2)
    a_qmax = qmax_for_bits(a_bits)
    gpb = bk // G  # scale groups per K block

    @pl.when(k == 0)
    def _zero_acc():
        acc_s[...] = jnp.zeros_like(acc_s)

    @pl.when((n == 0) & (k == 0))
    def _zero_h():
        h_s[...] = jnp.zeros_like(h_s)

    # ---- stage A (first N block only): quantize the X tile into scratch and
    # accumulate the first low-rank GEMM H += dq(Xq_g @ Uq_g)
    @pl.when(n == 0)
    def _quantize_and_lowrank():
        x = x_ref[...].astype(jnp.float32)  # (bm, bk)
        for g in range(gpb):
            xg = x[:, g * G : (g + 1) * G]
            amax = jnp.max(jnp.abs(xg), axis=1, keepdims=True)  # (bm, 1)
            scale = jnp.where(amax > 0, amax / a_qmax, 1.0)
            q = jnp.clip(jnp.round(xg / scale), -a_qmax, a_qmax).astype(jnp.int8)
            xq_s[:, pl.ds(k * bk + g * G, G)] = q
            xs_s[:, pl.ds(k * gpb + g, 1)] = scale
            # first low-rank GEMM on the freshly quantized group
            ug = _unpack_rows(up_ref[pl.ds((k * bk + g * G) // 2, G // 2), :])  # (G, r)
            us = us_ref[pl.ds(k * gpb + g, 1), :]  # (1, r)
            ph = _int8_dot(q, ug).astype(jnp.float32)
            h_s[...] += ph * scale * us

    # ---- stage B: residual-component partial for this (n, k) tile
    for g in range(gpb):
        xg = xq_s[:, pl.ds(k * bk + g * G, G)]  # (bm, G) int8
        sg = xs_s[:, pl.ds(k * gpb + g, 1)]  # (bm, 1)
        rg = _unpack_rows(rp_ref[g * (G // 2) : (g + 1) * (G // 2), :])  # (G, bn)
        rs = rs_ref[g : g + 1, :]  # (1, bn)
        pr = _int8_dot(xg, rg).astype(jnp.float32)
        acc_s[...] += pr * sg * rs

    # ---- stage C (first N block, last K step): requantize H on the fly
    @pl.when((n == 0) & (k == n_k - 1))
    def _requantize_h():
        h = h_s[...]
        for gg in range(r // gr):
            hg = h[:, gg * gr : (gg + 1) * gr]
            amax = jnp.max(jnp.abs(hg), axis=1, keepdims=True)
            scale = jnp.where(amax > 0, amax / a_qmax, 1.0)
            hq_s[:, gg * gr : (gg + 1) * gr] = jnp.clip(
                jnp.round(hg / scale), -a_qmax, a_qmax
            ).astype(jnp.int8)
            hs_s[:, gg : gg + 1] = scale

    # ---- stage D (last K step): single epilogue — second low-rank GEMM +
    # merge with the residual accumulator + one write-back
    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_s[...]
        for gg in range(r // gr):
            hqg = hq_s[:, gg * gr : (gg + 1) * gr]  # (bm, gr)
            vg = _unpack_rows(vp_ref[gg * (gr // 2) : (gg + 1) * (gr // 2), :])
            pv = _int8_dot(hqg, vg).astype(jnp.float32)
            out = out + pv * hs_s[:, gg : gg + 1] * vs_ref[gg : gg + 1, :]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def dual_gemm(
    x: jax.Array,
    w: TwinQuantWeights,
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused dual-component quantized matmul. x: (M, K) -> (M, N) bf16.

    M, N, K must be multiples of the block sizes (the ops.py wrapper pads).
    """
    m, k = x.shape
    n = w.ndim_out
    r = w.rank
    G, gr = w.group, w.rgroup
    # grid-coverage/divisibility + VMEM-budget contracts (raise ContractError
    # with the violated relation before Mosaic sees the launch)
    validate_dual_gemm(m, n, k, r, G, gr, block_m, block_n, block_k)
    n_k = k // block_k

    grid = (m // block_m, n // block_n, n_k)

    kernel = functools.partial(
        _dual_gemm_kernel,
        bk=block_k, G=G, gr=gr, r=r, a_bits=w.a_bits, n_k=n_k,
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # X: fetched only during the n==0 sweep (index pins to (m, 0) after)
            pl.BlockSpec(
                (block_m, block_k),
                lambda mi, ni, ki: (mi, jnp.where(ni == 0, ki, 0)),
            ),
            # U pinned whole in VMEM (K*r/2 bytes), fetched once
            pl.BlockSpec((k // 2, r), lambda mi, ni, ki: (0, 0)),
            pl.BlockSpec((k // G, r), lambda mi, ni, ki: (0, 0)),
            pl.BlockSpec((r // 2, block_n), lambda mi, ni, ki: (0, ni)),
            pl.BlockSpec((r // gr, block_n), lambda mi, ni, ki: (0, ni)),
            pl.BlockSpec((block_k // 2, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((block_k // G, block_n), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.bfloat16),
        scratch_shapes=[
            pltpu.VMEM((block_m, k), jnp.int8),
            pltpu.VMEM((block_m, k // G), jnp.float32),
            pltpu.VMEM((block_m, r), jnp.float32),
            pltpu.VMEM((block_m, r), jnp.int8),
            pltpu.VMEM((block_m, r // gr), jnp.float32),
            pltpu.VMEM((block_m, block_n), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.ARBITRARY, pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(x, w.up, w.us, w.vp, w.vs, w.rp, w.rs)


# ---------------------------------------------------------------------------
# fused projection group (q/k/v, gate/up): one launch for all sibling outputs
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def dual_gemm_group(
    x: jax.Array,
    gw: TwinQuantGroupWeights,
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Prefill-shaped fused dual GEMM over a sibling-projection group.

    x: (M, K) -> (M, sum N_j) bf16, M/K multiples of the blocks and
    ``block_n`` dividing every segment's N (so each N block is owned by one
    segment). Relative to running the unfused kernel once per sibling, the
    X tile is quantized ONCE (at the n==0 sweep) instead of once per
    sibling, the X panel is fetched from HBM once instead of S times, and
    the stacked-rank H accumulator is built in a single pass over K. Each
    output block's epilogue contracts only the owning segment's H columns
    against that segment's V (block-diagonal V without materialized zeros),
    and H requantization uses each segment's own rank-group structure — so
    every output segment is bit-exact vs the unfused kernel at the same
    blocks.
    """
    m, k = x.shape
    G = gw.group
    seg_n, seg_r, grs = gw.seg_n, gw.seg_r, gw.rgroups
    n_segs = len(seg_n)
    r_total = gw.rank
    n_total = gw.ndim_out
    # grid-coverage/divisibility + VMEM-budget contracts (per-segment checks
    # included: block_n must never straddle a segment boundary)
    validate_dual_gemm_group(m, k, G, seg_n, seg_r, grs, block_m, block_n, block_k)
    n_k = k // block_k
    bm, bn, bk = block_m, block_n, block_k
    gpb = bk // G  # scale groups per K block
    nblk_off = tuple(no // bn for no in gw.n_offsets)
    nblk_end = tuple((no + nj) // bn for no, nj in zip(gw.n_offsets, seg_n))
    r_off = gw.r_offsets
    hs_off, hs_cols = [], 0
    for rj, gr in zip(seg_r, grs):
        hs_off.append(hs_cols)
        hs_cols += rj // gr
    hs_off = tuple(hs_off)
    a_bits = gw.a_bits

    def kernel(*args):
        x_ref, up_ref, us_ref = args[:3]
        vrefs = args[3 : 3 + 2 * n_segs]
        rp_ref, rs_ref, o_ref = args[3 + 2 * n_segs : 6 + 2 * n_segs]
        xq_s, xs_s, h_s, hq_s, hs_s, acc_s = args[6 + 2 * n_segs :]
        ni = pl.program_id(1)
        ki = pl.program_id(2)
        a_qmax = qmax_for_bits(a_bits)

        @pl.when(ki == 0)
        def _zero_acc():
            acc_s[...] = jnp.zeros_like(acc_s)

        @pl.when((ni == 0) & (ki == 0))
        def _zero_h():
            h_s[...] = jnp.zeros_like(h_s)

        # ---- stage A (first N block only): quantize the X tile once into
        # scratch and accumulate the stacked low-rank GEMM H += dq(Xq @ Uq)
        @pl.when(ni == 0)
        def _quantize_and_lowrank():
            xv = x_ref[...].astype(jnp.float32)  # (bm, bk)
            for g in range(gpb):
                xg = xv[:, g * G : (g + 1) * G]
                amax = jnp.max(jnp.abs(xg), axis=1, keepdims=True)
                scale = jnp.where(amax > 0, amax / a_qmax, 1.0)
                q = jnp.clip(jnp.round(xg / scale), -a_qmax, a_qmax).astype(jnp.int8)
                xq_s[:, pl.ds(ki * bk + g * G, G)] = q
                xs_s[:, pl.ds(ki * gpb + g, 1)] = scale
                ug = _unpack_rows(up_ref[pl.ds((ki * bk + g * G) // 2, G // 2), :])
                us = us_ref[pl.ds(ki * gpb + g, 1), :]
                ph = _int8_dot(q, ug).astype(jnp.float32)
                h_s[...] += ph * scale * us

        # ---- stage B: residual partial for this (concatenated-N, K) tile
        for g in range(gpb):
            xg = xq_s[:, pl.ds(ki * bk + g * G, G)]
            sg = xs_s[:, pl.ds(ki * gpb + g, 1)]
            rg = _unpack_rows(rp_ref[g * (G // 2) : (g + 1) * (G // 2), :])
            rs = rs_ref[g : g + 1, :]
            pr = _int8_dot(xg, rg).astype(jnp.float32)
            acc_s[...] += pr * sg * rs

        # ---- stage C (first N block, last K step): requantize each
        # segment's H columns with that segment's OWN rank groups
        @pl.when((ni == 0) & (ki == n_k - 1))
        def _requantize_h():
            h = h_s[...]
            for j in range(n_segs):
                gr = grs[j]
                for gg in range(seg_r[j] // gr):
                    base = r_off[j] + gg * gr
                    hg = h[:, base : base + gr]
                    amax = jnp.max(jnp.abs(hg), axis=1, keepdims=True)
                    scale = jnp.where(amax > 0, amax / a_qmax, 1.0)
                    hq_s[:, base : base + gr] = jnp.clip(
                        jnp.round(hg / scale), -a_qmax, a_qmax
                    ).astype(jnp.int8)
                    hs_s[:, hs_off[j] + gg : hs_off[j] + gg + 1] = scale

        # ---- stage D (last K step): the owning segment's second low-rank
        # GEMM + merge with the residual accumulator + one write-back
        for j in range(n_segs):

            @pl.when((ki == n_k - 1) & (ni >= nblk_off[j]) & (ni < nblk_end[j]))
            def _seg_epilogue(j=j):
                vp_ref, vs_ref = vrefs[2 * j], vrefs[2 * j + 1]
                loc = (ni - nblk_off[j]) * bn  # column offset inside segment j
                gr = grs[j]
                acc = acc_s[...]
                for gg in range(seg_r[j] // gr):
                    hqg = hq_s[:, r_off[j] + gg * gr : r_off[j] + (gg + 1) * gr]
                    vg = _unpack_rows(
                        vp_ref[gg * (gr // 2) : (gg + 1) * (gr // 2), pl.ds(loc, bn)]
                    )
                    pv = _int8_dot(hqg, vg).astype(jnp.float32)
                    acc = acc + (
                        pv
                        * hs_s[:, hs_off[j] + gg : hs_off[j] + gg + 1]
                        * vs_ref[gg : gg + 1, pl.ds(loc, bn)]
                    )
                o_ref[...] = acc.astype(o_ref.dtype)

    in_specs = [
        # X: fetched only during the n==0 sweep (index pins to (m, 0) after)
        pl.BlockSpec(
            (bm, bk),
            lambda mi, ni, ki: (mi, jnp.where(ni == 0, ki, 0)),
        ),
        # stacked U pinned whole in VMEM, fetched once
        pl.BlockSpec((k // 2, r_total), lambda mi, ni, ki: (0, 0)),
        pl.BlockSpec((k // G, r_total), lambda mi, ni, ki: (0, 0)),
    ]
    for vp, vs in zip(gw.vps, gw.vss):
        # per-segment V resident whole (rank is small; sliced per N block)
        in_specs.append(pl.BlockSpec(vp.shape, lambda mi, ni, ki: (0, 0)))
        in_specs.append(pl.BlockSpec(vs.shape, lambda mi, ni, ki: (0, 0)))
    in_specs += [
        pl.BlockSpec((bk // 2, bn), lambda mi, ni, ki: (ki, ni)),
        pl.BlockSpec((bk // G, bn), lambda mi, ni, ki: (ki, ni)),
    ]
    operands = [x, gw.up, gw.us]
    for vp, vs in zip(gw.vps, gw.vss):
        operands += [vp, vs]
    operands += [gw.rp, gw.rs]

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n_total // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n_total), jnp.bfloat16),
        scratch_shapes=[
            pltpu.VMEM((bm, k), jnp.int8),
            pltpu.VMEM((bm, k // G), jnp.float32),
            pltpu.VMEM((bm, r_total), jnp.float32),
            pltpu.VMEM((bm, r_total), jnp.int8),
            pltpu.VMEM((bm, hs_cols), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.ARBITRARY, pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(*operands)
