"""Unified quantized-linear dispatch: one entry point, three schedules.

Every quantized matmul in the system — model forward passes, the serving
engine, the benchmarks — funnels through :func:`quant_linear` (dual
component) or :func:`w4a16_linear` (weight-only), which route each call by
**shape regime** at trace time:

  * ``prefill`` — M >= 128-panel schedule: the (M/bm, N/bn, K/bk) fused
    kernel in twinquant_dual_gemm.py, blocks from the persisted autotuner
    (kernels/autotune.py) with a deterministic heuristic fallback;
  * ``decode``  — M <= DECODE_M_MAX (the continuous-batching slot count):
    the resident-panel kernel in twinquant_dual_gemv.py, which pins the
    activation panel and both low-rank factors whole in VMEM;
  * ``ref``     — untileable shapes (K not a multiple of the scale group,
    N not 128-aligned, ...) run the exact jnp oracle in kernels/ref.py.
    This replaces the old hard asserts: an odd shape is a routing decision,
    not a crash.

:func:`fused_linear` (kind ``dual_fused``) is the horizontal-fusion entry:
sibling projections that consume the same activation (q/k/v, gate/up) run as
ONE launch over a :class:`~repro.kernels.ref.TwinQuantGroupWeights`, with the
same three-path routing (fused autotune kinds ``dual_prefill_fused`` /
``dual_decode_fused``). :func:`set_fusion` is the process-global A/B switch
the benchmarks toggle.

Routing is a trace-time (static-shape) decision, so under ``jax.jit`` it
costs nothing on the execution path. Each decision increments a **dispatch
counter** keyed ``<kind>/<path>``: under jit that means one bump per
compiled route (per executable, not per step); for eager callers it is one
bump per call. The counters are process-global — the routing tests and the
benchmark gate read them around sequentially-driven engines.

Execution backend is orthogonal to routing (``impl`` argument):

  * ``"auto"``   — Pallas kernel on TPU; on CPU the routed schedule is
    *recorded* but executed with the oracle's exact numerics (interpret-mode
    Pallas is orders of magnitude too slow for the serving engine);
  * ``"kernel"`` — force the routed Pallas kernel (interpret mode on CPU) —
    what the kernel-agreement tests use;
  * ``"ref"``    — force the oracle.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.autotune import DECODE_M_MAX, get_blocks
from repro.kernels.contracts import (
    check_twinquant_group_pack,
    check_twinquant_pack,
    check_w4a16_pack,
)
from repro.kernels.ref import (
    TwinQuantGroupWeights,
    TwinQuantWeights,
    fuse_twinquant_weights,
)
from repro.kernels.twinquant_dual_gemm import dual_gemm, dual_gemm_group
from repro.kernels.twinquant_dual_gemv import dual_gemv, dual_gemv_group
from repro.kernels.w4a16_gemm import w4a16_gemm

__all__ = [
    "DECODE_M_MAX",
    "QuantLinear",
    "QuantLinearGroup",
    "Route",
    "classify_dual",
    "classify_dual_group",
    "classify_paged_decode",
    "classify_ragged",
    "classify_w4a16",
    "default_interpret",
    "dispatch_counters",
    "force_ref_enabled",
    "fused_linear",
    "fusion_enabled",
    "paged_decode",
    "quant_linear",
    "ragged_attention",
    "reset_dispatch_counters",
    "set_force_ref",
    "set_fusion",
    "w4a16_linear",
]

PATH_PREFILL = "prefill"
PATH_DECODE = "decode"
PATH_KERNEL = "kernel"
PATH_REF = "ref"


def default_interpret() -> bool:
    """True when Pallas would run in interpret mode (CPU backend) — the
    ``impl="auto"`` paths then execute the oracle's exact numerics while
    still recording the routed schedule."""
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# fusion policy (process-global, like the counters)
# ---------------------------------------------------------------------------

_fusion_enabled = True


def fusion_enabled() -> bool:
    """Whether sibling-projection groups may fuse into one launch (default)."""
    return _fusion_enabled


def set_fusion(enabled: bool) -> bool:
    """Enable/disable horizontal fusion; returns the previous setting.

    The A/B switch for the benchmarks (``run.py --quick --no-fused``):
    with fusion off, ``models.common.linear_group`` applies each sibling
    through its own :func:`quant_linear` call, the pre-fusion behavior.
    """
    global _fusion_enabled
    prev = _fusion_enabled
    _fusion_enabled = bool(enabled)
    return prev


_force_ref = False


def force_ref_enabled() -> bool:
    """Whether every dispatch entry is forced onto its reference path."""
    return _force_ref


def set_force_ref(enabled: bool) -> bool:
    """Force every dispatch entry point onto its reference path (as if
    ``impl="ref"``); returns the previous setting.

    The chaos harness's degraded-mode switch (launch/faults.py): with the
    flag on, newly-TRACED executables route ``<kind>/ref[forced]`` — the
    graceful-degradation behavior when a kernel backend is suspect. Effect
    is trace-time only: executables compiled before the flip keep their
    routes (jit caching), so flip it before constructing the engine under
    test."""
    global _force_ref
    prev = _force_ref
    _force_ref = bool(enabled)
    return prev


@dataclasses.dataclass(frozen=True)
class Route:
    """A routing decision: which schedule, which blocks, and why.

    ``code`` is the machine-readable fallback reason. For kernel paths it is
    ``"ok"``; for ref routes it names WHY the oracle ran — ``forced`` /
    ``k_group`` / ``rank_rgroup`` (shape can never tile) vs
    ``decode_untileable`` / ``prefill_untileable`` (heuristic_blocks /
    TuneCache yielded no viable blocks for an otherwise kernel-eligible
    shape). The counters record ref routes as ``<kind>/ref[<code>]`` in
    addition to ``<kind>/ref``, so ``routing()`` deltas distinguish an
    intentional oracle route from a block-selection failure.
    """

    path: str  # "prefill" | "decode" | "ref"
    blocks: Optional[tuple[int, int, int]]  # (bm, bn, bk); None for ref
    reason: str
    code: str = "ok"


# ---------------------------------------------------------------------------
# dispatch counters (trace-time)
# ---------------------------------------------------------------------------

_counters: dict[str, int] = {}


def dispatch_counters() -> dict[str, int]:
    """Snapshot of per-(kind, path) routing decision counts."""
    return dict(_counters)


def reset_dispatch_counters() -> None:
    """Zero the process-global routing counters (test/bench bookkeeping)."""
    _counters.clear()


def _record(kind: str, route: Route) -> None:
    key = f"{kind}/{route.path}"
    _counters[key] = _counters.get(key, 0) + 1
    if route.path == PATH_REF:
        # ref routes additionally record their machine-readable fallback
        # reason, so a block-selection failure is distinguishable from an
        # intentional oracle route in routing() deltas
        rkey = f"{kind}/ref[{route.code}]"
        _counters[rkey] = _counters.get(rkey, 0) + 1


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------


def classify_dual(
    m: int, n: int, k: int, group: int, rgroup: int, rank: int
) -> Route:
    """Route a dual-component (M, K) x (K, N) call by shape regime."""
    if k % group != 0 or group % 2 != 0:
        return Route(PATH_REF, None, f"K={k} not tileable by group={group}", "k_group")
    if rank % rgroup != 0 or rgroup % 2 != 0:
        return Route(
            PATH_REF, None, f"rank={rank} not tileable by rgroup={rgroup}", "rank_rgroup"
        )
    if m <= DECODE_M_MAX:
        blocks = get_blocks("dual_decode", m, n, k, group, rank)
        if blocks is None:
            return Route(PATH_REF, None, f"N={n} not 128-aligned", "decode_untileable")
        return Route(PATH_DECODE, blocks, f"M={m}<={DECODE_M_MAX}")
    blocks = get_blocks("dual_prefill", m, n, k, group, rank)
    if blocks is None:
        return Route(PATH_REF, None, f"(N={n}, K={k}) not tileable", "prefill_untileable")
    return Route(PATH_PREFILL, blocks, f"M={m}>{DECODE_M_MAX}")


def classify_dual_group(
    m: int,
    k: int,
    group: int,
    seg_n: tuple[int, ...],
    seg_r: tuple[int, ...],
    rgroups: tuple[int, ...],
) -> Route:
    """Route a fused sibling-projection group by shape regime.

    The fused kernels additionally need a ``block_n`` that tiles EVERY
    segment (an N block must never straddle a segment boundary), so block
    lookup runs against ``gcd(seg_n)``; rank enters the key as the stacked
    total. Anything untileable routes to the per-segment oracle.
    """
    if k % group != 0 or group % 2 != 0:
        return Route(PATH_REF, None, f"K={k} not tileable by group={group}", "k_group")
    for rj, gr in zip(seg_r, rgroups):
        if rj % gr != 0 or gr % 2 != 0:
            return Route(
                PATH_REF, None, f"rank={rj} not tileable by rgroup={gr}", "rank_rgroup"
            )
    ngcd = math.gcd(*seg_n)
    rank = sum(seg_r)
    if m <= DECODE_M_MAX:
        blocks = get_blocks("dual_decode_fused", m, ngcd, k, group, rank)
        if blocks is None:
            return Route(
                PATH_REF, None, f"gcd(N)={ngcd} not 128-aligned", "decode_untileable"
            )
        return Route(PATH_DECODE, blocks, f"M={m}<={DECODE_M_MAX}")
    blocks = get_blocks("dual_prefill_fused", m, ngcd, k, group, rank)
    if blocks is None:
        return Route(
            PATH_REF, None, f"(gcd(N)={ngcd}, K={k}) not tileable", "prefill_untileable"
        )
    return Route(PATH_PREFILL, blocks, f"M={m}>{DECODE_M_MAX}")


def classify_ragged(t: int, h: int, kvh: int, hd: int, b: int, maxp: int,
                    page: int) -> Route:
    """Route a ragged-attention call (kind ``ragged``).

    The kernel has one schedule (grid over ``(B, max_pages + 1)``, whole
    token panel resident), so classification is a viability check, not a
    regime choice: GQA-incompatible head counts route ref (``hd_unaligned``
    also covers head dims the TPU lane layout can't tile), and a token
    budget whose resident panels blow the VMEM budget routes ref (``vmem``).
    """
    from repro.kernels.contracts import ContractError, validate_ragged_attention

    if h % kvh != 0:
        return Route(PATH_REF, None, f"H={h} not grouped by KV={kvh}", "hd_unaligned")
    if hd % 8 != 0:
        return Route(
            PATH_REF, None, f"head_dim={hd} not lane-tileable", "hd_unaligned"
        )
    try:
        validate_ragged_attention(t, h, kvh, hd, b, maxp, page)
    except ContractError:
        return Route(
            PATH_REF, None, f"T={t} resident panels exceed VMEM budget", "vmem"
        )
    return Route(PATH_KERNEL, None, f"ragged schedule (T={t}, maxp={maxp})")


def classify_paged_decode(b: int, sq: int, h: int, kvh: int, hd: int,
                          maxp: int, page: int) -> Route:
    """Route a paged decode-attention call (kind ``paged_decode``).

    Like ``classify_ragged``, the kernel has one schedule (grid over
    ``(B, max_pages + 2)``, whole draft panel resident, tail-page commit in
    the epilogue), so classification is a viability check: GQA-incompatible
    head counts and lane-untileable head dims route ref (``hd_unaligned``),
    a draft stack past the decode panel bound routes ref (``rows``), and a
    panel that blows the VMEM budget routes ref (``vmem``).
    """
    from repro.kernels.contracts import ContractError, validate_paged_decode

    if h % kvh != 0:
        return Route(PATH_REF, None, f"H={h} not grouped by KV={kvh}", "hd_unaligned")
    if hd % 8 != 0:
        return Route(
            PATH_REF, None, f"head_dim={hd} not lane-tileable", "hd_unaligned"
        )
    if sq > DECODE_M_MAX:
        return Route(
            PATH_REF, None,
            f"sq={sq} draft rows exceed DECODE_M_MAX={DECODE_M_MAX}", "rows",
        )
    try:
        validate_paged_decode(b, sq, h, kvh, hd, maxp, page,
                              decode_m_max=DECODE_M_MAX)
    except ContractError:
        return Route(
            PATH_REF, None, f"B*sq={b * sq} panel exceeds VMEM budget", "vmem"
        )
    return Route(PATH_KERNEL, None, f"paged decode schedule (B={b}, sq={sq})")


def classify_w4a16(m: int, n: int, k: int, group: int) -> Route:
    """Route a weight-only call: the prefill-style kernel or the oracle."""
    if k % group != 0 or group % 2 != 0:
        return Route(PATH_REF, None, f"K={k} not tileable by group={group}", "k_group")
    blocks = get_blocks("w4a16", m, n, k, group)
    if blocks is None:
        return Route(PATH_REF, None, f"(N={n}, K={k}) not tileable", "prefill_untileable")
    return Route(PATH_PREFILL, blocks, "weight-only kernel schedule")


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------


def _flatten_m(shape: tuple[int, ...]) -> int:
    """Flattened token-row count of a (..., K) shape — THE M the execution
    path routes on. ``route_for`` inspection uses the same function, so a
    routing preview can never disagree with what ``quant_linear`` runs."""
    return math.prod(shape[:-1])


def _flatten(x: jax.Array) -> tuple[jax.Array, tuple[int, ...], int]:
    batch_shape = x.shape[:-1]
    m = _flatten_m(x.shape)
    return x.reshape(m, x.shape[-1]), batch_shape, m


def _pad_m(x2: jax.Array, bm: int) -> jax.Array:
    pad = (-x2.shape[0]) % bm
    return jnp.pad(x2, ((0, pad), (0, 0))) if pad else x2


def _finish(y, m, batch_shape, n, bias):
    y = y[:m].reshape(*batch_shape, n)
    if bias is not None:
        y = (y.astype(jnp.float32) + bias.astype(jnp.float32)).astype(y.dtype)
    return y


def quant_linear(
    x: jax.Array,
    w: TwinQuantWeights,
    bias: Optional[jax.Array] = None,
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jax.Array:
    """Dual-component quantized linear: (..., K) -> (..., N) bf16, routed.

    Explicit block sizes pin the prefill schedule (legacy kernel-test hook)
    and default ``impl`` to ``"kernel"``.
    """
    k = x.shape[-1]
    n = w.ndim_out
    # pack-consistency contract: a malformed pack (fields disagreeing with
    # each other or with the activation's K) raises a ContractError diagnostic
    # instead of silently falling back to ref or producing garbage numerics
    check_twinquant_pack(w, k)
    x2, batch_shape, m = _flatten(x)
    explicit = block_m is not None or block_n is not None or block_k is not None
    if impl == "ref" or _force_ref:
        route = Route(PATH_REF, None, "forced impl=ref", "forced")
    elif explicit:
        base = get_blocks("dual_prefill", m, n, k, w.group, w.rank) or (
            min(128, m), 128, w.group,
        )
        blocks = (block_m or base[0], block_n or base[1], block_k or base[2])
        route = Route(PATH_PREFILL, blocks, "explicit blocks")
        if impl == "auto":
            impl = "kernel"
    else:
        route = classify_dual(m, n, k, w.group, w.rgroup, w.rank)
    _record("dual", route)

    if interpret is None:
        interpret = default_interpret()
    run_kernel = route.path != PATH_REF and (
        impl == "kernel" or (impl == "auto" and not interpret)
    )
    if not run_kernel:
        y = _ref.dual_gemm_ref(x2, w)
    elif route.path == PATH_DECODE:
        y = dual_gemv(x2, w, block_n=route.blocks[1], interpret=interpret)
    else:
        bm, bn, bk = route.blocks
        y = dual_gemm(
            _pad_m(x2, bm), w, block_m=bm, block_n=bn, block_k=bk, interpret=interpret
        )
    return _finish(y, m, batch_shape, n, bias)


def fused_linear(
    x: jax.Array,
    ws: Union[TwinQuantGroupWeights, Sequence[TwinQuantWeights]],
    biases: Optional[Sequence[Optional[jax.Array]]] = None,
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, ...]:
    """Fused sibling-projection linear: (..., K) -> per-segment (..., N_j).

    One routed launch computes every projection in the group (q/k/v,
    gate/up): the activation is quantized once and its panel fetched once,
    instead of once per sibling. Routing kind is ``dual_fused`` — its
    counter entries are the per-trace launch-count evidence the bench gate
    reads. Numerics per segment are identical to :func:`quant_linear` on the
    unfused pack (decode bit-exact, prefill within f32-reassociation ULPs of
    the oracle, exactly like the unfused kernels).
    """
    gw = ws if isinstance(ws, TwinQuantGroupWeights) else fuse_twinquant_weights(ws)
    if biases is None:
        biases = (None,) * gw.n_segments
    assert len(biases) == gw.n_segments, (len(biases), gw.n_segments)
    k = x.shape[-1]
    # pack-consistency contract (see quant_linear): malformed fused packs get
    # a diagnostic, not a silent fallback
    check_twinquant_group_pack(gw, k)
    x2, batch_shape, m = _flatten(x)
    if impl == "ref" or _force_ref:
        route = Route(PATH_REF, None, "forced impl=ref", "forced")
    else:
        route = classify_dual_group(m, k, gw.group, gw.seg_n, gw.seg_r, gw.rgroups)
    _record("dual_fused", route)

    if interpret is None:
        interpret = default_interpret()
    run_kernel = route.path != PATH_REF and (
        impl == "kernel" or (impl == "auto" and not interpret)
    )
    if not run_kernel:
        y = _ref.dual_gemm_group_ref(x2, gw)
    elif route.path == PATH_DECODE:
        y = dual_gemv_group(x2, gw, block_n=route.blocks[1], interpret=interpret)
    else:
        bm, bn, bk = route.blocks
        y = dual_gemm_group(
            _pad_m(x2, bm), gw, block_m=bm, block_n=bn, block_k=bk,
            interpret=interpret,
        )
    return tuple(
        _finish(yj, m, batch_shape, nj, bj)
        for yj, nj, bj in zip(gw.split(y), gw.seg_n, biases)
    )


def w4a16_linear(
    x: jax.Array,
    wp: jax.Array,
    ws: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    group: int = 128,
    impl: str = "auto",
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jax.Array:
    """Weight-only quantized linear: (..., K) -> (..., N) bf16, routed."""
    k = x.shape[-1]
    n = wp.shape[-1]
    # pack-consistency contract (see quant_linear)
    check_w4a16_pack(wp, ws, k, group)
    x2, batch_shape, m = _flatten(x)
    explicit = block_m is not None or block_n is not None or block_k is not None
    if impl == "ref" or _force_ref:
        route = Route(PATH_REF, None, "forced impl=ref", "forced")
    elif explicit:
        base = get_blocks("w4a16", m, n, k, group) or (min(128, m), 128, group)
        blocks = (block_m or base[0], block_n or base[1], block_k or base[2])
        route = Route(PATH_PREFILL, blocks, "explicit blocks")
        if impl == "auto":
            impl = "kernel"
    else:
        route = classify_w4a16(m, n, k, group)
    _record("w4a16", route)

    if interpret is None:
        interpret = default_interpret()
    run_kernel = route.path != PATH_REF and (
        impl == "kernel" or (impl == "auto" and not interpret)
    )
    if not run_kernel:
        y = _ref.w4a16_gemm_ref(x2, wp, ws, group=group)
    else:
        bm, bn, bk = route.blocks
        y = w4a16_gemm(
            _pad_m(x2, bm), wp, ws,
            group=group, block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
        )
    return _finish(y, m, batch_shape, n, bias)


def ragged_attention(
    q: jax.Array,
    kp: jax.Array,
    vp: jax.Array,
    kt: jax.Array,
    vt: jax.Array,
    bt: jax.Array,
    slot: jax.Array,
    pos: jax.Array,
    ctx: jax.Array,
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Routed ragged paged attention: one launch for a mixed token batch.

    ``q (T, H, hd)`` / ``kt, vt (T, KV, hd)`` are this step's post-RoPE rows,
    ``kp, vp (P, page, KV, hd)`` one layer's paged K/V pools, ``bt (B,
    maxp)`` the block tables, ``slot/pos (T,)`` the ragged row metadata
    (``slot == B`` marks padding) and ``ctx (B,)`` each slot's committed
    prefix length. Returns the (T, H, hd) attention output; pad rows are
    garbage and must be discarded by the caller.

    Routing kind is ``ragged`` (paths ``kernel`` / ``ref``); like the linear
    entries, ``impl="auto"`` on CPU records the routed schedule but executes
    the jnp oracle.
    """
    from repro.kernels.contracts import check_ragged_args
    from repro.kernels.ragged_attention import (
        ragged_attention_kernel,
        ragged_attention_ref,
    )

    check_ragged_args(q, kp, vp, kt, vt, bt, slot, pos, ctx)
    t, h, hd = q.shape
    kvh = kt.shape[1]
    b, maxp = bt.shape
    if impl == "ref" or _force_ref:
        route = Route(PATH_REF, None, "forced impl=ref", "forced")
    else:
        route = classify_ragged(t, h, kvh, hd, b, maxp, kp.shape[1])
    _record("ragged", route)

    if interpret is None:
        interpret = default_interpret()
    run_kernel = route.path != PATH_REF and (
        impl == "kernel" or (impl == "auto" and not interpret)
    )
    if not run_kernel:
        return ragged_attention_ref(q, kp, vp, kt, vt, bt, slot, pos, ctx)
    return ragged_attention_kernel(
        q, kp, vp, kt, vt, bt, slot, pos, ctx, interpret=interpret
    )


def paged_decode(
    q: jax.Array,
    kp: jax.Array,
    vp: jax.Array,
    kt: jax.Array,
    vt: jax.Array,
    bt: jax.Array,
    pos: jax.Array,
    *,
    commit: bool = True,
    impl: str = "auto",
    interpret: Optional[bool] = None,
):
    """Routed paged decode attention: block-table indirection in-kernel.

    ``q (B, sq, H, hd)`` / ``kt, vt (B, sq, KV, hd)`` are post-RoPE draft
    rows (``sq == 1`` is plain decode; speculative verification stacks up to
    DECODE_M_MAX rows per slot), ``kp, vp (P, page, KV, hd)`` one layer's
    paged K/V pools, ``bt (B, maxp)`` the block tables and ``pos (B,)`` each
    slot's committed prefix length. Row ``i`` of slot ``b`` attends the
    committed prefix ``[0, pos_b)`` plus draft rows ``<= i`` — no dense
    ``gather_pages`` view is ever materialized.

    With ``commit=True`` returns ``(out, kp_new, vp_new)`` with the draft
    K/V scattered into the tail pages (fused into the kernel epilogue on the
    kernel path; the caller's pool buffers are donated). With
    ``commit=False`` returns ``out`` only — the scan-stacked model paths use
    this and batch one page commit per layer after the scan.

    Routing kind is ``paged_decode`` (paths ``kernel`` / ``ref``); like the
    other entries, ``impl="auto"`` on CPU records the routed schedule but
    executes the jnp oracle (whose ``sq == 1`` numerics are bit-identical to
    the dense-view decode path it replaces).
    """
    from repro.kernels.contracts import check_paged_decode_args
    from repro.kernels.paged_attention import paged_decode_kernel, paged_decode_ref

    check_paged_decode_args(q, kp, vp, kt, vt, bt, pos)
    b, sq, h, hd = q.shape
    kvh = kt.shape[2]
    maxp = bt.shape[1]
    if impl == "ref" or _force_ref:
        route = Route(PATH_REF, None, "forced impl=ref", "forced")
    else:
        route = classify_paged_decode(b, sq, h, kvh, hd, maxp, kp.shape[1])
    _record("paged_decode", route)

    if interpret is None:
        interpret = default_interpret()
    run_kernel = route.path != PATH_REF and (
        impl == "kernel" or (impl == "auto" and not interpret)
    )
    if not run_kernel:
        return paged_decode_ref(q, kp, vp, kt, vt, bt, pos, commit=commit)
    return paged_decode_kernel(
        q, kp, vp, kt, vt, bt, pos, commit=commit, interpret=interpret
    )


class QuantLinear:
    """A routed quantized linear layer bound to one weight pack.

    Thin convenience wrapper over :func:`quant_linear` for callers that hold
    a :class:`TwinQuantWeights` (offline quantization pipelines, notebooks):

        layer = QuantLinear(weights, bias)
        y = layer(x)              # routed by x's shape regime
        layer.route_for(x.shape)  # inspect the decision without running
    """

    def __init__(self, w: TwinQuantWeights, bias: Optional[jax.Array] = None):
        self.w = w
        self.bias = bias

    def __call__(self, x: jax.Array, *, impl: str = "auto") -> jax.Array:
        return quant_linear(x, self.w, self.bias, impl=impl)

    def route_for(self, shape: tuple[int, ...]) -> Route:
        """Routing decision for an activation of ``shape``, without running.

        Uses the same M computation as quant_linear's _flatten: inspection
        and execution can never disagree on the shape regime."""
        return classify_dual(
            _flatten_m(shape), self.w.ndim_out, shape[-1],
            self.w.group, self.w.rgroup, self.w.rank,
        )


class QuantLinearGroup:
    """A routed fused projection group bound to sibling weight packs.

    The group-level counterpart of :class:`QuantLinear`: one launch computes
    every sibling projection of a shared activation.

        qkv = QuantLinearGroup([wq, wk, wv], [bq, None, None])
        q, k, v = qkv(x)              # one routed fused launch
        qkv.route_for(x.shape)        # inspect without running
    """

    def __init__(
        self,
        ws: Union[TwinQuantGroupWeights, Sequence[TwinQuantWeights]],
        biases: Optional[Sequence[Optional[jax.Array]]] = None,
    ):
        self.gw = ws if isinstance(ws, TwinQuantGroupWeights) else fuse_twinquant_weights(ws)
        self.biases = biases

    def __call__(self, x: jax.Array, *, impl: str = "auto") -> tuple[jax.Array, ...]:
        return fused_linear(x, self.gw, self.biases, impl=impl)

    def route_for(self, shape: tuple[int, ...]) -> Route:
        """Routing decision for an activation of ``shape``, without running."""
        gw = self.gw
        return classify_dual_group(
            _flatten_m(shape), shape[-1], gw.group, gw.seg_n, gw.seg_r, gw.rgroups
        )
