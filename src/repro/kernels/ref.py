"""Pure-jnp oracles for the TwinQuant kernels.

These define the EXACT numerics the Pallas kernels must reproduce — same
group structure, same rounding (``jnp.round``), same f32 accumulation order —
so interpret-mode kernel tests can compare with tight tolerances.

Packing layout ("group-split rows"): quantized weights are packed two int4
values per int8 byte along the contraction axis (axis 0). Within each scale
group of ``G`` rows, packed row ``j`` of the group holds logical row ``j``
(low nibble) and row ``j + G/2`` (high nibble). This keeps every packed block
fully local to its scale group, so a ``(block_k/2, block_n)`` packed tile
unpacks into exactly the ``(block_k, block_n)`` logical tile of the kernel's
current K block — the property the TPU kernel's BlockSpec tiling relies on
(a global interleaved layout would not block correctly).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantization import qmax_for_bits

__all__ = [
    "pack_rows_groupsplit",
    "unpack_rows_groupsplit",
    "quantize_rows_ref",
    "quantize_act_ref",
    "dual_gemm_ref",
    "w4a16_gemm_ref",
    "TwinQuantWeights",
    "pack_twinquant_weights",
]


# ---------------------------------------------------------------------------
# group-split packing along axis 0
# ---------------------------------------------------------------------------


def pack_rows_groupsplit(q: jax.Array, group: int) -> jax.Array:
    """(K, N) int4-valued int8 -> (K/2, N) packed, group-split layout."""
    k, n = q.shape
    assert k % group == 0 and group % 2 == 0, (k, group)
    g2 = group // 2
    q4 = q.reshape(k // group, 2, g2, n)
    lo = q4[:, 0]
    hi = q4[:, 1]
    packed = (lo & 0x0F) | ((hi & 0x0F) << 4)
    return packed.astype(jnp.int8).reshape(k // 2, n)


def unpack_rows_groupsplit(p: jax.Array, group: int) -> jax.Array:
    """Inverse of :func:`pack_rows_groupsplit`."""
    k2, n = p.shape
    g2 = group // 2
    p4 = p.reshape(k2 // g2, g2, n).astype(jnp.int32)
    lo = jnp.right_shift(jnp.left_shift(p4, 28), 28)
    hi = jnp.right_shift(jnp.left_shift(p4, 24), 28)
    out = jnp.concatenate([lo, hi], axis=1)  # (K/group, group, n)
    return out.reshape(k2 * 2, n).astype(jnp.int8)


# ---------------------------------------------------------------------------
# quantization helpers shared with the kernel (identical rounding)
# ---------------------------------------------------------------------------


def quantize_rows_ref(w: jax.Array, group: int, bits: int):
    """Group-wise symmetric quantization along axis 0.

    Returns (q int8 (K, N), scales f32 (K/group, N)).
    """
    k, n = w.shape
    qmax = qmax_for_bits(bits)
    g = w.reshape(k // group, group, n).astype(jnp.float32)
    amax = jnp.max(jnp.abs(g), axis=1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(g / scale[:, None, :]), -qmax, qmax)
    return q.reshape(k, n).astype(jnp.int8), scale.astype(jnp.float32)


def quantize_act_ref(x: jax.Array, group: int, bits: int):
    """Group-wise symmetric quantization along axis 1 (activations).

    Returns (q int8 (M, K), scales f32 (M, K/group)).
    """
    m, k = x.shape
    qmax = qmax_for_bits(bits)
    g = x.reshape(m, k // group, group).astype(jnp.float32)
    amax = jnp.max(jnp.abs(g), axis=2)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(g / scale[:, :, None]), -qmax, qmax)
    return q.reshape(m, k).astype(jnp.int8), scale.astype(jnp.float32)


def _int8_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


# ---------------------------------------------------------------------------
# packed-weight container (produced offline, consumed by kernel + oracle)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TwinQuantWeights:
    """Offline-quantized dual-component weights (HBM-resident, 4-bit packed)."""

    up: jax.Array  # (K/2, r)   packed int4 — low-rank in-factor  Q^T U G
    us: jax.Array  # (K/G, r)   f32 scales
    vp: jax.Array  # (r/2, N)   packed int4 — low-rank out-factor G^-1 V
    vs: jax.Array  # (r/gr, N)  f32 scales
    rp: jax.Array  # (K/2, N)   packed int4 — residual Q^T R
    rs: jax.Array  # (K/G, N)   f32 scales
    group: int  # K-axis scale group (128)
    rgroup: int  # r-axis scale group (min(128, r))
    a_bits: int  # activation bits (4 or 8); H is requantized at a_bits

    def tree_flatten(self):
        return (self.up, self.us, self.vp, self.vs, self.rp, self.rs), (
            self.group,
            self.rgroup,
            self.a_bits,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def kdim(self) -> int:
        return self.up.shape[0] * 2

    @property
    def ndim_out(self) -> int:
        return self.rp.shape[1]

    @property
    def rank(self) -> int:
        return self.up.shape[1]


def pack_twinquant_weights(
    U: jax.Array,
    V: jax.Array,
    R: jax.Array,
    *,
    w_bits: int = 4,
    a_bits: int = 4,
    group: int = 128,
) -> TwinQuantWeights:
    """Quantize + pack the (already transformed) components offline."""
    assert w_bits == 4, "packed path is int4; use w4a16 for other widths"
    k, r = U.shape
    rgroup = min(group, r)
    uq, us = quantize_rows_ref(U, group, w_bits)
    vq, vs = quantize_rows_ref(V, rgroup, w_bits)
    rq, rs = quantize_rows_ref(R, group, w_bits)
    return TwinQuantWeights(
        up=pack_rows_groupsplit(uq, group),
        us=us,
        vp=pack_rows_groupsplit(vq, rgroup),
        vs=vs,
        rp=pack_rows_groupsplit(rq, group),
        rs=rs,
        group=group,
        rgroup=rgroup,
        a_bits=a_bits,
    )


# ---------------------------------------------------------------------------
# the dual-component GEMM oracle
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("block_k",))
def dual_gemm_ref(x: jax.Array, w: TwinQuantWeights, block_k: int = 512) -> jax.Array:
    """Reference for the fused dual-component kernel.

    y = dq(Xq @ Rq)  +  dq( requant(dq(Xq @ Uq)) @ Vq )

    with group-wise scales and H requantized at ``w.a_bits``. Accumulation
    order matches the kernel: K groups in ascending order via lax.scan.
    """
    m, k = x.shape
    G, gr, a_bits = w.group, w.rgroup, w.a_bits
    a_qmax = qmax_for_bits(a_bits)
    r = w.rank
    n = w.ndim_out

    xq, xs = quantize_act_ref(x, G, a_bits)
    uq = unpack_rows_groupsplit(w.up, G)
    vq = unpack_rows_groupsplit(w.vp, gr)
    rq = unpack_rows_groupsplit(w.rp, G)

    n_groups = k // G

    def group_partial(g):
        xg = jax.lax.dynamic_slice(xq, (0, g * G), (m, G))
        sg = jax.lax.dynamic_slice(xs, (0, g), (m, 1))
        rg = jax.lax.dynamic_slice(rq, (g * G, 0), (G, n))
        ug = jax.lax.dynamic_slice(uq, (g * G, 0), (G, r))
        rsg = jax.lax.dynamic_slice(w.rs, (g, 0), (1, n))
        usg = jax.lax.dynamic_slice(w.us, (g, 0), (1, r))
        acc_r = _int8_dot(xg, rg).astype(jnp.float32) * sg * rsg
        acc_h = _int8_dot(xg, ug).astype(jnp.float32) * sg * usg
        return acc_r, acc_h

    def body(carry, g):
        acc_r, acc_h = carry
        pr, ph = group_partial(g)
        return (acc_r + pr, acc_h + ph), None

    init = (jnp.zeros((m, n), jnp.float32), jnp.zeros((m, r), jnp.float32))
    (acc_r, h), _ = jax.lax.scan(body, init, jnp.arange(n_groups))

    # requantize H at a_bits, gr groups along r
    hg = h.reshape(m, r // gr, gr)
    amax = jnp.max(jnp.abs(hg), axis=2)
    hs = jnp.where(amax > 0, amax / a_qmax, 1.0)
    hq = jnp.clip(jnp.round(hg / hs[:, :, None]), -a_qmax, a_qmax).astype(jnp.int8)
    hq = hq.reshape(m, r)

    out = acc_r
    for gg in range(r // gr):
        hqg = hq[:, gg * gr : (gg + 1) * gr]
        vg = vq[gg * gr : (gg + 1) * gr, :]
        p = _int8_dot(hqg, vg).astype(jnp.float32)
        out = out + p * hs[:, gg][:, None] * w.vs[gg, :][None, :]
    return out.astype(jnp.bfloat16)


@partial(jax.jit, static_argnames=("group",))
def w4a16_gemm_ref(x: jax.Array, wp: jax.Array, ws: jax.Array, group: int = 128) -> jax.Array:
    """Weight-only-quantized GEMM oracle: bf16 activations, int4 weights.

    wp: (K/2, N) packed; ws: (K/G, N) scales. Dequantized weights are cast to
    bf16 and dotted with f32 accumulation, one scale group at a time in
    ascending order — the exact numerics of the w4a16 Pallas kernel.
    """
    wq = unpack_rows_groupsplit(wp, group)
    k, n = wq.shape
    m = x.shape[0]
    xb = x.astype(jnp.bfloat16)

    def body(acc, g):
        wg = jax.lax.dynamic_slice(wq, (g * group, 0), (group, n))
        sg = jax.lax.dynamic_slice(ws, (g, 0), (1, n))
        w_deq = (wg.astype(jnp.float32) * sg).astype(jnp.bfloat16)
        xg = jax.lax.dynamic_slice(xb, (0, g * group), (m, group))
        p = jax.lax.dot_general(
            xg, w_deq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return acc + p, None

    acc, _ = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32), jnp.arange(k // group))
    return acc.astype(jnp.bfloat16)
