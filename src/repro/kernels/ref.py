"""Pure-jnp oracles for the TwinQuant kernels.

These define the EXACT numerics the Pallas kernels must reproduce — same
group structure, same rounding (``jnp.round``), same f32 accumulation order —
so interpret-mode kernel tests can compare with tight tolerances.

Packing layout ("group-split rows"): quantized weights are packed two int4
values per int8 byte along the contraction axis (axis 0). Within each scale
group of ``G`` rows, packed row ``j`` of the group holds logical row ``j``
(low nibble) and row ``j + G/2`` (high nibble). This keeps every packed block
fully local to its scale group, so a ``(block_k/2, block_n)`` packed tile
unpacks into exactly the ``(block_k, block_n)`` logical tile of the kernel's
current K block — the property the TPU kernel's BlockSpec tiling relies on
(a global interleaved layout would not block correctly).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantization import qmax_for_bits

__all__ = [
    "pack_rows_groupsplit",
    "unpack_rows_groupsplit",
    "quantize_rows_ref",
    "quantize_act_ref",
    "dual_gemm_ref",
    "dual_gemm_group_ref",
    "w4a16_gemm_ref",
    "TwinQuantWeights",
    "TwinQuantGroupWeights",
    "pack_twinquant_weights",
    "fuse_twinquant_weights",
]


# ---------------------------------------------------------------------------
# group-split packing along axis 0
# ---------------------------------------------------------------------------


def pack_rows_groupsplit(q: jax.Array, group: int) -> jax.Array:
    """(K, N) int4-valued int8 -> (K/2, N) packed, group-split layout."""
    k, n = q.shape
    assert k % group == 0 and group % 2 == 0, (k, group)
    g2 = group // 2
    q4 = q.reshape(k // group, 2, g2, n)
    lo = q4[:, 0]
    hi = q4[:, 1]
    packed = (lo & 0x0F) | ((hi & 0x0F) << 4)
    return packed.astype(jnp.int8).reshape(k // 2, n)


def unpack_rows_groupsplit(p: jax.Array, group: int) -> jax.Array:
    """Inverse of :func:`pack_rows_groupsplit`."""
    k2, n = p.shape
    g2 = group // 2
    p4 = p.reshape(k2 // g2, g2, n).astype(jnp.int32)
    lo = jnp.right_shift(jnp.left_shift(p4, 28), 28)
    hi = jnp.right_shift(jnp.left_shift(p4, 24), 28)
    out = jnp.concatenate([lo, hi], axis=1)  # (K/group, group, n)
    return out.reshape(k2 * 2, n).astype(jnp.int8)


# ---------------------------------------------------------------------------
# quantization helpers shared with the kernel (identical rounding)
# ---------------------------------------------------------------------------


def quantize_rows_ref(w: jax.Array, group: int, bits: int):
    """Group-wise symmetric quantization along axis 0.

    Returns (q int8 (K, N), scales f32 (K/group, N)).
    """
    k, n = w.shape
    qmax = qmax_for_bits(bits)
    g = w.reshape(k // group, group, n).astype(jnp.float32)
    amax = jnp.max(jnp.abs(g), axis=1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(g / scale[:, None, :]), -qmax, qmax)
    return q.reshape(k, n).astype(jnp.int8), scale.astype(jnp.float32)


def quantize_act_ref(x: jax.Array, group: int, bits: int):
    """Group-wise symmetric quantization along axis 1 (activations).

    Returns (q int8 (M, K), scales f32 (M, K/group)).
    """
    m, k = x.shape
    qmax = qmax_for_bits(bits)
    g = x.reshape(m, k // group, group).astype(jnp.float32)
    amax = jnp.max(jnp.abs(g), axis=2)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(g / scale[:, :, None]), -qmax, qmax)
    return q.reshape(m, k).astype(jnp.int8), scale.astype(jnp.float32)


def _int8_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


# ---------------------------------------------------------------------------
# packed-weight container (produced offline, consumed by kernel + oracle)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TwinQuantWeights:
    """Offline-quantized dual-component weights (HBM-resident, 4-bit packed)."""

    up: jax.Array  # (K/2, r)   packed int4 — low-rank in-factor  Q^T U G
    us: jax.Array  # (K/G, r)   f32 scales
    vp: jax.Array  # (r/2, N)   packed int4 — low-rank out-factor G^-1 V
    vs: jax.Array  # (r/gr, N)  f32 scales
    rp: jax.Array  # (K/2, N)   packed int4 — residual Q^T R
    rs: jax.Array  # (K/G, N)   f32 scales
    group: int  # K-axis scale group (128)
    rgroup: int  # r-axis scale group (min(128, r))
    a_bits: int  # activation bits (4 or 8); H is requantized at a_bits

    def tree_flatten(self):
        return (self.up, self.us, self.vp, self.vs, self.rp, self.rs), (
            self.group,
            self.rgroup,
            self.a_bits,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def kdim(self) -> int:
        return self.up.shape[0] * 2

    @property
    def ndim_out(self) -> int:
        return self.rp.shape[1]

    @property
    def rank(self) -> int:
        return self.up.shape[1]


def pack_twinquant_weights(
    U: jax.Array,
    V: jax.Array,
    R: jax.Array,
    *,
    w_bits: int = 4,
    a_bits: int = 4,
    group: int = 128,
) -> TwinQuantWeights:
    """Quantize + pack the (already transformed) components offline."""
    assert w_bits == 4, "packed path is int4; use w4a16 for other widths"
    k, r = U.shape
    rgroup = min(group, r)
    uq, us = quantize_rows_ref(U, group, w_bits)
    vq, vs = quantize_rows_ref(V, rgroup, w_bits)
    rq, rs = quantize_rows_ref(R, group, w_bits)
    return TwinQuantWeights(
        up=pack_rows_groupsplit(uq, group),
        us=us,
        vp=pack_rows_groupsplit(vq, rgroup),
        vs=vs,
        rp=pack_rows_groupsplit(rq, group),
        rs=rs,
        group=group,
        rgroup=rgroup,
        a_bits=a_bits,
    )


# ---------------------------------------------------------------------------
# fused projection group: sibling packs merged along N (§4.3 horizontal fusion)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TwinQuantGroupWeights:
    """Sibling :class:`TwinQuantWeights` fused along N (one launch per group).

    Projections that consume the SAME activation (q/k/v, gate/up, wq_a/wkv_a)
    are merged so the kernel quantizes X once and fetches its panel once:

    * ``rp``/``rs`` — residual factors concatenated along N. R quantization
      is per (K-group, column), i.e. column-independent, so concatenation IS
      the per-segment quantization, bit for bit.
    * ``up``/``us`` — per-matrix U factors stacked along the rank axis
      (column-independent for the same reason): ``H = [H_0 | H_1 | ...]``.
    * ``vps``/``vss`` — V kept **per segment** (logically a block-diagonal V:
      output segment j only consumes its own H columns). Per-segment storage
      preserves each segment's own rank-axis scale-group structure
      (``rgroups[j]``), which a materialized block-diagonal V could not when
      segments have different ranks — the bit-exactness invariant.

    Segment geometry (``seg_n``, ``seg_r``, offsets) is derived from the
    per-segment ``vps`` shapes, so it stays static under jit/vmap.
    """

    up: jax.Array  # (K/2, R)    packed int4 — U factors stacked along rank
    us: jax.Array  # (K/G, R)    f32 scales
    vps: tuple  # per segment: (r_j/2, N_j) packed int4
    vss: tuple  # per segment: (r_j/gr_j, N_j) f32 scales
    rp: jax.Array  # (K/2, sum N) packed int4 — residuals concatenated
    rs: jax.Array  # (K/G, sum N) f32 scales
    group: int  # shared K-axis scale group
    rgroups: tuple  # per-segment r-axis scale group
    a_bits: int  # shared activation bits

    def tree_flatten(self):
        return (self.up, self.us, self.vps, self.vss, self.rp, self.rs), (
            self.group,
            self.rgroups,
            self.a_bits,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        up, us, vps, vss, rp, rs = children
        return cls(up, us, tuple(vps), tuple(vss), rp, rs, *aux)

    @property
    def kdim(self) -> int:
        return self.rp.shape[0] * 2

    @property
    def n_segments(self) -> int:
        return len(self.vps)

    @property
    def seg_n(self) -> tuple:
        return tuple(vp.shape[1] for vp in self.vps)

    @property
    def seg_r(self) -> tuple:
        return tuple(vp.shape[0] * 2 for vp in self.vps)

    @property
    def ndim_out(self) -> int:
        return self.rp.shape[1]

    @property
    def rank(self) -> int:
        return self.up.shape[1]

    def _offsets(self, sizes) -> tuple:
        offs, acc = [], 0
        for s in sizes:
            offs.append(acc)
            acc += s
        return tuple(offs)

    @property
    def n_offsets(self) -> tuple:
        return self._offsets(self.seg_n)

    @property
    def r_offsets(self) -> tuple:
        return self._offsets(self.seg_r)

    def segment(self, j: int) -> TwinQuantWeights:
        """The j-th sibling pack, recovered as exact views of the fused one."""
        no, ro = self.n_offsets[j], self.r_offsets[j]
        nj, rj = self.seg_n[j], self.seg_r[j]
        return TwinQuantWeights(
            up=self.up[:, ro : ro + rj],
            us=self.us[:, ro : ro + rj],
            vp=self.vps[j],
            vs=self.vss[j],
            rp=self.rp[:, no : no + nj],
            rs=self.rs[:, no : no + nj],
            group=self.group,
            rgroup=self.rgroups[j],
            a_bits=self.a_bits,
        )

    def split(self, y: jax.Array) -> tuple:
        """Split a fused (..., sum N) output into per-segment views."""
        return tuple(
            y[..., no : no + nj] for no, nj in zip(self.n_offsets, self.seg_n)
        )


def fuse_twinquant_weights(ws) -> TwinQuantGroupWeights:
    """Merge sibling packs (same K, group, a_bits) into one fused group.

    Pure concatenation of already-quantized per-segment packs — no
    requantization — so ``fused.segment(j)`` recovers ``ws[j]`` bit-exactly
    and the fused kernels reproduce per-segment unfused numerics.
    """
    ws = tuple(ws)
    assert ws, "need at least one pack"
    base = ws[0]
    for w in ws:
        assert w.up.ndim == 2, "fuse_twinquant_weights takes unstacked 2-D packs"
        assert w.kdim == base.kdim, (w.kdim, base.kdim)
        assert w.group == base.group, (w.group, base.group)
        assert w.a_bits == base.a_bits, (w.a_bits, base.a_bits)
    return TwinQuantGroupWeights(
        up=jnp.concatenate([w.up for w in ws], axis=1),
        us=jnp.concatenate([w.us for w in ws], axis=1),
        vps=tuple(w.vp for w in ws),
        vss=tuple(w.vs for w in ws),
        rp=jnp.concatenate([w.rp for w in ws], axis=1),
        rs=jnp.concatenate([w.rs for w in ws], axis=1),
        group=base.group,
        rgroups=tuple(w.rgroup for w in ws),
        a_bits=base.a_bits,
    )


@jax.jit
def dual_gemm_group_ref(x: jax.Array, gw: TwinQuantGroupWeights) -> jax.Array:
    """Fused-group oracle — genuinely fused, yet bit-exact per segment.

    X is quantized ONCE and one ascending-group scan covers the concatenated
    residual/stacked-U factors; only the H requantization and V epilogue run
    per segment (each with its own rank-group structure). Every operation is
    column-independent and in the same order as :func:`dual_gemm_ref` on the
    segment's own pack, so each output segment equals
    ``dual_gemm_ref(x, gw.segment(j))`` bit for bit — the exactness contract
    the group kernels are tested against (decode exact, prefill within
    f32-reassociation ULPs, exactly like the unfused kernels).
    """
    m, k = x.shape
    G, a_bits = gw.group, gw.a_bits
    a_qmax = qmax_for_bits(a_bits)
    r = gw.rank
    n = gw.ndim_out

    xq, xs = quantize_act_ref(x, G, a_bits)
    uq = unpack_rows_groupsplit(gw.up, G)
    rq = unpack_rows_groupsplit(gw.rp, G)

    n_groups = k // G

    def group_partial(g):
        xg = jax.lax.dynamic_slice(xq, (0, g * G), (m, G))
        sg = jax.lax.dynamic_slice(xs, (0, g), (m, 1))
        rg = jax.lax.dynamic_slice(rq, (g * G, 0), (G, n))
        ug = jax.lax.dynamic_slice(uq, (g * G, 0), (G, r))
        rsg = jax.lax.dynamic_slice(gw.rs, (g, 0), (1, n))
        usg = jax.lax.dynamic_slice(gw.us, (g, 0), (1, r))
        acc_r = _int8_dot(xg, rg).astype(jnp.float32) * sg * rsg
        acc_h = _int8_dot(xg, ug).astype(jnp.float32) * sg * usg
        return acc_r, acc_h

    def body(carry, g):
        acc_r, acc_h = carry
        pr, ph = group_partial(g)
        return (acc_r + pr, acc_h + ph), None

    init = (jnp.zeros((m, n), jnp.float32), jnp.zeros((m, r), jnp.float32))
    (acc_r, h), _ = jax.lax.scan(body, init, jnp.arange(n_groups))

    # per segment: requantize its H columns with its OWN rank groups, then
    # the second low-rank GEMM against its own V
    outs = []
    for j in range(gw.n_segments):
        no, ro = gw.n_offsets[j], gw.r_offsets[j]
        nj, rj, gr = gw.seg_n[j], gw.seg_r[j], gw.rgroups[j]
        hg = h[:, ro : ro + rj].reshape(m, rj // gr, gr)
        amax = jnp.max(jnp.abs(hg), axis=2)
        hs = jnp.where(amax > 0, amax / a_qmax, 1.0)
        hq = jnp.clip(jnp.round(hg / hs[:, :, None]), -a_qmax, a_qmax).astype(jnp.int8)
        hq = hq.reshape(m, rj)
        vq = unpack_rows_groupsplit(gw.vps[j], gr)
        out = acc_r[:, no : no + nj]
        for gg in range(rj // gr):
            hqg = hq[:, gg * gr : (gg + 1) * gr]
            vg = vq[gg * gr : (gg + 1) * gr, :]
            p = _int8_dot(hqg, vg).astype(jnp.float32)
            out = out + p * hs[:, gg][:, None] * gw.vss[j][gg, :][None, :]
        outs.append(out)
    return jnp.concatenate(outs, axis=-1).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# the dual-component GEMM oracle
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("block_k",))
def dual_gemm_ref(x: jax.Array, w: TwinQuantWeights, block_k: int = 512) -> jax.Array:
    """Reference for the fused dual-component kernel.

    y = dq(Xq @ Rq)  +  dq( requant(dq(Xq @ Uq)) @ Vq )

    with group-wise scales and H requantized at ``w.a_bits``. Accumulation
    order matches the kernel: K groups in ascending order via lax.scan.
    """
    m, k = x.shape
    G, gr, a_bits = w.group, w.rgroup, w.a_bits
    a_qmax = qmax_for_bits(a_bits)
    r = w.rank
    n = w.ndim_out

    xq, xs = quantize_act_ref(x, G, a_bits)
    uq = unpack_rows_groupsplit(w.up, G)
    vq = unpack_rows_groupsplit(w.vp, gr)
    rq = unpack_rows_groupsplit(w.rp, G)

    n_groups = k // G

    def group_partial(g):
        xg = jax.lax.dynamic_slice(xq, (0, g * G), (m, G))
        sg = jax.lax.dynamic_slice(xs, (0, g), (m, 1))
        rg = jax.lax.dynamic_slice(rq, (g * G, 0), (G, n))
        ug = jax.lax.dynamic_slice(uq, (g * G, 0), (G, r))
        rsg = jax.lax.dynamic_slice(w.rs, (g, 0), (1, n))
        usg = jax.lax.dynamic_slice(w.us, (g, 0), (1, r))
        acc_r = _int8_dot(xg, rg).astype(jnp.float32) * sg * rsg
        acc_h = _int8_dot(xg, ug).astype(jnp.float32) * sg * usg
        return acc_r, acc_h

    def body(carry, g):
        acc_r, acc_h = carry
        pr, ph = group_partial(g)
        return (acc_r + pr, acc_h + ph), None

    init = (jnp.zeros((m, n), jnp.float32), jnp.zeros((m, r), jnp.float32))
    (acc_r, h), _ = jax.lax.scan(body, init, jnp.arange(n_groups))

    # requantize H at a_bits, gr groups along r
    hg = h.reshape(m, r // gr, gr)
    amax = jnp.max(jnp.abs(hg), axis=2)
    hs = jnp.where(amax > 0, amax / a_qmax, 1.0)
    hq = jnp.clip(jnp.round(hg / hs[:, :, None]), -a_qmax, a_qmax).astype(jnp.int8)
    hq = hq.reshape(m, r)

    out = acc_r
    for gg in range(r // gr):
        hqg = hq[:, gg * gr : (gg + 1) * gr]
        vg = vq[gg * gr : (gg + 1) * gr, :]
        p = _int8_dot(hqg, vg).astype(jnp.float32)
        out = out + p * hs[:, gg][:, None] * w.vs[gg, :][None, :]
    return out.astype(jnp.bfloat16)


@partial(jax.jit, static_argnames=("group",))
def w4a16_gemm_ref(x: jax.Array, wp: jax.Array, ws: jax.Array, group: int = 128) -> jax.Array:
    """Weight-only-quantized GEMM oracle: bf16 activations, int4 weights.

    wp: (K/2, N) packed; ws: (K/G, N) scales. Dequantized weights are cast to
    bf16 and dotted with f32 accumulation, one scale group at a time in
    ascending order — the exact numerics of the w4a16 Pallas kernel.
    """
    wq = unpack_rows_groupsplit(wp, group)
    k, n = wq.shape
    m = x.shape[0]
    xb = x.astype(jnp.bfloat16)

    def body(acc, g):
        wg = jax.lax.dynamic_slice(wq, (g * group, 0), (group, n))
        sg = jax.lax.dynamic_slice(ws, (g, 0), (1, n))
        w_deq = (wg.astype(jnp.float32) * sg).astype(jnp.bfloat16)
        xg = jax.lax.dynamic_slice(xb, (0, g * group), (m, group))
        p = jax.lax.dot_general(
            xg, w_deq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return acc + p, None

    acc, _ = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32), jnp.arange(k // group))
    return acc.astype(jnp.bfloat16)
