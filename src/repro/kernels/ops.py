"""Public jit'd wrappers around the Pallas kernels.

Handles: leading batch dims, M-padding to the block size, interpret-mode
selection (automatic on CPU — the kernels TARGET TPU and are validated in
interpret mode per DESIGN.md), bias addition, and block-size heuristics.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.ref import TwinQuantWeights, pack_twinquant_weights  # re-export
from repro.kernels.twinquant_dual_gemm import dual_gemm
from repro.kernels.w4a16_gemm import w4a16_gemm

__all__ = [
    "TwinQuantWeights",
    "pack_twinquant_weights",
    "twinquant_matmul",
    "w4a16_matmul",
    "default_interpret",
    "pick_blocks",
]


def default_interpret() -> bool:
    return jax.default_backend() == "cpu"


def pick_blocks(m: int, n: int, k: int, group: int):
    """Block-size heuristic: MXU-aligned, VMEM-bounded, shape-capped."""
    bm = min(128, _round_up_pow2(m))
    bn = 256 if n % 256 == 0 else (128 if n % 128 == 0 else n)
    bk = 512 if k % 512 == 0 else (256 if k % 256 == 0 else (128 if k % 128 == 0 else k))
    bk = max(bk, group)
    return bm, bn, bk


def _round_up_pow2(x: int) -> int:
    p = 8
    while p < x and p < 128:
        p *= 2
    return p


def _flatten_pad(x: jax.Array, bm: int):
    """(..., K) -> padded (M', K); returns (x2d, batch_shape, m)."""
    batch_shape = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    pad = (-m) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, batch_shape, m


@functools.partial(jax.jit, static_argnames=("interpret", "block_m", "block_n", "block_k", "use_ref"))
def twinquant_matmul(
    x: jax.Array,
    w: TwinQuantWeights,
    bias: Optional[jax.Array] = None,
    *,
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    use_ref: bool = False,
) -> jax.Array:
    """y = TwinQuant(x) for x of shape (..., K); returns (..., N) bf16.

    ``use_ref=True`` routes through the pure-jnp oracle — the production
    fallback for shapes the kernel doesn't tile (and for CPU speed in smoke
    tests; interpret-mode Pallas is exact but slow).
    """
    if interpret is None:
        interpret = default_interpret()
    k = x.shape[-1]
    n = w.ndim_out
    if use_ref:
        x2, batch_shape, m = _flatten_pad(x, 1)
        y = _ref.dual_gemm_ref(x2, w)
    else:
        bm, bn, bk = pick_blocks(x.size // k, n, k, w.group)
        bm = block_m or bm
        bn = block_n or bn
        bk = block_k or bk
        x2, batch_shape, m = _flatten_pad(x, bm)
        y = dual_gemm(x2, w, block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    y = y[:m].reshape(*batch_shape, n)
    if bias is not None:
        y = (y.astype(jnp.float32) + bias.astype(jnp.float32)).astype(y.dtype)
    return y


@functools.partial(jax.jit, static_argnames=("group", "interpret", "block_m", "block_n", "block_k", "use_ref"))
def w4a16_matmul(
    x: jax.Array,
    wp: jax.Array,
    ws: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    group: int = 128,
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    use_ref: bool = False,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    k = x.shape[-1]
    n = wp.shape[1]
    if use_ref:
        x2, batch_shape, m = _flatten_pad(x, 1)
        y = _ref.w4a16_gemm_ref(x2, wp, ws, group=group)
    else:
        bm, bn, bk = pick_blocks(x.size // k, n, k, group)
        bm = block_m or bm
        bn = block_n or bn
        bk = block_k or bk
        x2, batch_shape, m = _flatten_pad(x, bm)
        y = w4a16_gemm(
            x2, wp, ws, group=group, block_m=bm, block_n=bn, block_k=bk, interpret=interpret
        )
    y = y[:m].reshape(*batch_shape, n)
    if bias is not None:
        y = (y.astype(jnp.float32) + bias.astype(jnp.float32)).astype(y.dtype)
    return y
