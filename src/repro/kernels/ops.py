"""Public wrappers around the Pallas kernels (back-compat surface).

The routing brain lives in kernels/dispatch.py — :func:`twinquant_matmul`
and :func:`w4a16_matmul` are kept as the stable API used by the kernel tests
and examples, and delegate to the dispatch layer. Explicit block sizes force
the prefill-kernel schedule (the legacy behavior the block-sweep tests rely
on); ``use_ref=True`` forces the jnp oracle.

``pick_blocks`` survives as a fixed, non-asserting heuristic: it now returns
``None`` for untileable shapes (the old version fell back to ``bn = n`` —
VMEM blow-up for wide non-128-multiple N — and ``bk = max(bk, group)``,
which can violate ``k % block_k == 0``). Callers must treat ``None`` as
"route to the ref path".
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.autotune import heuristic_blocks
from repro.kernels.dispatch import default_interpret, quant_linear, w4a16_linear
from repro.kernels.ref import TwinQuantWeights, pack_twinquant_weights  # re-export

__all__ = [
    "TwinQuantWeights",
    "pack_twinquant_weights",
    "twinquant_matmul",
    "w4a16_matmul",
    "default_interpret",
    "pick_blocks",
]


def pick_blocks(m: int, n: int, k: int, group: int) -> Optional[tuple[int, int, int]]:
    """Deterministic block heuristic; ``None`` when the shape is untileable."""
    return heuristic_blocks("dual_prefill", m, n, k, group)


def twinquant_matmul(
    x: jax.Array,
    w: TwinQuantWeights,
    bias: Optional[jax.Array] = None,
    *,
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    use_ref: bool = False,
) -> jax.Array:
    """y = TwinQuant(x) for x of shape (..., K); returns (..., N) bf16.

    ``use_ref=True`` routes through the pure-jnp oracle — the production
    fallback for shapes the kernels don't tile (and for CPU speed in smoke
    tests; interpret-mode Pallas is exact but slow).
    """
    return quant_linear(
        x, w, bias,
        impl="ref" if use_ref else "auto",
        interpret=interpret,
        block_m=block_m, block_n=block_n, block_k=block_k,
    )


def w4a16_matmul(
    x: jax.Array,
    wp: jax.Array,
    ws: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    group: int = 128,
    interpret: Optional[bool] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    use_ref: bool = False,
) -> jax.Array:
    return w4a16_linear(
        x, wp, ws, bias,
        group=group,
        impl="ref" if use_ref else "auto",
        interpret=interpret,
        block_m=block_m, block_n=block_n, block_k=block_k,
    )
