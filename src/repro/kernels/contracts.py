"""Trace-time launch contracts for the quantized Pallas kernels.

Every fused-kernel launch in this repo depends on a web of structural
invariants — grid coverage (``m % block_m == 0``), BlockSpec divisibility
(``block_k % group``, ``rank % rgroup``), the group-split packing parity
(``group % 2``), and a VMEM footprint small enough for the resident-panel
schedules to actually pin their operands. Violating one used to surface as a
bare ``assert`` tuple, an opaque Mosaic lowering error, or (through the
dispatch layer) a silent ref-path fallback.

This module is the machine-checked version of those invariants:

* :func:`validate_dual_gemm` / :func:`validate_dual_gemv` /
  :func:`validate_dual_gemm_group` / :func:`validate_dual_gemv_group` /
  :func:`validate_w4a16` — grid-coverage + divisibility contracts shared by
  the kernel wrappers. They raise :class:`ContractError` (a ``ValueError``)
  with the violated relation, the offending values, and a hint — BEFORE
  ``pl.pallas_call`` hands the launch to Mosaic.
* :func:`vmem_footprint` / :func:`check_vmem` — a per-launch VMEM estimate
  computed from the kernel's BlockSpec block shapes and scratch shapes
  (streamed operands double-buffered, pinned/constant-index operands counted
  once), rejected with a per-buffer breakdown when it exceeds the budget.
* :func:`check_twinquant_pack` / :func:`check_twinquant_group_pack` /
  :func:`check_w4a16_pack` — shape/dtype consistency contracts on the packed
  weight containers, run at every ``kernels/dispatch.py`` entry so a
  malformed pack (field shapes that disagree with each other or with the
  activation) produces a diagnostic instead of garbage numerics or an
  indistinguishable ref fallback. Odd-but-internally-consistent shapes (N
  not 128-aligned, K not a group multiple) remain ROUTING decisions and are
  untouched here.

The checks run at trace time (all inputs are static shapes/ints), so under
``jax.jit`` they cost nothing on the execution path. The static analyzer
(``python -m repro.analysis``) accepts a ``validate_*`` call as the
divisibility guard for a wrapper's BlockSpec integer divisions.
"""

from __future__ import annotations

import math
import os
from typing import Optional, Sequence

import jax.numpy as jnp

__all__ = [
    "ContractError",
    "VMEM_BUDGET_BYTES",
    "check_paged_decode_args",
    "check_ragged_args",
    "check_twinquant_group_pack",
    "check_twinquant_pack",
    "check_vmem",
    "check_w4a16_pack",
    "divisible",
    "validate_dual_gemm",
    "validate_dual_gemm_group",
    "validate_dual_gemv",
    "validate_dual_gemv_group",
    "validate_paged_decode",
    "validate_ragged_attention",
    "validate_w4a16",
    "vmem_footprint",
]


class ContractError(ValueError):
    """A kernel-launch or weight-pack contract violation, caught at trace
    time with a readable message — never a Mosaic error or a silent
    fallback."""


def _budget_bytes() -> int:
    """Per-core VMEM budget (bytes). ~16 MiB on current TPU generations;
    override with ``REPRO_VMEM_BUDGET_BYTES`` for other parts or for forcing
    the contract in tests."""
    return int(os.environ.get("REPRO_VMEM_BUDGET_BYTES", 16 * 2**20))


# module-level snapshot for introspection; check_vmem re-reads the env so
# tests can tighten the budget without reloading the module
VMEM_BUDGET_BYTES = _budget_bytes()


def divisible(a: int, b: int, what: str, *, kind: str, hint: str = "") -> None:
    """Contract: ``a % b == 0``. The shared primitive behind every BlockSpec
    integer division (``k // 2``, ``block_k // G``, ``r // gr``, ...)."""
    if b <= 0:
        raise ContractError(
            f"[{kind}] {what}: divisor must be positive, got {b}"
            + (f"\n  hint: {hint}" if hint else "")
        )
    if a % b != 0:
        raise ContractError(
            f"[{kind}] {what}: {a} is not a multiple of {b} "
            f"(remainder {a % b})" + (f"\n  hint: {hint}" if hint else "")
        )


def positive(value: int, what: str, *, kind: str) -> None:
    if value <= 0:
        raise ContractError(f"[{kind}] {what} must be positive, got {value}")


# ---------------------------------------------------------------------------
# VMEM footprint estimation
# ---------------------------------------------------------------------------

_ROLE_COPIES = {
    # streamed operands and outputs are double-buffered by the Pallas
    # pipeline; pinned (constant-index) operands and scratch live once
    "streamed": 2,
    "out": 2,
    "pinned": 1,
    "scratch": 1,
}


def vmem_footprint(
    bufs: Sequence[tuple[str, tuple[int, ...], object, str]],
) -> tuple[int, dict[str, int]]:
    """Estimate a launch's VMEM working set from its block/scratch shapes.

    ``bufs`` is ``(name, block_shape, dtype, role)`` with role one of
    ``streamed`` / ``pinned`` / ``out`` / ``scratch``. Returns
    ``(total_bytes, {name: bytes})`` with the pipeline's double buffering
    applied to streamed operands and outputs.
    """
    breakdown: dict[str, int] = {}
    for name, shape, dtype, role in bufs:
        copies = _ROLE_COPIES[role]
        nbytes = int(math.prod(shape)) * jnp.dtype(dtype).itemsize * copies
        breakdown[name] = breakdown.get(name, 0) + nbytes
    return sum(breakdown.values()), breakdown


def check_vmem(
    kind: str,
    bufs: Sequence[tuple[str, tuple[int, ...], object, str]],
    budget: Optional[int] = None,
) -> int:
    """Reject an over-budget launch with a per-buffer breakdown BEFORE Mosaic
    produces its allocation error. Returns the estimated total bytes."""
    if budget is None:
        budget = _budget_bytes()
    total, breakdown = vmem_footprint(bufs)
    if total > budget:
        lines = [
            f"    {name:<12} {nbytes / 2**20:8.2f} MiB"
            for name, nbytes in sorted(
                breakdown.items(), key=lambda kv: -kv[1]
            )
        ]
        raise ContractError(
            f"[{kind}] estimated VMEM footprint {total / 2**20:.2f} MiB "
            f"exceeds the {budget / 2**20:.2f} MiB budget "
            "(streamed operands and outputs counted double-buffered):\n"
            + "\n".join(lines)
            + "\n  hint: shrink block_n/block_k (autotune the shape), or let "
            "the dispatch layer route this shape to the jnp oracle"
        )
    return total


# ---------------------------------------------------------------------------
# grid-coverage / divisibility contracts (one per kernel schedule)
# ---------------------------------------------------------------------------


def validate_dual_gemm(
    m: int, n: int, k: int, r: int, group: int, rgroup: int,
    block_m: int, block_n: int, block_k: int,
    *, kind: str = "dual_gemm", budget: Optional[int] = None,
) -> None:
    """Contract for the prefill-shaped dual-component GEMM launch."""
    for name, v in (("block_m", block_m), ("block_n", block_n), ("block_k", block_k)):
        positive(v, name, kind=kind)
    hint = "blocks must tile the padded operand exactly (grid coverage)"
    divisible(m, block_m, "M % block_m", kind=kind, hint=hint)
    divisible(n, block_n, "N % block_n", kind=kind, hint=hint)
    divisible(k, block_k, "K % block_k", kind=kind, hint=hint)
    divisible(block_k, group, "block_k % group", kind=kind,
              hint="every K block must hold whole scale groups")
    divisible(group, 2, "group % 2", kind=kind,
              hint="group-split nibble packing pairs rows inside a group")
    divisible(r, rgroup, "rank % rgroup", kind=kind,
              hint="H requantization tiles the rank axis by rgroup")
    divisible(rgroup, 2, "rgroup % 2", kind=kind,
              hint="V is group-split packed along the rank axis")
    check_vmem(kind, [
        ("x", (block_m, block_k), jnp.bfloat16, "streamed"),
        ("up", (k // 2, r), jnp.int8, "pinned"),
        ("us", (k // group, r), jnp.float32, "pinned"),
        ("vp", (r // 2, block_n), jnp.int8, "streamed"),
        ("vs", (r // rgroup, block_n), jnp.float32, "streamed"),
        ("rp", (block_k // 2, block_n), jnp.int8, "streamed"),
        ("rs", (block_k // group, block_n), jnp.float32, "streamed"),
        ("out", (block_m, block_n), jnp.bfloat16, "out"),
        ("xq_s", (block_m, k), jnp.int8, "scratch"),
        ("xs_s", (block_m, k // group), jnp.float32, "scratch"),
        ("h_s", (block_m, r), jnp.float32, "scratch"),
        ("hq_s", (block_m, r), jnp.int8, "scratch"),
        ("hs_s", (block_m, r // rgroup), jnp.float32, "scratch"),
        ("acc_s", (block_m, block_n), jnp.float32, "scratch"),
    ], budget=budget)


def validate_dual_gemv(
    m: int, n: int, k: int, r: int, group: int, rgroup: int, block_n: int,
    *, decode_m_max: int, kind: str = "dual_gemv", budget: Optional[int] = None,
) -> None:
    """Contract for the decode-shaped (resident-panel) dual GEMM launch."""
    positive(block_n, "block_n", kind=kind)
    if m > decode_m_max:
        raise ContractError(
            f"[{kind}] M={m} exceeds the decode panel bound "
            f"DECODE_M_MAX={decode_m_max}\n  hint: the dispatch layer routes "
            "larger M to the prefill schedule"
        )
    divisible(n, block_n, "N % block_n", kind=kind,
              hint="the 1-D grid streams whole (K, block_n) residual tiles")
    divisible(k, group, "K % group", kind=kind,
              hint="the panel is quantized one whole scale group at a time")
    divisible(group, 2, "group % 2", kind=kind,
              hint="group-split nibble packing pairs rows inside a group")
    divisible(r, rgroup, "rank % rgroup", kind=kind,
              hint="H requantization tiles the rank axis by rgroup")
    divisible(rgroup, 2, "rgroup % 2", kind=kind,
              hint="V is group-split packed along the rank axis")
    check_vmem(kind, [
        ("x", (m, k), jnp.bfloat16, "pinned"),
        ("up", (k // 2, r), jnp.int8, "pinned"),
        ("us", (k // group, r), jnp.float32, "pinned"),
        ("vp", (r // 2, n), jnp.int8, "pinned"),
        ("vs", (r // rgroup, n), jnp.float32, "pinned"),
        ("rp", (k // 2, block_n), jnp.int8, "streamed"),
        ("rs", (k // group, block_n), jnp.float32, "streamed"),
        ("out", (m, block_n), jnp.bfloat16, "out"),
        ("xq_s", (m, k), jnp.int8, "scratch"),
        ("xs_s", (m, k // group), jnp.float32, "scratch"),
        ("hq_s", (m, r), jnp.int8, "scratch"),
        ("hs_s", (m, r // rgroup), jnp.float32, "scratch"),
    ], budget=budget)


def _validate_segments(
    seg_n: Sequence[int], seg_r: Sequence[int], rgroups: Sequence[int],
    block_n: int, *, kind: str,
) -> None:
    if not (len(seg_n) == len(seg_r) == len(rgroups)):
        raise ContractError(
            f"[{kind}] segment tables disagree: {len(seg_n)} widths, "
            f"{len(seg_r)} ranks, {len(rgroups)} rank-groups"
        )
    for j, (nj, rj, gr) in enumerate(zip(seg_n, seg_r, rgroups)):
        divisible(nj, block_n, f"segment {j}: N_j % block_n", kind=kind,
                  hint="an N block must never straddle a segment boundary")
        divisible(rj, gr, f"segment {j}: rank_j % rgroup_j", kind=kind,
                  hint="each segment's H requantizes with its own rank groups")
        divisible(gr, 2, f"segment {j}: rgroup_j % 2", kind=kind,
                  hint="V is group-split packed along the rank axis")


def validate_dual_gemm_group(
    m: int, k: int, group: int,
    seg_n: Sequence[int], seg_r: Sequence[int], rgroups: Sequence[int],
    block_m: int, block_n: int, block_k: int,
    *, kind: str = "dual_gemm_group", budget: Optional[int] = None,
) -> None:
    """Contract for the prefill-shaped fused sibling-projection launch."""
    for name, v in (("block_m", block_m), ("block_n", block_n), ("block_k", block_k)):
        positive(v, name, kind=kind)
    hint = "blocks must tile the padded operand exactly (grid coverage)"
    divisible(m, block_m, "M % block_m", kind=kind, hint=hint)
    divisible(k, block_k, "K % block_k", kind=kind, hint=hint)
    divisible(block_k, group, "block_k % group", kind=kind,
              hint="every K block must hold whole scale groups")
    divisible(group, 2, "group % 2", kind=kind,
              hint="group-split nibble packing pairs rows inside a group")
    _validate_segments(seg_n, seg_r, rgroups, block_n, kind=kind)
    r_total = sum(seg_r)
    hs_cols = sum(rj // gr for rj, gr in zip(seg_r, rgroups))
    bufs = [
        ("x", (block_m, block_k), jnp.bfloat16, "streamed"),
        ("up", (k // 2, r_total), jnp.int8, "pinned"),
        ("us", (k // group, r_total), jnp.float32, "pinned"),
        ("rp", (block_k // 2, block_n), jnp.int8, "streamed"),
        ("rs", (block_k // group, block_n), jnp.float32, "streamed"),
        ("out", (block_m, block_n), jnp.bfloat16, "out"),
        ("xq_s", (block_m, k), jnp.int8, "scratch"),
        ("xs_s", (block_m, k // group), jnp.float32, "scratch"),
        ("h_s", (block_m, r_total), jnp.float32, "scratch"),
        ("hq_s", (block_m, r_total), jnp.int8, "scratch"),
        ("hs_s", (block_m, hs_cols), jnp.float32, "scratch"),
        ("acc_s", (block_m, block_n), jnp.float32, "scratch"),
    ]
    for j, (nj, rj, gr) in enumerate(zip(seg_n, seg_r, rgroups)):
        bufs.append((f"vp[{j}]", (rj // 2, nj), jnp.int8, "pinned"))
        bufs.append((f"vs[{j}]", (rj // gr, nj), jnp.float32, "pinned"))
    check_vmem(kind, bufs, budget=budget)


def validate_dual_gemv_group(
    m: int, k: int, group: int,
    seg_n: Sequence[int], seg_r: Sequence[int], rgroups: Sequence[int],
    block_n: int,
    *, decode_m_max: int, kind: str = "dual_gemv_group",
    budget: Optional[int] = None,
) -> None:
    """Contract for the decode-shaped fused sibling-projection launch."""
    positive(block_n, "block_n", kind=kind)
    if m > decode_m_max:
        raise ContractError(
            f"[{kind}] M={m} exceeds the decode panel bound "
            f"DECODE_M_MAX={decode_m_max}\n  hint: the dispatch layer routes "
            "larger M to the prefill schedule"
        )
    divisible(k, group, "K % group", kind=kind,
              hint="the panel is quantized one whole scale group at a time")
    divisible(group, 2, "group % 2", kind=kind,
              hint="group-split nibble packing pairs rows inside a group")
    _validate_segments(seg_n, seg_r, rgroups, block_n, kind=kind)
    r_total = sum(seg_r)
    hs_cols = sum(rj // gr for rj, gr in zip(seg_r, rgroups))
    bufs = [
        ("x", (m, k), jnp.bfloat16, "pinned"),
        ("up", (k // 2, r_total), jnp.int8, "pinned"),
        ("us", (k // group, r_total), jnp.float32, "pinned"),
        ("rp", (k // 2, block_n), jnp.int8, "streamed"),
        ("rs", (k // group, block_n), jnp.float32, "streamed"),
        ("out", (m, block_n), jnp.bfloat16, "out"),
        ("xq_s", (m, k), jnp.int8, "scratch"),
        ("xs_s", (m, k // group), jnp.float32, "scratch"),
        ("hq_s", (m, r_total), jnp.int8, "scratch"),
        ("hs_s", (m, hs_cols), jnp.float32, "scratch"),
    ]
    for j, (nj, rj, gr) in enumerate(zip(seg_n, seg_r, rgroups)):
        bufs.append((f"vp[{j}]", (rj // 2, nj), jnp.int8, "pinned"))
        bufs.append((f"vs[{j}]", (rj // gr, nj), jnp.float32, "pinned"))
    check_vmem(kind, bufs, budget=budget)


def validate_w4a16(
    m: int, n: int, k: int, group: int,
    block_m: int, block_n: int, block_k: int,
    *, kind: str = "w4a16_gemm", budget: Optional[int] = None,
) -> None:
    """Contract for the weight-only int4 GEMM launch."""
    for name, v in (("block_m", block_m), ("block_n", block_n), ("block_k", block_k)):
        positive(v, name, kind=kind)
    hint = "blocks must tile the padded operand exactly (grid coverage)"
    divisible(m, block_m, "M % block_m", kind=kind, hint=hint)
    divisible(n, block_n, "N % block_n", kind=kind, hint=hint)
    divisible(k, block_k, "K % block_k", kind=kind, hint=hint)
    divisible(block_k, group, "block_k % group", kind=kind,
              hint="every K block must hold whole scale groups")
    divisible(group, 2, "group % 2", kind=kind,
              hint="group-split nibble packing pairs rows inside a group")
    check_vmem(kind, [
        ("x", (block_m, block_k), jnp.bfloat16, "streamed"),
        ("wp", (block_k // 2, block_n), jnp.int8, "streamed"),
        ("ws", (block_k // group, block_n), jnp.float32, "streamed"),
        ("out", (block_m, block_n), jnp.bfloat16, "out"),
        ("acc_s", (block_m, block_n), jnp.float32, "scratch"),
    ], budget=budget)


def validate_ragged_attention(
    t: int, h: int, kvh: int, hd: int, b: int, maxp: int, page: int,
    *, kind: str = "ragged", budget: Optional[int] = None,
) -> None:
    """Contract for the ragged-attention launch (one mixed prefill/decode
    token batch of T rows attending paged KV pools through block tables).

    The schedule pins the whole (T, H*hd) query panel, the (T, KV*hd)
    in-batch K/V rows, the f32 online-softmax state, and the output in VMEM
    while streaming one (page, KV*hd) K/V page pair per grid step — so T
    (the engine token budget) is the knob that blows the budget, not the
    sequence length."""
    positive(t, "T (token batch rows)", kind=kind)
    positive(page, "page_size", kind=kind)
    positive(maxp, "max_pages (block-table width)", kind=kind)
    positive(b, "B (engine slots)", kind=kind)
    divisible(h, kvh, "n_heads % n_kv_heads", kind=kind,
              hint="GQA groups share each KV head across h//kvh query heads")
    check_vmem(kind, [
        ("q", (t, h * hd), jnp.bfloat16, "pinned"),
        ("k_page", (1, page, kvh * hd), jnp.bfloat16, "streamed"),
        ("v_page", (1, page, kvh * hd), jnp.bfloat16, "streamed"),
        ("k_tok", (t, kvh * hd), jnp.bfloat16, "pinned"),
        ("v_tok", (t, kvh * hd), jnp.bfloat16, "pinned"),
        ("meta", (5 * t,), jnp.int32, "pinned"),
        ("out", (t, h * hd), jnp.bfloat16, "out"),
        ("m_s", (t, h), jnp.float32, "scratch"),
        ("l_s", (t, h), jnp.float32, "scratch"),
        ("acc_s", (t, h * hd), jnp.float32, "scratch"),
    ], budget=budget)


def validate_paged_decode(
    b: int, sq: int, h: int, kvh: int, hd: int, maxp: int, page: int,
    *, decode_m_max: int = 8, kind: str = "paged_decode",
    budget: Optional[int] = None,
) -> None:
    """Contract for the paged decode-attention launch (B slots x sq draft
    rows attending paged KV pools through scalar-prefetched block tables,
    with the tail-page commit fused into the epilogue).

    The schedule pins the whole (B*sq, H*hd) query panel, the (B*sq, KV*hd)
    draft K/V rows, the f32 online-softmax state, and the output in VMEM
    while streaming one (page, KV*hd) K/V page pair per grid step (plus the
    tail pages in the commit epilogue) — so B*sq is the knob that blows the
    budget, never the sequence length. ``sq`` is additionally bounded by the
    decode panel regime (speculative verification stacks at most
    DECODE_M_MAX rows per slot, matching the dual-GEMV routing bound)."""
    positive(b, "B (engine slots)", kind=kind)
    positive(sq, "sq (draft rows per slot)", kind=kind)
    positive(page, "page_size", kind=kind)
    positive(maxp, "max_pages (block-table width)", kind=kind)
    if sq > decode_m_max:
        raise ContractError(
            f"[{kind}] sq={sq} draft rows exceed the decode panel bound "
            f"DECODE_M_MAX={decode_m_max}\n  hint: the speculative engine "
            "verifies at most DECODE_M_MAX tokens per slot per launch"
        )
    divisible(h, kvh, "n_heads % n_kv_heads", kind=kind,
              hint="GQA groups share each KV head across h//kvh query heads")
    t2 = b * sq
    check_vmem(kind, [
        ("q", (t2, h * hd), jnp.bfloat16, "pinned"),
        ("k_page", (1, page, kvh * hd), jnp.bfloat16, "streamed"),
        ("v_page", (1, page, kvh * hd), jnp.bfloat16, "streamed"),
        ("k_tok", (t2, kvh * hd), jnp.bfloat16, "pinned"),
        ("v_tok", (t2, kvh * hd), jnp.bfloat16, "pinned"),
        ("k_slot", (sq, kvh * hd), jnp.bfloat16, "streamed"),
        ("v_slot", (sq, kvh * hd), jnp.bfloat16, "streamed"),
        ("meta", (t2,), jnp.int32, "pinned"),
        ("out", (t2, h * hd), jnp.bfloat16, "out"),
        ("k_tail", (1, page, kvh * hd), jnp.bfloat16, "out"),
        ("v_tail", (1, page, kvh * hd), jnp.bfloat16, "out"),
        ("m_s", (t2, h), jnp.float32, "scratch"),
        ("l_s", (t2, h), jnp.float32, "scratch"),
        ("acc_s", (t2, h * hd), jnp.float32, "scratch"),
    ], budget=budget)


def check_paged_decode_args(q, kp, vp, kt, vt, bt, pos,
                            *, kind: str = "paged_decode") -> None:
    """Shape/dtype consistency contract for a paged-decode call.

    ``q (B, sq, H, hd)`` / ``kt, vt (B, sq, KV, hd)`` are the draft rows,
    ``kp, vp (P, page, KV, hd)`` the paged pools of ONE layer, ``bt (B,
    maxp)`` the block tables and ``pos (B,)`` the committed prefix lengths.
    Malformed combinations raise before any routing decision is made."""
    problems = []
    if q.ndim != 4:
        problems.append(f"q: expected (B, sq, H, hd), got {tuple(q.shape)}")
    if kt.ndim != 4 or vt.ndim != 4 or kt.shape != vt.shape:
        problems.append(
            f"kt/vt: expected matching (B, sq, KV, hd), got {tuple(kt.shape)} "
            f"vs {tuple(vt.shape)}"
        )
    if kp.ndim != 4 or vp.ndim != 4 or kp.shape != vp.shape:
        problems.append(
            f"kp/vp: expected matching (P, page, KV, hd) pools, got "
            f"{tuple(kp.shape)} vs {tuple(vp.shape)}"
        )
    if bt.ndim != 2:
        problems.append(f"bt: expected (B, max_pages), got {tuple(bt.shape)}")
    if problems:
        raise ContractError(
            f"[{kind}] malformed paged-decode call:\n  " + "\n  ".join(problems)
        )
    b, sq, _, hd = q.shape
    if kt.shape[0] != b or kt.shape[1] != sq or kt.shape[3] != hd:
        problems.append(
            f"kt shape {tuple(kt.shape)} disagrees with q {tuple(q.shape)}"
        )
    if kp.shape[2] != kt.shape[2] or kp.shape[3] != hd:
        problems.append(
            f"pool trailing dims {tuple(kp.shape[2:])} != draft (KV, hd)="
            f"({kt.shape[2]}, {hd})"
        )
    if q.shape[2] % kt.shape[2] != 0:
        problems.append(
            f"n_heads {q.shape[2]} not a multiple of n_kv_heads {kt.shape[2]}"
        )
    if bt.shape[0] != b:
        problems.append(
            f"bt rows {bt.shape[0]} != B={b} slots"
        )
    if pos.shape != (b,):
        problems.append(
            f"pos: expected ({b},), got {tuple(pos.shape)}"
        )
    if problems:
        raise ContractError(
            f"[{kind}] malformed paged-decode call:\n  " + "\n  ".join(problems)
        )


def check_ragged_args(q, kp, vp, kt, vt, bt, slot, pos, ctx,
                      *, kind: str = "ragged") -> None:
    """Shape/dtype consistency contract for a ragged-attention call.

    ``q (T, H, hd)`` / ``kt, vt (T, KV, hd)`` are the current step's rows,
    ``kp, vp (P, page, KV, hd)`` the paged pools of ONE layer, ``bt (B,
    maxp)`` the block tables and ``slot/pos (T,)`` / ``ctx (B,)`` the ragged
    row metadata (slot == B marks a pad row). Malformed combinations raise
    before any routing decision is made."""
    problems = []
    if q.ndim != 3:
        problems.append(f"q: expected (T, H, hd), got {tuple(q.shape)}")
    if kt.ndim != 3 or vt.ndim != 3 or kt.shape != vt.shape:
        problems.append(
            f"kt/vt: expected matching (T, KV, hd), got {tuple(kt.shape)} "
            f"vs {tuple(vt.shape)}"
        )
    if kp.ndim != 4 or vp.ndim != 4 or kp.shape != vp.shape:
        problems.append(
            f"kp/vp: expected matching (P, page, KV, hd) pools, got "
            f"{tuple(kp.shape)} vs {tuple(vp.shape)}"
        )
    if bt.ndim != 2:
        problems.append(f"bt: expected (B, max_pages), got {tuple(bt.shape)}")
    if problems:
        raise ContractError(f"[{kind}] malformed ragged call:\n  " + "\n  ".join(problems))
    t, _, hd = q.shape
    if kt.shape[0] != t or kt.shape[2] != hd:
        problems.append(
            f"kt rows/head_dim {tuple(kt.shape)} disagree with q {tuple(q.shape)}"
        )
    if kp.shape[2] != kt.shape[1] or kp.shape[3] != hd:
        problems.append(
            f"pool trailing dims {tuple(kp.shape[2:])} != in-batch (KV, hd)="
            f"({kt.shape[1]}, {hd})"
        )
    if q.shape[1] % kt.shape[1] != 0:
        problems.append(
            f"n_heads {q.shape[1]} not a multiple of n_kv_heads {kt.shape[1]}"
        )
    if slot.shape != (t,) or pos.shape != (t,):
        problems.append(
            f"slot/pos: expected ({t},), got {tuple(slot.shape)} / {tuple(pos.shape)}"
        )
    if ctx.shape != (bt.shape[0],):
        problems.append(
            f"ctx: expected ({bt.shape[0]},) to match bt rows, got {tuple(ctx.shape)}"
        )
    if problems:
        raise ContractError(f"[{kind}] malformed ragged call:\n  " + "\n  ".join(problems))


# ---------------------------------------------------------------------------
# weight-pack consistency contracts (dispatch entries)
# ---------------------------------------------------------------------------


def _is_int8(a) -> bool:
    return jnp.dtype(a.dtype) == jnp.dtype(jnp.int8)


def _is_float(a) -> bool:
    return jnp.issubdtype(jnp.dtype(a.dtype), jnp.floating)


def check_twinquant_pack(w, k: int, *, kind: str = "dual") -> None:
    """Internal-consistency contract for a :class:`TwinQuantWeights` pack.

    Rejects packs whose field shapes/dtypes disagree with each other or with
    the activation's K — the malformations that previously produced garbage
    numerics or an unexplained ref fallback. Odd-but-consistent shapes (N
    not 128-aligned, K not a group multiple) are ROUTING decisions and pass.
    """
    problems = []
    for name, a, want_int8 in (
        ("up", w.up, True), ("us", w.us, False), ("vp", w.vp, True),
        ("vs", w.vs, False), ("rp", w.rp, True), ("rs", w.rs, False),
    ):
        if a.ndim != 2:
            problems.append(f"{name}: expected a 2-D pack field, got shape {a.shape}")
        if want_int8 and not _is_int8(a):
            problems.append(f"{name}: expected packed int8 nibbles, got {a.dtype}")
        if not want_int8 and not _is_float(a):
            problems.append(f"{name}: expected float scales, got {a.dtype}")
    if problems:
        raise ContractError(f"[{kind}] malformed pack:\n  " + "\n  ".join(problems))
    r, n = w.up.shape[-1], w.rp.shape[-1]
    if w.up.shape[-2] * 2 != k:
        problems.append(
            f"up rows {w.up.shape[-2]} pack K={w.up.shape[-2] * 2}, but the "
            f"activation has K={k}"
        )
    if w.rp.shape[-2] * 2 != k:
        problems.append(
            f"rp rows {w.rp.shape[-2]} pack K={w.rp.shape[-2] * 2}, but the "
            f"activation has K={k}"
        )
    if w.us.shape[-2] * w.group != k:
        problems.append(
            f"us has {w.us.shape[-2]} scale rows for group={w.group}, "
            f"covering K={w.us.shape[-2] * w.group} != {k}"
        )
    if w.us.shape[-1] != r:
        problems.append(f"us width {w.us.shape[-1]} != rank {r}")
    if w.vp.shape[-2] * 2 != r:
        problems.append(
            f"vp rows {w.vp.shape[-2]} pack rank={w.vp.shape[-2] * 2} != {r}"
        )
    if w.vs.shape[-2] * w.rgroup != r:
        problems.append(
            f"vs has {w.vs.shape[-2]} scale rows for rgroup={w.rgroup}, "
            f"covering rank={w.vs.shape[-2] * w.rgroup} != {r}"
        )
    if w.vp.shape[-1] != n or w.vs.shape[-1] != n:
        problems.append(
            f"V width ({w.vp.shape[-1]}, {w.vs.shape[-1]}) != output N={n}"
        )
    if w.rs.shape[-2] * w.group != k or w.rs.shape[-1] != n:
        problems.append(
            f"rs shape {tuple(w.rs.shape)} inconsistent with "
            f"(K/group, N)=({k}/{w.group}, {n})"
        )
    if problems:
        raise ContractError(
            f"[{kind}] malformed pack (K={k}, N={n}, rank={r}, "
            f"group={w.group}, rgroup={w.rgroup}):\n  " + "\n  ".join(problems)
        )


def check_twinquant_group_pack(gw, k: int, *, kind: str = "dual_fused") -> None:
    """Consistency contract for a fused :class:`TwinQuantGroupWeights` pack:
    stacked U/R fields must agree with the per-segment V geometry."""
    problems = []
    if len(gw.vps) != len(gw.vss) or len(gw.vps) != len(gw.rgroups):
        problems.append(
            f"segment tables disagree: {len(gw.vps)} vp, {len(gw.vss)} vs, "
            f"{len(gw.rgroups)} rgroups"
        )
        raise ContractError(f"[{kind}] malformed fused pack:\n  " + "\n  ".join(problems))
    if gw.up.shape[-2] * 2 != k or gw.rp.shape[-2] * 2 != k:
        problems.append(
            f"packed K ({gw.up.shape[-2] * 2} in up, {gw.rp.shape[-2] * 2} in "
            f"rp) != activation K={k}"
        )
    if gw.us.shape[-2] * gw.group != k:
        problems.append(
            f"us has {gw.us.shape[-2]} scale rows for group={gw.group}, "
            f"covering K={gw.us.shape[-2] * gw.group} != {k}"
        )
    if gw.up.shape[-1] != sum(gw.seg_r):
        problems.append(
            f"stacked U rank {gw.up.shape[-1]} != sum of segment ranks "
            f"{sum(gw.seg_r)}"
        )
    if gw.rp.shape[-1] != sum(gw.seg_n):
        problems.append(
            f"concatenated R width {gw.rp.shape[-1]} != sum of segment widths "
            f"{sum(gw.seg_n)}"
        )
    for j, (vp, vs, gr) in enumerate(zip(gw.vps, gw.vss, gw.rgroups)):
        if vp.shape[-1] != vs.shape[-1]:
            problems.append(
                f"segment {j}: vp width {vp.shape[-1]} != vs width {vs.shape[-1]}"
            )
        if vs.shape[-2] * gr != vp.shape[-2] * 2:
            problems.append(
                f"segment {j}: vs rows {vs.shape[-2]} x rgroup {gr} != "
                f"rank {vp.shape[-2] * 2}"
            )
    if problems:
        raise ContractError(
            f"[{kind}] malformed fused pack (K={k}, segments N={gw.seg_n}, "
            f"r={gw.seg_r}):\n  " + "\n  ".join(problems)
        )


def check_w4a16_pack(wp, ws, k: int, group: int, *, kind: str = "w4a16") -> None:
    """Consistency contract for a weight-only (packed, scales) pair."""
    problems = []
    if wp.ndim != 2 or ws.ndim != 2:
        problems.append(f"expected 2-D (wp, ws), got {wp.shape}, {ws.shape}")
    elif not _is_int8(wp):
        problems.append(f"wp: expected packed int8 nibbles, got {wp.dtype}")
    elif not _is_float(ws):
        problems.append(f"ws: expected float scales, got {ws.dtype}")
    else:
        if wp.shape[-2] * 2 != k:
            problems.append(
                f"wp rows {wp.shape[-2]} pack K={wp.shape[-2] * 2}, but the "
                f"activation has K={k}"
            )
        if ws.shape[-2] * group != k:
            problems.append(
                f"ws has {ws.shape[-2]} scale rows for group={group}, "
                f"covering K={ws.shape[-2] * group} != {k}"
            )
        if wp.shape[-1] != ws.shape[-1]:
            problems.append(
                f"wp width {wp.shape[-1]} != ws width {ws.shape[-1]}"
            )
    if problems:
        raise ContractError(
            f"[{kind}] malformed w4a16 pack (K={k}, group={group}):\n  "
            + "\n  ".join(problems)
        )
