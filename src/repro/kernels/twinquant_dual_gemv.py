"""Decode-shaped fused dual-component GEMM — the M=B<=8 regime of §4.3.

The prefill kernel (twinquant_dual_gemm.py) is scheduled for M>=128 panels:
it sweeps N blocks while re-reading the quantized activation panel from a
VMEM scratch and pays a (M/bm, N/bn, K/bk) grid's worth of index arithmetic.
In the serving engine's decode steps M is the slot count (1..8), so that
schedule wastes almost the entire MXU tile on padding and re-walks K once
per N block for the low-rank path bookkeeping.

This kernel is the decode-matched schedule:

* the whole activation panel ``X (m<=8, K)`` is **resident in VMEM** for the
  kernel's lifetime (constant-index BlockSpec) — quantized exactly once, at
  the first grid step, into int8 scratch; no N-sweep requantization logic;
* **both low-rank factors are pinned whole in VMEM** (``U``: K*r/2 bytes,
  ``V``: r*N/2 bytes — a few hundred KB at LLaMA3-8B shapes), so the
  low-rank intermediate ``H = requant(dq(Xq @ Uq))`` is computed and
  requantized once, at the first grid step, and every N block only pays the
  tiny (m, r) x (r, bn) second GEMM in its epilogue;
* the grid is **one-dimensional over N** (``(N/bn,)``): each step streams a
  whole-K ``(K/2, bn)`` packed residual tile — the only HBM traffic that
  scales with N — computes the residual component with a fori_loop over
  scale groups (bounded unroll), adds the low-rank epilogue, and writes the
  (m, bn) output tile once.

Numerics are identical to kernels/ref.dual_gemm_ref: same group structure,
same rounding, same ascending-group f32 accumulation order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import qmax_for_bits
from repro.kernels.autotune import DECODE_M_MAX
from repro.kernels.contracts import validate_dual_gemv, validate_dual_gemv_group
from repro.kernels.ref import TwinQuantGroupWeights, TwinQuantWeights

# jax renamed TPUCompilerParams -> CompilerParams; support both vintages
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["dual_gemv", "dual_gemv_group", "DECODE_M_MAX"]


def _unpack_rows(p: jax.Array) -> jax.Array:
    """(G/2, w) packed int8 -> (G, w) int8 (group-split layout)."""
    p32 = p.astype(jnp.int32)
    lo = jnp.right_shift(jnp.left_shift(p32, 28), 28)
    hi = jnp.right_shift(jnp.left_shift(p32, 24), 28)
    return jnp.concatenate([lo, hi], axis=0).astype(jnp.int8)


def _int8_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _dual_gemv_kernel(
    # inputs
    x_ref,  # (m, K)     bf16 — whole panel, resident
    up_ref,  # (K/2, r)  int8 packed — whole, resident
    us_ref,  # (K/G, r)  f32
    vp_ref,  # (r/2, N)  int8 packed — whole, resident
    vs_ref,  # (r/gr, N) f32
    rp_ref,  # (K/2, bn) int8 packed — streamed per N block
    rs_ref,  # (K/G, bn) f32
    # output
    o_ref,  # (m, bn)    bf16
    # scratch (persist across the sequential N grid)
    xq_s,  # (m, K)      int8 — quantized activation panel
    xs_s,  # (m, K/G)    f32  — its per-group scales
    hq_s,  # (m, r)      int8 — requantized low-rank intermediate
    hs_s,  # (m, r/gr)   f32  — its scales
    *,
    bn: int,
    G: int,
    gr: int,
    r: int,
    a_bits: int,
    n_groups: int,
):
    ni = pl.program_id(0)
    a_qmax = qmax_for_bits(a_bits)
    m = xq_s.shape[0]

    # ---- first grid step only: quantize the whole X panel and build H.
    # No per-N-block requantization state machine — X and U are resident, so
    # one ascending fori_loop over scale groups does the entire low-rank
    # front half of the dual GEMM.
    @pl.when(ni == 0)
    def _quantize_panel_and_h():
        def body(g, h):
            xg = x_ref[:, pl.ds(g * G, G)].astype(jnp.float32)  # (m, G)
            amax = jnp.max(jnp.abs(xg), axis=1, keepdims=True)  # (m, 1)
            scale = jnp.where(amax > 0, amax / a_qmax, 1.0)
            q = jnp.clip(jnp.round(xg / scale), -a_qmax, a_qmax).astype(jnp.int8)
            xq_s[:, pl.ds(g * G, G)] = q
            xs_s[:, pl.ds(g, 1)] = scale
            ug = _unpack_rows(up_ref[pl.ds(g * (G // 2), G // 2), :])  # (G, r)
            us = us_ref[pl.ds(g, 1), :]  # (1, r)
            return h + _int8_dot(q, ug).astype(jnp.float32) * scale * us

        h = jax.lax.fori_loop(0, n_groups, body, jnp.zeros((m, r), jnp.float32))
        for gg in range(r // gr):  # requantize H at a_bits (r/gr is 1-2)
            hg = h[:, gg * gr : (gg + 1) * gr]
            amax = jnp.max(jnp.abs(hg), axis=1, keepdims=True)
            scale = jnp.where(amax > 0, amax / a_qmax, 1.0)
            hq_s[:, gg * gr : (gg + 1) * gr] = jnp.clip(
                jnp.round(hg / scale), -a_qmax, a_qmax
            ).astype(jnp.int8)
            hs_s[:, gg : gg + 1] = scale

    # ---- every grid step: whole-K residual component for this N block
    def resid(g, acc):
        xg = xq_s[:, pl.ds(g * G, G)]  # (m, G) int8
        sg = xs_s[:, pl.ds(g, 1)]  # (m, 1)
        rg = _unpack_rows(rp_ref[pl.ds(g * (G // 2), G // 2), :])  # (G, bn)
        rs = rs_ref[pl.ds(g, 1), :]  # (1, bn)
        return acc + _int8_dot(xg, rg).astype(jnp.float32) * sg * rs

    out = jax.lax.fori_loop(0, n_groups, resid, jnp.zeros((m, bn), jnp.float32))

    # ---- epilogue: second low-rank GEMM from the resident V + one write-back
    for gg in range(r // gr):
        hqg = hq_s[:, gg * gr : (gg + 1) * gr]  # (m, gr)
        vg = _unpack_rows(vp_ref[gg * (gr // 2) : (gg + 1) * (gr // 2), pl.ds(ni * bn, bn)])
        pv = _int8_dot(hqg, vg).astype(jnp.float32)
        out = out + pv * hs_s[:, gg : gg + 1] * vs_ref[gg : gg + 1, pl.ds(ni * bn, bn)]
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dual_gemv(
    x: jax.Array,
    w: TwinQuantWeights,
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Decode-shaped fused dual-component matmul. x: (M<=8, K) -> (M, N) bf16.

    N must be a multiple of ``block_n`` and K a multiple of ``w.group``; the
    dispatch layer routes anything else to the jnp oracle.
    """
    m, k = x.shape
    n = w.ndim_out
    r = w.rank
    G, gr = w.group, w.rgroup
    # divisibility + resident-panel VMEM contracts (raise ContractError with
    # the violated relation before Mosaic sees the launch)
    validate_dual_gemv(m, n, k, r, G, gr, block_n, decode_m_max=DECODE_M_MAX)

    kernel = functools.partial(
        _dual_gemv_kernel,
        bn=block_n, G=G, gr=gr, r=r, a_bits=w.a_bits, n_groups=k // G,
    )

    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            # resident operands: constant index maps, fetched exactly once
            pl.BlockSpec((m, k), lambda ni: (0, 0)),
            pl.BlockSpec((k // 2, r), lambda ni: (0, 0)),
            pl.BlockSpec((k // G, r), lambda ni: (0, 0)),
            pl.BlockSpec((r // 2, n), lambda ni: (0, 0)),
            pl.BlockSpec((r // gr, n), lambda ni: (0, 0)),
            # streamed residual tile: whole K, one N block per grid step
            pl.BlockSpec((k // 2, block_n), lambda ni: (0, ni)),
            pl.BlockSpec((k // G, block_n), lambda ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda ni: (0, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.bfloat16),
        scratch_shapes=[
            pltpu.VMEM((m, k), jnp.int8),
            pltpu.VMEM((m, k // G), jnp.float32),
            pltpu.VMEM((m, r), jnp.int8),
            pltpu.VMEM((m, r // gr), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            # sequential N sweep: scratch (Xq, H) persists across grid steps
            dimension_semantics=(pltpu.ARBITRARY,),
        ),
        interpret=interpret,
    )(x, w.up, w.us, w.vp, w.vs, w.rp, w.rs)


# ---------------------------------------------------------------------------
# fused projection group (q/k/v, gate/up): one launch for all sibling outputs
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dual_gemv_group(
    x: jax.Array,
    gw: TwinQuantGroupWeights,
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Decode-shaped fused dual GEMM over a sibling-projection group.

    x: (M<=8, K) -> (M, sum N_j) bf16. One launch computes every segment of
    the group: the X panel is quantized ONCE (instead of once per sibling),
    H = requant(dq(Xq @ [U_0|U_1|...])) is built once over the stacked rank
    axis, and the 1-D grid streams the concatenated residual. Each N block
    belongs to exactly one segment (``block_n`` divides every ``N_j``); its
    epilogue consumes only that segment's H columns against that segment's
    resident V — the block-diagonal-V contraction without materialized
    zeros — so every output segment is bit-exact vs the unfused kernel.

    ``block_n`` must divide every segment's N and K must be a multiple of
    ``gw.group``; the dispatch layer routes anything else to the oracle.
    """
    m, k = x.shape
    G = gw.group
    seg_n, seg_r, grs = gw.seg_n, gw.seg_r, gw.rgroups
    n_segs = len(seg_n)
    r_total = gw.rank
    # divisibility + resident-panel VMEM contracts (per-segment checks
    # included: block_n must never straddle a segment boundary)
    validate_dual_gemv_group(m, k, G, seg_n, seg_r, grs, block_n, decode_m_max=DECODE_M_MAX)
    n_groups = k // G
    bn = block_n
    # static segment tables: N-block ownership, rank offsets, H-scale offsets
    nblk_off = tuple(no // bn for no in gw.n_offsets)
    nblk_end = tuple((no + nj) // bn for no, nj in zip(gw.n_offsets, seg_n))
    r_off = gw.r_offsets
    hs_off, hs_cols = [], 0
    for rj, gr in zip(seg_r, grs):
        hs_off.append(hs_cols)
        hs_cols += rj // gr
    hs_off = tuple(hs_off)
    a_bits = gw.a_bits

    def kernel(*args):
        x_ref, up_ref, us_ref = args[:3]
        vrefs = args[3 : 3 + 2 * n_segs]
        rp_ref, rs_ref, o_ref = args[3 + 2 * n_segs : 6 + 2 * n_segs]
        xq_s, xs_s, hq_s, hs_s = args[6 + 2 * n_segs :]
        ni = pl.program_id(0)
        a_qmax = qmax_for_bits(a_bits)

        # ---- first grid step: quantize the X panel once, build the stacked
        # H = dq(Xq @ [U_0|U_1|...]), requantize each segment's H columns with
        # that segment's OWN rank-group structure (static offsets/sizes)
        @pl.when(ni == 0)
        def _quantize_panel_and_h():
            def body(g, h):
                xg = x_ref[:, pl.ds(g * G, G)].astype(jnp.float32)  # (m, G)
                amax = jnp.max(jnp.abs(xg), axis=1, keepdims=True)
                scale = jnp.where(amax > 0, amax / a_qmax, 1.0)
                q = jnp.clip(jnp.round(xg / scale), -a_qmax, a_qmax).astype(jnp.int8)
                xq_s[:, pl.ds(g * G, G)] = q
                xs_s[:, pl.ds(g, 1)] = scale
                ug = _unpack_rows(up_ref[pl.ds(g * (G // 2), G // 2), :])
                us = us_ref[pl.ds(g, 1), :]
                return h + _int8_dot(q, ug).astype(jnp.float32) * scale * us

            h = jax.lax.fori_loop(0, n_groups, body, jnp.zeros((m, r_total), jnp.float32))
            for j in range(n_segs):
                gr = grs[j]
                for gg in range(seg_r[j] // gr):
                    base = r_off[j] + gg * gr
                    hg = h[:, base : base + gr]
                    amax = jnp.max(jnp.abs(hg), axis=1, keepdims=True)
                    scale = jnp.where(amax > 0, amax / a_qmax, 1.0)
                    hq_s[:, base : base + gr] = jnp.clip(
                        jnp.round(hg / scale), -a_qmax, a_qmax
                    ).astype(jnp.int8)
                    hs_s[:, hs_off[j] + gg : hs_off[j] + gg + 1] = scale

        # ---- every grid step: whole-K residual for this (concatenated) N block
        def resid(g, acc):
            xg = xq_s[:, pl.ds(g * G, G)]
            sg = xs_s[:, pl.ds(g, 1)]
            rg = _unpack_rows(rp_ref[pl.ds(g * (G // 2), G // 2), :])
            rs = rs_ref[pl.ds(g, 1), :]
            return acc + _int8_dot(xg, rg).astype(jnp.float32) * sg * rs

        out = jax.lax.fori_loop(0, n_groups, resid, jnp.zeros((m, bn), jnp.float32))

        # ---- epilogue: exactly one segment owns this N block; add its
        # low-rank contribution from its own H columns + resident V segment
        for j in range(n_segs):

            @pl.when((ni >= nblk_off[j]) & (ni < nblk_end[j]))
            def _seg_epilogue(j=j):
                vp_ref, vs_ref = vrefs[2 * j], vrefs[2 * j + 1]
                loc = (ni - nblk_off[j]) * bn  # column offset inside segment j
                gr = grs[j]
                acc = out
                for gg in range(seg_r[j] // gr):
                    hqg = hq_s[:, r_off[j] + gg * gr : r_off[j] + (gg + 1) * gr]
                    vg = _unpack_rows(
                        vp_ref[gg * (gr // 2) : (gg + 1) * (gr // 2), pl.ds(loc, bn)]
                    )
                    pv = _int8_dot(hqg, vg).astype(jnp.float32)
                    acc = acc + (
                        pv
                        * hs_s[:, hs_off[j] + gg : hs_off[j] + gg + 1]
                        * vs_ref[gg : gg + 1, pl.ds(loc, bn)]
                    )
                o_ref[...] = acc.astype(o_ref.dtype)

    n_total = gw.ndim_out
    in_specs = [
        # resident operands: constant index maps, fetched exactly once
        pl.BlockSpec((m, k), lambda ni: (0, 0)),
        pl.BlockSpec((k // 2, r_total), lambda ni: (0, 0)),
        pl.BlockSpec((k // G, r_total), lambda ni: (0, 0)),
    ]
    for vp, vs in zip(gw.vps, gw.vss):
        in_specs.append(pl.BlockSpec(vp.shape, lambda ni: (0, 0)))
        in_specs.append(pl.BlockSpec(vs.shape, lambda ni: (0, 0)))
    in_specs += [
        # streamed concatenated residual tile: whole K, one N block per step
        pl.BlockSpec((k // 2, bn), lambda ni: (0, ni)),
        pl.BlockSpec((k // G, bn), lambda ni: (0, ni)),
    ]
    operands = [x, gw.up, gw.us]
    for vp, vs in zip(gw.vps, gw.vss):
        operands += [vp, vs]
    operands += [gw.rp, gw.rs]

    return pl.pallas_call(
        kernel,
        grid=(n_total // bn,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, bn), lambda ni: (0, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n_total), jnp.bfloat16),
        scratch_shapes=[
            pltpu.VMEM((m, k), jnp.int8),
            pltpu.VMEM((m, k // G), jnp.float32),
            pltpu.VMEM((m, r_total), jnp.int8),
            pltpu.VMEM((m, hs_cols), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            # sequential N sweep: scratch (Xq, H) persists across grid steps
            dimension_semantics=(pltpu.ARBITRARY,),
        ),
        interpret=interpret,
    )(*operands)
