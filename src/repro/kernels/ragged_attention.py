"""Ragged paged attention — one launch for a mixed prefill/decode token batch.

The serving engine's unified step concatenates every live request's scheduled
tokens (prefill chunks + one decode token per slot) into a single flat batch
of ``T = token_budget`` rows. Each row ``t`` belongs to engine slot
``slot[t]`` (``slot == B`` marks padding), sits at absolute position
``pos[t]`` in that slot's timeline, and must attend

* the slot's **committed cache prefix** ``[0, ctx[slot[t]])`` — rows written
  by previous steps, living in the PR-4 page pools behind the slot's block
  table, and
* the **in-batch prefix**: rows ``u`` of the same batch with
  ``slot[u] == slot[t]`` and ``pos[u] <= pos[t]`` (causal within the row's
  span, including itself).

The kernel is an online-softmax (flash-attention recurrence) sweep over a
``(B, max_pages)`` grid. Every grid step ``(b, j)`` streams one K/V page
pair of slot ``b`` — the page index comes straight from the
scalar-prefetched block table via the BlockSpec index map, so unmapped (-1)
entries clamp to page 0 and are masked in-kernel. The LAST page step per
slot (``j == max_pages - 1``) additionally folds in the in-batch rows from
the resident ``(T, KV*hd)`` K/V panels — the in-batch tile rides the final
page iteration instead of spending a grid step of its own, so the sweep is
``B * max_pages`` steps, not ``B * (max_pages + 1)``. Rows not belonging to
the current slot are naturally inert: their masks are all-False, so ``m``
does not move, the correction factor is ``exp(0) = 1`` and their
probability mass is zero — the scratch state needs no explicit row gating.
Output is written once, at the last grid step.

Numerics: the jnp reference (``ragged_attention_ref``) mirrors each row's
bucketed-engine counterpart rounding-for-rounding — decode rows follow
``models/common.attention_decode_ro`` (cache and self value dots rounded to
bf16 separately), prefill-chunk rows follow ``_sdpa``'s single fused dot
(f32 partial accumulation, one final bf16 rounding). Single-chunk prompts
and decode steps are then bit-identical to the bucketed engine. A prompt
split across MULTIPLE chunks has exactly one f32 reassociation at each
chunk boundary (cache-sum + in-batch-sum vs the oracle's one sequential
sum); in practice greedy outputs stay token-identical (the serving tests
pin such workloads), but a ~1e-7-relative perturbation landing on a bf16
rounding boundary can in principle flip a near-tied argmax. The Pallas
kernel always accumulates fused-f32; agreement with the ref is tested to
bf16 tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.contracts import validate_ragged_attention

# jax renamed TPUCompilerParams -> CompilerParams; support both vintages
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["ragged_attention_kernel", "ragged_attention_ref"]

_NEG_INF = -1e30


def ragged_attention_ref(q, kp, vp, kt, vt, bt, slot, pos, ctx):
    """jnp oracle for the ragged step's attention.

    q (T, H, hd) / kt, vt (T, KV, hd): this step's post-RoPE rows.
    kp, vp (P, page, KV, hd): one layer's paged K/V pools.
    bt (B, maxp) int32 block tables, slot/pos (T,) int32 row metadata
    (slot == B is padding), ctx (B,) int32 committed rows per slot.
    Returns (T, H, hd) in vt.dtype; pad rows are garbage (caller discards).
    """
    t, h, hd = q.shape
    kv = kt.shape[1]
    g = h // kv
    b, maxp = bt.shape
    page = kp.shape[1]
    s_max = maxp * page
    slot_c = jnp.clip(slot, 0, b - 1)

    # dense per-row cache view through the block tables (unmapped -> page 0,
    # masked below by the ctx prefix — same contract as common.gather_pages)
    kc = kp[jnp.maximum(bt, 0)].reshape(b, s_max, kv, hd)[slot_c]  # (T, S, KV, hd)
    vc = vp[jnp.maximum(bt, 0)].reshape(b, s_max, kv, hd)[slot_c]

    qg = q.reshape(t, kv, g, hd)
    real = slot < b  # (T,)

    # committed-cache scores, mirroring attention_decode_ro: bf16 einsum,
    # cast f32, scale, strict prefix mask
    logits_c = jnp.einsum("tkgh,tskh->tkgs", qg, kc).astype(jnp.float32)
    logits_c = logits_c / (hd**0.5)
    mask_c = (jnp.arange(s_max)[None, :] < ctx[slot_c][:, None]) & real[:, None]
    logits_c = jnp.where(mask_c[:, None, None, :], logits_c, _NEG_INF)

    # in-batch scores: same-slot causal prefix (includes self)
    logits_b = jnp.einsum("tkgh,ukh->tkgu", qg, kt).astype(jnp.float32)
    logits_b = logits_b / (hd**0.5)
    mask_b = (slot[None, :] == slot[:, None]) & (pos[None, :] <= pos[:, None])
    mask_b = mask_b & real[:, None]
    logits_b = jnp.where(mask_b[:, None, None, :], logits_b, _NEG_INF)

    m = jnp.maximum(
        jnp.max(logits_c, axis=-1, keepdims=True),
        jnp.max(logits_b, axis=-1, keepdims=True),
    )
    pc = jnp.exp(logits_c - m)
    pb = jnp.exp(logits_b - m)
    den = jnp.sum(pc, axis=-1, keepdims=True) + jnp.sum(pb, axis=-1, keepdims=True)
    # value reduction, matching each row's BUCKETED-engine counterpart
    # rounding-for-rounding so greedy decoding stays token-identical:
    # * decode rows (exactly one in-batch term: themselves) mirror
    #   attention_decode_ro — cache and self dots rounded to bf16 separately,
    #   then added in bf16;
    # * prefill-chunk rows (>= 2 in-batch terms) mirror _sdpa's single fused
    #   dot — both partial dots accumulate in f32 and round ONCE, otherwise
    #   the extra bf16 rounding drifts a full ulp off the bucketed oracle.
    pcd = (pc / den).astype(vc.dtype)
    pbd = (pb / den).astype(vt.dtype)
    out_fused = jnp.einsum("tkgs,tskh->tkgh", pcd, vc,
                           preferred_element_type=jnp.float32)
    out_fused = out_fused + jnp.einsum("tkgu,ukh->tkgh", pbd, vt,
                                       preferred_element_type=jnp.float32)
    out_split = (jnp.einsum("tkgs,tskh->tkgh", pcd, vc)
                 + jnp.einsum("tkgu,ukh->tkgh", pbd, vt))
    decode_like = (jnp.sum(mask_b, axis=-1) <= 1)[:, None, None, None]
    out = jnp.where(decode_like, out_split.astype(jnp.float32), out_fused)
    return out.astype(vt.dtype).reshape(t, h, hd)


def _ragged_attention_fwd(
    # scalar prefetch
    bt_ref,  # (B, maxp) int32 — block tables, read by index maps + validity
    # inputs
    q_ref,  # (T, H*hd)  bf16 — whole panel, resident
    kp_ref,  # (1, page, KV*hd) bf16 — one K page, streamed via bt
    vp_ref,  # (1, page, KV*hd) bf16 — one V page, streamed via bt
    kt_ref,  # (T, KV*hd) bf16 — in-batch K rows, resident
    vt_ref,  # (T, KV*hd) bf16 — in-batch V rows, resident
    slot_c_ref,  # (T, 1) int32 — row -> slot (column layout)
    pos_c_ref,  # (T, 1) int32 — row -> absolute position
    ctx_c_ref,  # (T, 1) int32 — row -> committed prefix length
    slot_r_ref,  # (1, T) int32 — slot again, row layout (avoids transposes)
    pos_r_ref,  # (1, T) int32
    # output
    o_ref,  # (T, H*hd) bf16
    # scratch (persist across the sequential grid)
    m_s,  # (T, H) f32 — running max
    l_s,  # (T, H) f32 — running denominator
    acc_s,  # (T, H*hd) f32 — running numerator
    *,
    b_slots: int,
    maxp: int,
    page: int,
    g: int,
    hd: int,
    h_total: int,
    scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((b == 0) & (j == 0))
    def _init():
        m_s[...] = jnp.full(m_s.shape, _NEG_INF, jnp.float32)
        l_s[...] = jnp.zeros(l_s.shape, jnp.float32)
        acc_s[...] = jnp.zeros(acc_s.shape, jnp.float32)

    row_b = slot_c_ref[...] == b  # (T, 1): rows owned by the current slot

    def update(h_i, s, valid, vmat):
        # one online-softmax fold for head h_i: s (T, S') raw f32 scores,
        # valid (T, S') mask, vmat (S', hd) values
        m_old = m_s[:, h_i : h_i + 1]
        l_old = l_s[:, h_i : h_i + 1]
        a_old = acc_s[:, h_i * hd : (h_i + 1) * hd]
        s = jnp.where(valid, s, _NEG_INF)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_old - m_new)
        m_s[:, h_i : h_i + 1] = m_new
        l_s[:, h_i : h_i + 1] = l_old * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_s[:, h_i * hd : (h_i + 1) * hd] = a_old * corr + jax.lax.dot_general(
            p, vmat.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    # committed prefix: one page of slot b's cache per grid step (fetched
    # through the block table by the BlockSpec index map; -1 clamps to page
    # 0 and is masked here) — every (b, j) step is a page step
    page_ok = bt_ref[b, j] >= 0
    kv_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid_p = row_b & (kv_pos < ctx_c_ref[...]) & page_ok  # (T, page)
    for h_i in range(h_total):
        kv_i = h_i // g
        qh = q_ref[:, h_i * hd : (h_i + 1) * hd]  # (T, hd)
        kh = kp_ref[0][:, kv_i * hd : (kv_i + 1) * hd]  # (page, hd)
        s = jax.lax.dot_general(
            qh, kh, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale
        update(h_i, s, valid_p, vp_ref[0][:, kv_i * hd : (kv_i + 1) * hd])

    @pl.when(j == maxp - 1)
    def _in_batch():
        # this step's own rows: same-slot causal prefix, including self.
        # Folded into the slot's LAST page step — the in-batch tile costs no
        # extra grid iteration
        valid = row_b & (slot_r_ref[...] == b) & (pos_r_ref[...] <= pos_c_ref[...])
        for h_i in range(h_total):
            kv_i = h_i // g
            qh = q_ref[:, h_i * hd : (h_i + 1) * hd]
            kh = kt_ref[:, kv_i * hd : (kv_i + 1) * hd]  # (T, hd)
            s = jax.lax.dot_general(
                qh, kh, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * scale
            update(h_i, s, valid, vt_ref[:, kv_i * hd : (kv_i + 1) * hd])

    @pl.when((b == b_slots - 1) & (j == maxp - 1))
    def _finalize():
        # pad rows have l == 0 (never valid anywhere) -> guarded divide;
        # their garbage output is discarded host-side
        for h_i in range(h_total):
            l_h = jnp.maximum(l_s[:, h_i : h_i + 1], 1e-30)
            o_ref[:, h_i * hd : (h_i + 1) * hd] = (
                acc_s[:, h_i * hd : (h_i + 1) * hd] / l_h
            ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ragged_attention_kernel(q, kp, vp, kt, vt, bt, slot, pos, ctx, *,
                            interpret: bool = False):
    """Pallas launch wrapper; same signature/semantics as the ref."""
    t, h, hd = q.shape
    kv = kt.shape[1]
    g = h // kv
    b, maxp = bt.shape
    page = kp.shape[1]
    validate_ragged_attention(t, h, kv, hd, b, maxp, page)

    q2 = q.reshape(t, h * hd)
    kp2 = kp.reshape(kp.shape[0], page, kv * hd)
    vp2 = vp.reshape(vp.shape[0], page, kv * hd)
    kt2 = kt.reshape(t, kv * hd)
    vt2 = vt.reshape(t, kv * hd)
    slot_c = slot.astype(jnp.int32).reshape(t, 1)
    pos_c = pos.astype(jnp.int32).reshape(t, 1)
    ctx_c = jnp.take(ctx.astype(jnp.int32), jnp.clip(slot, 0, b - 1)).reshape(t, 1)
    slot_r = slot.astype(jnp.int32).reshape(1, t)
    pos_r = pos.astype(jnp.int32).reshape(1, t)

    kernel = functools.partial(
        _ragged_attention_fwd,
        b_slots=b, maxp=maxp, page=page, g=g, hd=hd, h_total=h,
        scale=hd**-0.5,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, maxp),
        in_specs=[
            pl.BlockSpec((t, h * hd), lambda bi, ji, bts: (0, 0)),
            # the page index comes from the scalar-prefetched block table:
            # unmapped (-1) entries clamp to page 0 (masked in-kernel); the
            # in-batch tile shares the last page step, so ji is always a
            # real page column
            pl.BlockSpec(
                (1, page, kv * hd),
                lambda bi, ji, bts: (
                    jnp.where(bts[bi, ji] < 0, 0, bts[bi, ji]), 0, 0
                ),
            ),
            pl.BlockSpec(
                (1, page, kv * hd),
                lambda bi, ji, bts: (
                    jnp.where(bts[bi, ji] < 0, 0, bts[bi, ji]), 0, 0
                ),
            ),
            pl.BlockSpec((t, kv * hd), lambda bi, ji, bts: (0, 0)),
            pl.BlockSpec((t, kv * hd), lambda bi, ji, bts: (0, 0)),
            pl.BlockSpec((t, 1), lambda bi, ji, bts: (0, 0)),
            pl.BlockSpec((t, 1), lambda bi, ji, bts: (0, 0)),
            pl.BlockSpec((t, 1), lambda bi, ji, bts: (0, 0)),
            pl.BlockSpec((1, t), lambda bi, ji, bts: (0, 0)),
            pl.BlockSpec((1, t), lambda bi, ji, bts: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, h * hd), lambda bi, ji, bts: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((t, h), jnp.float32),
            pltpu.VMEM((t, h), jnp.float32),
            pltpu.VMEM((t, h * hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h * hd), vt.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY, pltpu.ARBITRARY)
        ),
        interpret=interpret,
    )(bt.astype(jnp.int32), q2, kp2, vp2, kt2, vt2,
      slot_c, pos_c, ctx_c, slot_r, pos_r)
    return out.reshape(t, h, hd)
