"""Weight-only int4 GEMM (W4A16) — the AWQ/GPTQ-style baseline system.

Same packing/tiling conventions as the dual-component kernel, but activations
stay bf16: packed int4 weights are sign-extended and dequantized to bf16 in
VMEM, then dotted on the MXU with f32 accumulation. Serves as (a) the W4A16
baseline the paper compares against and (b) the fallback path for layers
whose shapes don't admit full W4A4 (e.g. tiny ranks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both vintages
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from repro.kernels.contracts import validate_w4a16

__all__ = ["w4a16_gemm"]


def _unpack_rows(p: jax.Array) -> jax.Array:
    p32 = p.astype(jnp.int32)
    lo = jnp.right_shift(jnp.left_shift(p32, 28), 28)
    hi = jnp.right_shift(jnp.left_shift(p32, 24), 28)
    return jnp.concatenate([lo, hi], axis=0).astype(jnp.int8)


def _w4a16_kernel(x_ref, wp_ref, ws_ref, o_ref, acc_s, *, bk: int, G: int, n_k: int):
    k = pl.program_id(2)
    gpb = bk // G

    @pl.when(k == 0)
    def _zero():
        acc_s[...] = jnp.zeros_like(acc_s)

    xb = x_ref[...].astype(jnp.bfloat16)
    for g in range(gpb):
        wg = _unpack_rows(wp_ref[g * (G // 2) : (g + 1) * (G // 2), :])  # (G, bn)
        sg = ws_ref[g : g + 1, :]  # (1, bn)
        w_deq = (wg.astype(jnp.float32) * sg).astype(jnp.bfloat16)
        xg = xb[:, g * G : (g + 1) * G]
        acc_s[...] += jax.lax.dot_general(
            xg, w_deq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = acc_s[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group", "block_m", "block_n", "block_k", "interpret"))
def w4a16_gemm(
    x: jax.Array,
    wp: jax.Array,
    ws: jax.Array,
    *,
    group: int = 128,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K) bf16; wp: (K/2, N) packed int4; ws: (K/G, N) f32 -> (M, N) bf16."""
    m, k = x.shape
    n = wp.shape[1]
    # grid-coverage/divisibility + VMEM-budget contracts (raise ContractError
    # with the violated relation before Mosaic sees the launch)
    validate_w4a16(m, n, k, group, block_m, block_n, block_k)
    n_k = k // block_k

    kernel = functools.partial(_w4a16_kernel, bk=block_k, G=group, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k // 2, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((block_k // group, block_n), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.ARBITRARY, pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(x, wp, ws)
