"""Persisted (block_m, block_n, block_k) autotuner for the quantized GEMMs.

Replaces the old ``ops.pick_blocks`` heuristic, which had two fallback bugs:
``bn = n`` for non-128-multiple N (a 13k-wide single block blows VMEM) and
``bk = max(bk, group)`` which can violate ``k % block_k == 0`` and trip the
kernel's tiling assert. Here the contract is explicit:

* :func:`heuristic_blocks` is the **deterministic fallback**: it returns a
  validated, MXU-aligned block triple, or ``None`` when the shape is not
  tileable at all — the dispatch layer routes ``None`` to the jnp oracle
  instead of asserting.
* :func:`autotune_blocks` is the **measured sweep**: it times every
  candidate triple for a (kind, M-regime, N, K, group, rank) key and
  persists the winner to ``artifacts/tune/<kind>.json`` via
  :class:`TuneCache`. Keys use the M *regime* (decode vs prefill), not the
  exact M, so one serving deployment warms the cache for every batch size
  in its regime.
* :func:`get_blocks` is what the dispatch layer calls on the hot path:
  cache hit -> tuned blocks; miss -> heuristic. Never measures implicitly.

Kinds: ``dual_prefill`` / ``dual_decode`` / ``w4a16`` for single packs, plus
``dual_prefill_fused`` / ``dual_decode_fused`` for horizontally fused
projection groups (q/k/v, gate/up). The fused kinds use the same schedules;
the dispatch layer passes ``n = gcd(segment widths)`` so every candidate
``block_n`` tiles every segment (N blocks never straddle a segment
boundary), and ``rank = sum(segment ranks)`` (the stacked-U rank axis).

Cache file format (schema 1)::

    {
      "schema": 1,
      "backend": "tpu",
      "entries": {
        "dual/prefill/n4096/k14336/g128/r128": {
          "blocks": [128, 256, 512],
          "best_us": 812.4,
          "candidates": 9
        }
      }
    }

The cache directory is ``artifacts/tune`` (override: ``REPRO_TUNE_DIR``).
"""

from __future__ import annotations

import json
import os
import time
import warnings
from pathlib import Path
from typing import Callable, Optional

import jax

__all__ = [
    "TuneCache",
    "autotune_blocks",
    "candidate_blocks",
    "cache_key",
    "default_cache",
    "get_blocks",
    "heuristic_blocks",
    "regime",
]

SCHEMA = 1

# Decode regime bound — kept in sync with twinquant_dual_gemv.DECODE_M_MAX
# (imported there from here to keep this module kernel-import-free).
DECODE_M_MAX = 8

_BN_CANDIDATES = (512, 256, 128)
_BK_CANDIDATES = (1024, 512, 256, 128)


def regime(m: int) -> str:
    """Shape regime of an M (flattened token-row count)."""
    return "decode" if m <= DECODE_M_MAX else "prefill"


def cache_key(kind: str, m: int, n: int, k: int, group: int, rank: int = 0) -> str:
    """Deterministic cache key: M enters only through its regime."""
    return f"{kind}/{regime(m)}/n{n}/k{k}/g{group}/r{rank}"


def _round_up_pow2(x: int) -> int:
    p = 8
    while p < x and p < 128:
        p *= 2
    return p


def heuristic_blocks(
    kind: str, m: int, n: int, k: int, group: int, rank: int = 0
) -> Optional[tuple[int, int, int]]:
    """Deterministic block triple for a tileable shape, else ``None``.

    Validity contract (matches the kernel asserts):
      * ``k % block_k == 0`` and ``block_k % group == 0``
      * ``n % block_n == 0`` with ``block_n`` MXU-lane aligned (128x)
      * dual kernels additionally need ``rank % rgroup == 0`` upstream —
        checked by the dispatch layer, not here.
    """
    if k <= 0 or n <= 0 or m <= 0:
        return None
    if k % group != 0 or group % 2 != 0:
        return None
    bn = next((c for c in _BN_CANDIDATES if n % c == 0), None)
    if bn is None:
        return None
    if kind in ("dual_decode", "dual_decode_fused"):
        # whole-K schedule: block_k is unused by the gemv grid but recorded
        # as K so cache entries stay self-describing. For the fused kind the
        # caller passes n = gcd over segment widths, so bn | every segment.
        return (DECODE_M_MAX, bn, k)
    bk = next((c for c in _BK_CANDIDATES if k % c == 0 and c % group == 0), None)
    if bk is None:
        bk = group if k % group == 0 else None
    if bk is None:
        return None
    bm = min(128, _round_up_pow2(m))
    return (bm, bn, bk)


def candidate_blocks(
    kind: str, m: int, n: int, k: int, group: int, rank: int = 0
) -> list[tuple[int, int, int]]:
    """All valid block triples for the measured sweep (deterministic order)."""
    base = heuristic_blocks(kind, m, n, k, group, rank)
    if base is None:
        return []
    if kind in ("dual_decode", "dual_decode_fused"):
        return [(DECODE_M_MAX, bn, k) for bn in _BN_CANDIDATES if n % bn == 0]
    bms = sorted({min(128, _round_up_pow2(m)), 128} | ({64} if m >= 64 else set()))
    bns = [c for c in _BN_CANDIDATES if n % c == 0]
    bks = [c for c in _BK_CANDIDATES if k % c == 0 and c % group == 0]
    if not bks and k % group == 0:
        bks = [group]
    return [(bm, bn, bk) for bm in bms for bn in bns for bk in bks]


class TuneCache:
    """One JSON file per kernel kind under the tune directory."""

    def __init__(self, directory: Optional[os.PathLike] = None):
        if directory is None:
            directory = os.environ.get("REPRO_TUNE_DIR", "artifacts/tune")
        self.dir = Path(directory)
        self._loaded: dict[str, dict] = {}

    def _path(self, kind: str) -> Path:
        return self.dir / f"{kind}.json"

    def _load(self, kind: str) -> dict:
        if kind not in self._loaded:
            p = self._path(kind)
            if p.exists():
                try:
                    doc = json.loads(p.read_text())
                except (OSError, json.JSONDecodeError) as e:
                    warnings.warn(
                        f"ignoring unreadable tune cache {p}: {e} "
                        "(falling back to heuristic blocks)",
                        stacklevel=3,
                    )
                    doc = {}
                if not isinstance(doc, dict):
                    warnings.warn(
                        f"ignoring tune cache {p}: expected a JSON object, "
                        f"got {type(doc).__name__} (falling back to heuristic blocks)",
                        stacklevel=3,
                    )
                    doc = {}
                elif doc and doc.get("schema") != SCHEMA:
                    warnings.warn(
                        f"ignoring tune cache {p}: schema "
                        f"{doc.get('schema')!r} != {SCHEMA} "
                        "(falling back to heuristic blocks)",
                        stacklevel=3,
                    )
                    doc = {}
            else:
                doc = {}
            doc.setdefault("schema", SCHEMA)
            doc.setdefault("backend", jax.default_backend())
            doc.setdefault("entries", {})
            self._loaded[kind] = doc
        return self._loaded[kind]

    def lookup(self, key: str) -> Optional[tuple[int, int, int]]:
        kind = key.split("/", 1)[0]
        entries = self._load(kind).get("entries")
        entry = entries.get(key) if isinstance(entries, dict) else None
        if not isinstance(entry, dict):
            return None
        blocks = entry.get("blocks")
        if not (isinstance(blocks, list) and len(blocks) == 3):
            return None
        try:
            return tuple(int(b) for b in blocks)
        except (TypeError, ValueError):
            # garbage values inside a well-shaped entry: treat as a miss (the
            # dispatch layer degrades to heuristic blocks, never crashes)
            warnings.warn(
                f"ignoring malformed tune-cache entry {key!r}: "
                f"blocks={blocks!r}",
                stacklevel=2,
            )
            return None

    def store(self, key: str, blocks: tuple[int, int, int], **meta) -> None:
        kind = key.split("/", 1)[0]
        doc = self._load(kind)
        doc["entries"][key] = {"blocks": [int(b) for b in blocks], **meta}
        self.dir.mkdir(parents=True, exist_ok=True)
        self._path(kind).write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")

    def clear(self) -> None:
        self._loaded = {}


_default_cache: Optional[TuneCache] = None


def default_cache() -> TuneCache:
    global _default_cache
    if _default_cache is None:
        _default_cache = TuneCache()
    return _default_cache


def blocks_valid(
    kind: str, blocks: tuple[int, int, int], n: int, k: int, group: int
) -> bool:
    """Do these blocks satisfy the kernel tiling asserts for (n, k, group)?"""
    bm, bn, bk = blocks
    if bm <= 0 or bn <= 0 or bk <= 0 or n % bn != 0:
        return False
    if kind in ("dual_decode", "dual_decode_fused"):
        return k % group == 0
    return k % bk == 0 and bk % group == 0


def get_blocks(
    kind: str,
    m: int,
    n: int,
    k: int,
    group: int,
    rank: int = 0,
    cache: Optional[TuneCache] = None,
) -> Optional[tuple[int, int, int]]:
    """Hot-path lookup: tuned blocks if persisted, else the heuristic.

    Cache hits are re-validated against the kernel tiling contract — a
    stale or foreign entry (tuned before a kernel change, hand-edited,
    copied from another deployment) must degrade to the heuristic, never
    resurrect the tiling asserts the dispatch layer exists to remove."""
    cache = cache or default_cache()
    hit = cache.lookup(cache_key(kind, m, n, k, group, rank))
    if hit is not None and blocks_valid(kind, hit, n, k, group):
        return hit
    return heuristic_blocks(kind, m, n, k, group, rank)


def _measure(call: Callable[[], jax.Array], iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(call())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def autotune_blocks(
    kind: str,
    make_call: Callable[[tuple[int, int, int]], Callable[[], jax.Array]],
    m: int,
    n: int,
    k: int,
    group: int,
    rank: int = 0,
    cache: Optional[TuneCache] = None,
    iters: int = 5,
) -> Optional[tuple[int, int, int]]:
    """Measured sweep over candidate blocks; persists and returns the winner.

    ``make_call(blocks)`` must return a zero-arg callable running the kernel
    at those blocks (the autotuner never constructs kernel arguments itself).
    Returns ``None`` for untileable shapes, without touching the cache.
    """
    cands = candidate_blocks(kind, m, n, k, group, rank)
    if not cands:
        return None
    cache = cache or default_cache()
    best, best_t = None, float("inf")
    for blocks in cands:
        try:
            t = _measure(make_call(blocks), iters=iters)
        except Exception:  # a candidate that fails to compile is just skipped
            continue
        if t < best_t:
            best, best_t = blocks, t
    if best is None:
        return None
    cache.store(
        cache_key(kind, m, n, k, group, rank),
        best,
        best_us=round(best_t * 1e6, 2),
        candidates=len(cands),
    )
    return best


def _cli() -> None:
    """Measured-sweep CLI (run on the serving hardware)::

        python -m repro.kernels.autotune dual_prefill --m 1024 --n 4096 --k 4096
        python -m repro.kernels.autotune dual_decode  --m 8 --n 14336 --k 4096

    Builds a random layer at the given shape, times every candidate block
    triple, and persists the winner to the tune cache the dispatch layer
    reads (artifacts/tune/<kind>.json).
    """
    import argparse

    ap = argparse.ArgumentParser(description=_cli.__doc__)
    ap.add_argument("kind", choices=["dual_prefill", "dual_decode", "w4a16"])
    ap.add_argument("--m", type=int, required=True)
    ap.add_argument("--n", type=int, required=True)
    ap.add_argument("--k", type=int, required=True)
    ap.add_argument("--group", type=int, default=128)
    ap.add_argument("--rank", type=int, default=128)
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro.kernels.ref import (
        pack_rows_groupsplit,
        pack_twinquant_weights,
        quantize_rows_ref,
    )

    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    interpret = jax.default_backend() == "cpu"
    x = jax.random.normal(k4, (args.m, args.k)).astype(jnp.bfloat16)

    if args.kind == "w4a16":
        from repro.kernels.w4a16_gemm import w4a16_gemm

        wq, ws = quantize_rows_ref(
            jax.random.normal(k1, (args.k, args.n)) * 0.1, args.group, 4
        )
        wp = pack_rows_groupsplit(wq, args.group)

        def make_call(blocks):
            bm, bn, bk = blocks
            pad = (-args.m) % bm
            xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
            return lambda: w4a16_gemm(
                xp, wp, ws, group=args.group,
                block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
            )
    else:
        from repro.kernels.twinquant_dual_gemm import dual_gemm
        from repro.kernels.twinquant_dual_gemv import dual_gemv

        w = pack_twinquant_weights(
            jax.random.normal(k1, (args.k, args.rank)) * 0.1,
            jax.random.normal(k2, (args.rank, args.n)) * 0.1,
            jax.random.normal(k3, (args.k, args.n)) * 0.05,
            group=args.group,
        )

        def make_call(blocks):
            bm, bn, bk = blocks
            if args.kind == "dual_decode":
                return lambda: dual_gemv(x, w, block_n=bn, interpret=interpret)
            pad = (-args.m) % bm
            xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
            return lambda: dual_gemm(
                xp, w, block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
            )

    best = autotune_blocks(
        args.kind, make_call, args.m, args.n, args.k, args.group, args.rank,
        iters=args.iters,
    )
    if best is None:
        raise SystemExit(f"shape not tileable: {(args.m, args.n, args.k)}")
    key_str = cache_key(args.kind, args.m, args.n, args.k, args.group, args.rank)
    print(f"{key_str} -> blocks {best} (persisted to {default_cache().dir})")


if __name__ == "__main__":
    _cli()
