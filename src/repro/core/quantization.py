"""Symmetric group-wise quantization primitives (paper Eq. 1).

Conventions used throughout the repo:

* Quantization is symmetric: ``q = clip(round(x / s), -qmax, qmax)`` with
  ``s = max|group| / qmax`` and ``qmax = 2**(bits-1) - 1`` (so int4 uses the
  symmetric range [-7, 7] — the same convention as QuaRot/SVDQuant).
* Grouping is along ONE axis (the contraction axis of the consuming matmul),
  ``group_size`` contiguous elements per scale (paper default 128).
* Integer values are *stored* as int8 regardless of ``bits`` (int4 values
  live in [-7, 7] inside an int8); HBM-resident 4-bit tensors are packed two
  nibbles per byte via :func:`pack_int4` / :func:`unpack_int4`.
* ``jnp.round`` (round-half-to-even) is the single rounding used everywhere —
  the Pallas kernels and the pure-jnp oracles share it, so kernel-vs-ref
  comparisons are exact.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "QTensor",
    "qmax_for_bits",
    "compute_scales",
    "quantize",
    "dequantize",
    "fake_quant",
    "pack_int4",
    "unpack_int4",
]


def qmax_for_bits(bits: int) -> int:
    return 2 ** (bits - 1) - 1


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static description of one quantizer (weights OR activations)."""

    bits: int = 4
    group_size: int = 128
    # axis the groups run along; -1 == last axis (the matmul contraction dim)
    axis: int = -1

    @property
    def qmax(self) -> int:
        return qmax_for_bits(self.bits)

    def replace(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """A quantized tensor: int values + per-group scales + static metadata."""

    q: jax.Array  # int8 storage (values within the `bits` range)
    scale: jax.Array  # f32, shape == q.shape with `axis` reduced by group_size
    bits: int
    group_size: int
    axis: int

    def tree_flatten(self):
        return (self.q, self.scale), (self.bits, self.group_size, self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        bits, group_size, axis = aux
        return cls(q=q, scale=scale, bits=bits, group_size=group_size, axis=axis)

    @property
    def shape(self):
        return self.q.shape


def _grouped(x: jax.Array, axis: int, group_size: int) -> tuple[jax.Array, int]:
    """Reshape ``axis`` into (n_groups, group_size); returns (y, norm_axis)."""
    axis = axis % x.ndim
    n = x.shape[axis]
    if n % group_size != 0:
        raise ValueError(f"axis size {n} not divisible by group_size {group_size}")
    new_shape = x.shape[:axis] + (n // group_size, group_size) + x.shape[axis + 1 :]
    return x.reshape(new_shape), axis + 1


def compute_scales(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Per-group symmetric scales ``max|group| / qmax`` (zero-safe)."""
    g, gaxis = _grouped(x, cfg.axis, cfg.group_size)
    amax = jnp.max(jnp.abs(g), axis=gaxis)
    # zero-safe: an all-zero group quantizes to zeros with scale 1
    scale = jnp.where(amax > 0, amax / cfg.qmax, jnp.ones_like(amax))
    return scale.astype(jnp.float32)


def quantize(x: jax.Array, cfg: QuantConfig, scale: Optional[jax.Array] = None) -> QTensor:
    """Quantize ``x`` group-wise along ``cfg.axis``."""
    if scale is None:
        scale = compute_scales(x, cfg)
    g, gaxis = _grouped(x.astype(jnp.float32), cfg.axis, cfg.group_size)
    s = jnp.expand_dims(scale, gaxis)
    q = jnp.clip(jnp.round(g / s), -cfg.qmax, cfg.qmax)
    q = q.reshape(x.shape).astype(jnp.int8)
    return QTensor(
        q=q, scale=scale, bits=cfg.bits, group_size=cfg.group_size, axis=cfg.axis % x.ndim
    )


def dequantize(t: QTensor, dtype=jnp.float32) -> jax.Array:
    g, gaxis = _grouped(t.q.astype(jnp.float32), t.axis, t.group_size)
    s = jnp.expand_dims(t.scale, gaxis)
    return (g * s).reshape(t.q.shape).astype(dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator.

    Used by the calibration trainer (paper §4.2) — gradients flow through the
    rounding as identity (within the clip range).
    """
    return dequantize(quantize(x, cfg), dtype=x.dtype)


def _fq_fwd(x, cfg):
    scale = compute_scales(x, cfg)
    y = dequantize(quantize(x, cfg, scale), dtype=x.dtype)
    # residual: clip mask (gradient is zero where the value saturated)
    g, gaxis = _grouped(x, cfg.axis, cfg.group_size)
    s = jnp.expand_dims(scale, gaxis)
    inside = (jnp.abs(g / s) <= cfg.qmax).reshape(x.shape)
    return y, inside


def _fq_bwd(cfg, inside, ct):
    return (ct * inside.astype(ct.dtype),)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


# ---------------------------------------------------------------------------
# int4 nibble packing: two int4 values per int8 byte along the LAST axis.
# The packed layout is the HBM-resident form consumed by the Pallas kernels —
# it halves weight bytes relative to int8 storage (the roofline-relevant win).
# ---------------------------------------------------------------------------


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int4-valued int8 pairs along the last axis: out[..., i] holds
    (q[..., 2i] & 0xF) | (q[..., 2i+1] << 4). Last axis must be even."""
    if q.shape[-1] % 2 != 0:
        raise ValueError("last axis must be even to pack int4 pairs")
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return ((lo & 0x0F) | ((hi & 0x0F) << 4)).astype(jnp.int8)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` (sign-extends each nibble)."""
    p16 = p.astype(jnp.int8)
    # sign-extend low nibble: shift left then arithmetic shift right
    lo = jnp.right_shift(jnp.left_shift(p16.astype(jnp.int32), 28), 28)
    hi = jnp.right_shift(jnp.left_shift(p16.astype(jnp.int32), 24), 28)
    out = jnp.stack([lo, hi], axis=-1).reshape(p.shape[:-1] + (p.shape[-1] * 2,))
    return out.astype(jnp.int8)
