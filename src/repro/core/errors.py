"""Error decomposition & theorem diagnostics (paper Eq. 5, Eq. 8, Thm 4.1).

These are analysis utilities — used by the benchmarks to reproduce Figures
1b/2/7 and to check the direction of Theorem 4.1 on real calibrated layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import QuantConfig, dequantize, quantize

__all__ = [
    "quant_error",
    "groupwise_error_map",
    "error_terms",
    "zeta_gain",
    "eta_gain",
    "total_delta",
]


def quant_error(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """E_X = X - Q(X)."""
    return x - dequantize(quantize(x, cfg), dtype=x.dtype)


def groupwise_error_map(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Per-group RMS quantization error (the Fig. 1b / Fig. 7 heatmaps)."""
    e = quant_error(x, cfg)
    axis = cfg.axis % x.ndim
    n = x.shape[axis]
    g = e.reshape(x.shape[:axis] + (n // cfg.group_size, cfg.group_size) + x.shape[axis + 1 :])
    return jnp.sqrt(jnp.mean(g**2, axis=axis + 1))


def error_terms(
    x, U, V, R, aq: QuantConfig, wq_u: QuantConfig, wq_v: QuantConfig, wq_r: QuantConfig
):
    """The three Eq.-5 terms: activation / low-rank / residual errors."""
    w_hat = U @ V + R
    e_x = quant_error(x, aq)
    e_u = quant_error(U, wq_u)
    e_v = quant_error(V, wq_v)
    e_r = quant_error(R, wq_r)
    e_uv = e_u @ V + U @ e_v
    act = jnp.sum((e_x @ w_hat) ** 2)
    lowrank = jnp.sum((x @ e_uv) ** 2)
    residual = jnp.sum((x @ e_r) ** 2)
    return {"activation": act, "lowrank": lowrank, "residual": residual,
            "total_linearized": act + lowrank + residual}


def total_delta(x, U, V, R, aq, wq_u, wq_v, wq_r):
    """Exact ||Delta||_F^2 of Eq. 4 (no independence approximation)."""
    def q(t, c):
        return dequantize(quantize(t, c), dtype=t.dtype)

    y_ref = x @ (U @ V + R)
    y_q = q(x, aq) @ (q(U, wq_u) @ q(V, wq_v) + q(R, wq_r))
    return jnp.sum((y_ref - y_q) ** 2)


def zeta_gain(x: jax.Array, Q: jax.Array) -> jax.Array:
    """Activation flattening gain zeta(Q, X) = E||X||_inf^2 / E||XQ||_inf^2.

    ||.||_inf taken per-row (per-token max magnitude), expectation over rows.
    """
    num = jnp.mean(jnp.max(jnp.abs(x), axis=-1) ** 2)
    den = jnp.mean(jnp.max(jnp.abs(x @ Q), axis=-1) ** 2)
    return num / den


def _uv_proxy(U, V):
    return (jnp.max(jnp.abs(U)) ** 2) * jnp.sum(V**2) + (jnp.max(jnp.abs(V)) ** 2) * jnp.sum(U**2)


def eta_gain(U, V, U2, V2) -> jax.Array:
    """Low-rank re-parameterization gain eta (Eq. 8 proxy ratio)."""
    return _uv_proxy(U, V) / _uv_proxy(U2, V2)
