"""Model-level TwinQuant: rewrite a params pytree into quantized form.

Two products, one algorithm:

* :func:`quantize_params` — the **serving** path: every eligible linear is
  replaced by a packed 4-bit dual-component pack (``up/us/vp/vs/rp/rs``)
  consumed by the fused Pallas kernel through ``models.common.linear``.
  Works for every architecture family (stacked layers are vmapped). The
  transforms (Q, G) are folded into the components before packing.

* :func:`simulate_quantize_params` — the **evaluation** path: eligible
  linears are replaced by dequantized "sim" dicts that reproduce exact
  W4A4/W4A8 TwinQuant numerics (including online activation transform +
  activation fake-quant) with plain bf16 matmuls — used by the accuracy
  benchmarks (paper Tables 2/3 reproduction) where we need model-level PPL
  under naive / +lowrank / +hadamard / TwinQuant variants on CPU.

Exclusions (kept high-precision, documented in DESIGN.md): embeddings, lm
head, MoE routers, norms/biases/convs/recurrences (not matmul weights), and
DeepSeek's ``wkv_b`` (it participates in the absorbed decode path as an
einsum operand, not a plain linear).
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, QuantSpec
from repro.core.calibration import CalibConfig, calibrate_layer
from repro.core.decomposition import svd_decompose
from repro.core.quantization import QuantConfig, dequantize, quantize
from repro.core.transforms import hadamard_matrix
from repro.kernels.ref import pack_twinquant_weights, quantize_rows_ref, pack_rows_groupsplit

EXCLUDE = re.compile(r"(embed|head|router|wkv_b|mtp/proj)")


def _eligible(path_str: str, w) -> bool:
    if EXCLUDE.search(path_str):
        return False
    if w.ndim < 2:
        return False
    k, n = w.shape[-2], w.shape[-1]
    return k % 256 == 0 and n % 2 == 0 and k >= 256


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# serving path: packed weights
# ---------------------------------------------------------------------------


def _pack_one(w: jax.Array, spec: QuantSpec):
    """2-D weight -> twinquant pack dict (SVD split, sqrt-balanced)."""
    k, n = w.shape
    r = min(spec.rank, k // 2, n)
    r = max(2, r // 2 * 2)
    U, V, R = svd_decompose(w.astype(jnp.float32), r)
    tq = pack_twinquant_weights(U, V, R, a_bits=spec.a_bits, group=min(spec.group_size, k))
    return {
        "up": tq.up, "us": tq.us, "vp": tq.vp, "vs": tq.vs, "rp": tq.rp, "rs": tq.rs,
        "abits": jnp.zeros((spec.a_bits,), jnp.int8),
    }


def _pack_one_w4a16(w: jax.Array, spec: QuantSpec):
    k, n = w.shape
    g = min(spec.group_size, k)
    wq, ws = quantize_rows_ref(w.astype(jnp.float32), g, 4)
    return {"wp": pack_rows_groupsplit(wq, g), "ws": ws}


def quantize_params(params: Any, cfg: ModelConfig, spec: QuantSpec) -> Any:
    """Rewrite eligible linears into packed quantized form (values via
    RTN-SVD; calibrated transforms can be folded in upstream). Pure jnp —
    usable under jax.eval_shape for the dry-run."""
    if spec.mode == "bf16":
        return params
    pack_one = _pack_one_w4a16 if spec.mode == "w4a16" else _pack_one

    def pack(w):
        return pack_one(w, spec)

    def visit(tree, path=""):
        if isinstance(tree, dict):
            if "w" in tree and hasattr(tree["w"], "ndim"):
                w = tree["w"]
                if _eligible(path + "/w", w):
                    fn = pack
                    for _ in range(w.ndim - 2):  # vmap over stacked dims
                        fn = jax.vmap(fn)
                    out = fn(w.astype(jnp.float32))
                    if "b" in tree:
                        out["b"] = tree["b"]
                    return out
                return tree
            return {k: visit(v, f"{path}/{k}") for k, v in tree.items()}
        return tree

    return visit(params)


# ---------------------------------------------------------------------------
# evaluation path: exact-numerics simulation dicts
# ---------------------------------------------------------------------------


def build_sim_linear(
    w: jax.Array,
    method: str,
    spec: QuantSpec,
    calib_x: Optional[jax.Array] = None,
    calib_cfg: Optional[CalibConfig] = None,
) -> dict:
    """2-D weight -> sim dict for exact quantized-numerics evaluation.

    method: 'naive' (RTN, no decomposition) | 'lowrank' (SVD, both 4-bit) |
            'hadamard' (SVD + fixed rotation) | 'twinquant' (learned Q, G).
    """
    k, n = w.shape
    w = w.astype(jnp.float32)
    if calib_x is not None and calib_x.shape[-1] != k:
        calib_x = None  # tap dim mismatch (e.g. down-proj input is d_ff-dim)
    r = max(2, min(spec.rank, k // 2, n) // 2 * 2)
    g = min(spec.group_size, k)
    wq = QuantConfig(bits=4, group_size=g, axis=0)
    vq = QuantConfig(bits=4, group_size=min(spec.group_size, r), axis=0)

    def dq(t, c):
        return dequantize(quantize(t, c), dtype=jnp.float32)

    lam = jnp.ones((k,), jnp.float32)
    Q = None
    if method == "naive":
        return {
            "lam": lam, "r_dq": dq(w, wq).astype(jnp.bfloat16),
            "abits": jnp.zeros((spec.a_bits,), jnp.int8),
        }
    if method == "twinquant":
        cc = calib_cfg or CalibConfig(rank=r, a_bits=spec.a_bits, group_size=g,
                                      steps_global=40, steps_invert=40, steps_joint=20)
        cc = cc if cc.rank == r else CalibConfig(**{**cc.__dict__, "rank": r})
        x = calib_x if calib_x is not None else jax.random.normal(jax.random.PRNGKey(0), (256, k))
        res = calibrate_layer(x, w, cc)
        lam = res.decomp.lam
        U2 = res.Q.T @ res.decomp.U @ res.G
        V2 = res.G_inv @ res.decomp.V
        R2 = res.Q.T @ res.decomp.R
        Q = res.Q
    else:
        U, V, R = svd_decompose(w, r)
        if method == "hadamard":
            Q = hadamard_matrix(k)
            U2, V2, R2 = Q.T @ U, V, Q.T @ R
        else:  # lowrank
            U2, V2, R2 = U, V, R

    out = {
        "lam": lam,
        "u_dq": dq(U2, wq).astype(jnp.bfloat16),
        "v_dq": dq(V2, vq).astype(jnp.bfloat16),
        "r_dq": dq(R2, wq).astype(jnp.bfloat16),
        "abits": jnp.zeros((spec.a_bits,), jnp.int8),
    }
    if Q is not None:
        out["Q"] = Q.astype(jnp.bfloat16)
    return out


def simulate_quantize_params(
    params: Any,
    cfg: ModelConfig,
    spec: QuantSpec,
    method: str,
    calib_taps: Optional[dict] = None,
    calib_cfg: Optional[CalibConfig] = None,
) -> Any:
    """Rewrite eligible linears into sim dicts. Stacked layer dims are looped
    in python (calibration is a python-loop trainer). calib_taps: optional
    {path_prefix: activations (..., K)} map for real calibration data."""

    def tap_for(path):
        if not calib_taps:
            return None
        for key, acts in calib_taps.items():
            if key in path:
                return acts
        return None

    def visit(tree, path=""):
        if isinstance(tree, dict):
            if "w" in tree and hasattr(tree["w"], "ndim"):
                w = tree["w"]
                if not _eligible(path + "/w", w):
                    return tree
                if w.ndim == 2:
                    out = build_sim_linear(w, method, spec, tap_for(path), calib_cfg)
                else:
                    lead = w.shape[:-2]
                    flat = w.reshape((-1,) + w.shape[-2:])
                    tap = tap_for(path)
                    sims = []
                    for i in range(flat.shape[0]):
                        ti = None
                        if tap is not None:
                            ti = tap[i] if tap.ndim == 3 and tap.shape[0] == flat.shape[0] else tap
                        sims.append(build_sim_linear(flat[i], method, spec, ti, calib_cfg))
                    out = jax.tree.map(lambda *xs: jnp.stack(xs).reshape(lead + xs[0].shape), *sims)
                if "b" in tree:
                    out["b"] = tree["b"]
                return out
            return {k: visit(v, f"{path}/{k}") for k, v in tree.items()}
        return tree

    return visit(params)
