"""Model-level TwinQuant: rewrite a params pytree into quantized form.

Two products, one algorithm:

* :func:`quantize_params` — the **serving** path: every eligible linear is
  replaced by a packed 4-bit dual-component pack (``up/us/vp/vs/rp/rs``)
  consumed by the fused Pallas kernel through ``models.common.linear``.
  Works for every architecture family (stacked layers are vmapped). The
  transforms (Q, G) are folded into the components before packing.

* :func:`simulate_quantize_params` — the **evaluation** path: eligible
  linears are replaced by dequantized "sim" dicts that reproduce exact
  W4A4/W4A8 TwinQuant numerics (including online activation transform +
  activation fake-quant) with plain bf16 matmuls — used by the accuracy
  benchmarks (paper Tables 2/3 reproduction) where we need model-level PPL
  under naive / +lowrank / +hadamard / TwinQuant variants on CPU.

* :func:`fuse_params` — the optional **horizontal-fusion** post-pass for
  serving: sibling packs that consume the same activation (q/k/v, gate/up,
  wq_a/wkv_a) are merged into one fused group pack
  (``models.common.linear_group`` -> ``kernels.dispatch.fused_linear``: one
  launch, one activation quantization per group). Applied to the in-memory
  tree only — checkpoints stay unfused on disk, and ``linear_group`` also
  fuses unmerged sibling packs at trace time, so the pass is an HBM-traffic
  optimization (no per-step weight concatenation), not a requirement.

Exclusions (kept high-precision, documented in DESIGN.md): embeddings, lm
head, MoE routers, norms/biases/convs/recurrences (not matmul weights), and
DeepSeek's ``wkv_b`` (it participates in the absorbed decode path as an
einsum operand, not a plain linear).
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, QuantSpec
from repro.core.calibration import CalibConfig, calibrate_layer
from repro.core.decomposition import svd_decompose
from repro.core.quantization import QuantConfig, dequantize, quantize
from repro.core.transforms import hadamard_matrix
from repro.kernels.ref import pack_twinquant_weights, quantize_rows_ref, pack_rows_groupsplit

EXCLUDE = re.compile(r"(embed|head|router|wkv_b|mtp/proj)")


def _eligible(path_str: str, w) -> bool:
    if EXCLUDE.search(path_str):
        return False
    if w.ndim < 2:
        return False
    k, n = w.shape[-2], w.shape[-1]
    return k % 256 == 0 and n % 2 == 0 and k >= 256


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# serving path: packed weights
# ---------------------------------------------------------------------------


def _pack_one(w: jax.Array, spec: QuantSpec):
    """2-D weight -> twinquant pack dict (SVD split, sqrt-balanced)."""
    k, n = w.shape
    r = min(spec.rank, k // 2, n)
    r = max(2, r // 2 * 2)
    U, V, R = svd_decompose(w.astype(jnp.float32), r)
    tq = pack_twinquant_weights(U, V, R, a_bits=spec.a_bits, group=min(spec.group_size, k))
    return {
        "up": tq.up, "us": tq.us, "vp": tq.vp, "vs": tq.vs, "rp": tq.rp, "rs": tq.rs,
        "abits": jnp.zeros((spec.a_bits,), jnp.int8),
    }


def _pack_one_w4a16(w: jax.Array, spec: QuantSpec):
    k, n = w.shape
    g = min(spec.group_size, k)
    wq, ws = quantize_rows_ref(w.astype(jnp.float32), g, 4)
    return {"wp": pack_rows_groupsplit(wq, g), "ws": ws}


def quantize_params(params: Any, cfg: ModelConfig, spec: QuantSpec) -> Any:
    """Rewrite eligible linears into packed quantized form (values via
    RTN-SVD; calibrated transforms can be folded in upstream). Pure jnp —
    usable under jax.eval_shape for the dry-run."""
    if spec.mode == "bf16":
        return params
    pack_one = _pack_one_w4a16 if spec.mode == "w4a16" else _pack_one

    def pack(w):
        return pack_one(w, spec)

    def visit(tree, path=""):
        if isinstance(tree, dict):
            if "w" in tree and hasattr(tree["w"], "ndim"):
                w = tree["w"]
                if _eligible(path + "/w", w):
                    fn = pack
                    for _ in range(w.ndim - 2):  # vmap over stacked dims
                        fn = jax.vmap(fn)
                    out = fn(w.astype(jnp.float32))
                    if "b" in tree:
                        out["b"] = tree["b"]
                    return out
                return tree
            return {k: visit(v, f"{path}/{k}") for k, v in tree.items()}
        return tree

    return visit(params)


# ---------------------------------------------------------------------------
# serving path: horizontal fusion of sibling packs (one launch per group)
# ---------------------------------------------------------------------------

# (sibling keys, fused key, parent-dict keys that may fuse them — None = any).
# "qkv" is restricted to dicts literally named "attn": encdec cross-attention
# ("xattn") projects q from the decoder stream but k/v from the encoder
# states, so its siblings do NOT share an activation and must stay separate
# (models/encdec._mha fuses its k/v pair at trace time instead).
FUSE_GROUPS = (
    (("q", "k", "v"), "qkv", ("attn",)),
    (("gate", "up"), "gate_up", None),
    (("wq_a", "wkv_a"), "wqkv_a", None),
)


def _is_pack(d) -> bool:
    return isinstance(d, dict) and "rp" in d


def _packs_fusable(packs: list) -> bool:
    """Sibling packs mergeable along N: all dual-component, same K (and any
    stacked leading dims), same scale group and activation bits."""
    if not all(_is_pack(d) for d in packs):
        return False
    base = packs[0]
    group = base["rp"].shape[-2] * 2 // base["rs"].shape[-2]
    return all(
        d["rp"].shape[:-1] == base["rp"].shape[:-1]
        and d["rp"].shape[-2] * 2 // d["rs"].shape[-2] == group
        and d["abits"].shape == base["abits"].shape
        for d in packs
    )


def fuse_linear_packs(packs: list) -> dict:
    """Merge sibling pack dicts into one fused group pack dict.

    Pure concatenation of already-quantized arrays (R/U factors and their
    scales are column-independent, so concat IS the per-segment quantization;
    V stays per segment as ``vp{j}``/``vs{j}`` to preserve each segment's own
    rank-group structure). Works on scan/expert-stacked packs too (all axes
    are trailing). Biases concatenate into one ``b``.
    """
    out = {
        "up": jnp.concatenate([d["up"] for d in packs], axis=-1),
        "us": jnp.concatenate([d["us"] for d in packs], axis=-1),
        "rp": jnp.concatenate([d["rp"] for d in packs], axis=-1),
        "rs": jnp.concatenate([d["rs"] for d in packs], axis=-1),
        "abits": packs[0]["abits"],
    }
    for j, d in enumerate(packs):
        out[f"vp{j}"] = d["vp"]
        out[f"vs{j}"] = d["vs"]
    if any("b" in d for d in packs):
        out["b"] = jnp.concatenate(
            [
                d["b"] if "b" in d
                else jnp.zeros(d["rp"].shape[:-2] + (d["rp"].shape[-1],), jnp.float32)
                for d in packs
            ],
            axis=-1,
        )
    return out


def fuse_params(params: Any) -> Any:
    """Merge sibling quantized packs that share an input into fused groups.

    In-memory rewrite for serving (run after :func:`quantize_params` or after
    restoring a quantized checkpoint): ``{"q":pack,"k":pack,"v":pack}``
    becomes ``{"qkv": fused_pack}`` (same for gate/up -> ``gate_up`` and
    MLA's wq_a/wkv_a -> ``wqkv_a``), which ``models.common.linear_group``
    executes as ONE kernel launch. Checkpoints are saved from the unfused
    tree, so the on-disk format is unchanged. Non-pack siblings (bf16,
    w4a16, sim dicts, partially quantized groups) are left untouched.
    """

    def visit(tree, key=""):
        if not isinstance(tree, dict):
            return tree
        tree = {k: visit(v, k) for k, v in tree.items()}
        for names, fused_key, parents in FUSE_GROUPS:
            if parents is not None and key not in parents:
                continue
            if all(n in tree for n in names) and _packs_fusable(
                [tree[n] for n in names]
            ):
                packs = [tree.pop(n) for n in names]
                tree[fused_key] = fuse_linear_packs(packs)
        return tree

    return visit(params)


# ---------------------------------------------------------------------------
# evaluation path: exact-numerics simulation dicts
# ---------------------------------------------------------------------------


def build_sim_linear(
    w: jax.Array,
    method: str,
    spec: QuantSpec,
    calib_x: Optional[jax.Array] = None,
    calib_cfg: Optional[CalibConfig] = None,
) -> dict:
    """2-D weight -> sim dict for exact quantized-numerics evaluation.

    method: 'naive' (RTN, no decomposition) | 'lowrank' (SVD, both 4-bit) |
            'hadamard' (SVD + fixed rotation) | 'twinquant' (learned Q, G).
    """
    k, n = w.shape
    w = w.astype(jnp.float32)
    if calib_x is not None and calib_x.shape[-1] != k:
        calib_x = None  # tap dim mismatch (e.g. down-proj input is d_ff-dim)
    r = max(2, min(spec.rank, k // 2, n) // 2 * 2)
    g = min(spec.group_size, k)
    wq = QuantConfig(bits=4, group_size=g, axis=0)
    vq = QuantConfig(bits=4, group_size=min(spec.group_size, r), axis=0)

    def dq(t, c):
        return dequantize(quantize(t, c), dtype=jnp.float32)

    lam = jnp.ones((k,), jnp.float32)
    Q = None
    if method == "naive":
        return {
            "lam": lam, "r_dq": dq(w, wq).astype(jnp.bfloat16),
            "abits": jnp.zeros((spec.a_bits,), jnp.int8),
        }
    if method == "twinquant":
        cc = calib_cfg or CalibConfig(rank=r, a_bits=spec.a_bits, group_size=g,
                                      steps_global=40, steps_invert=40, steps_joint=20)
        cc = cc if cc.rank == r else CalibConfig(**{**cc.__dict__, "rank": r})
        x = calib_x if calib_x is not None else jax.random.normal(jax.random.PRNGKey(0), (256, k))
        res = calibrate_layer(x, w, cc)
        lam = res.decomp.lam
        U2 = res.Q.T @ res.decomp.U @ res.G
        V2 = res.G_inv @ res.decomp.V
        R2 = res.Q.T @ res.decomp.R
        Q = res.Q
    else:
        U, V, R = svd_decompose(w, r)
        if method == "hadamard":
            Q = hadamard_matrix(k)
            U2, V2, R2 = Q.T @ U, V, Q.T @ R
        else:  # lowrank
            U2, V2, R2 = U, V, R

    out = {
        "lam": lam,
        "u_dq": dq(U2, wq).astype(jnp.bfloat16),
        "v_dq": dq(V2, vq).astype(jnp.bfloat16),
        "r_dq": dq(R2, wq).astype(jnp.bfloat16),
        "abits": jnp.zeros((spec.a_bits,), jnp.int8),
    }
    if Q is not None:
        out["Q"] = Q.astype(jnp.bfloat16)
    return out


def simulate_quantize_params(
    params: Any,
    cfg: ModelConfig,
    spec: QuantSpec,
    method: str,
    calib_taps: Optional[dict] = None,
    calib_cfg: Optional[CalibConfig] = None,
) -> Any:
    """Rewrite eligible linears into sim dicts. Stacked layer dims are looped
    in python (calibration is a python-loop trainer). calib_taps: optional
    {path_prefix: activations (..., K)} map for real calibration data."""

    def tap_for(path):
        if not calib_taps:
            return None
        for key, acts in calib_taps.items():
            if key in path:
                return acts
        return None

    def visit(tree, path=""):
        if isinstance(tree, dict):
            if "w" in tree and hasattr(tree["w"], "ndim"):
                w = tree["w"]
                if not _eligible(path + "/w", w):
                    return tree
                if w.ndim == 2:
                    out = build_sim_linear(w, method, spec, tap_for(path), calib_cfg)
                else:
                    lead = w.shape[:-2]
                    flat = w.reshape((-1,) + w.shape[-2:])
                    tap = tap_for(path)
                    sims = []
                    for i in range(flat.shape[0]):
                        ti = None
                        if tap is not None:
                            ti = tap[i] if tap.ndim == 3 and tap.shape[0] == flat.shape[0] else tap
                        sims.append(build_sim_linear(flat[i], method, spec, ti, calib_cfg))
                    out = jax.tree.map(lambda *xs: jnp.stack(xs).reshape(lead + xs[0].shape), *sims)
                if "b" in tree:
                    out["b"] = tree["b"]
                return out
            return {k: visit(v, f"{path}/{k}") for k, v in tree.items()}
        return tree

    return visit(params)
