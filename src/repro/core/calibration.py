"""Per-layer TwinQuant calibration: the three-stage joint optimization of
(Q, G) over Stiefel x GL (paper §4.2).

The layer objective is Eq. 6:

    || X W_hat  -  fq(X Q) [ fq(Q^T U G) fq(G^-1 V) + fq(Q^T R) ] ||_F^2
      + reg * conditioning_penalty(G)

with `fq` the STE fake-quantizer. Stages:

    (i)   Global Alignment     — only Q trains
    (ii)  Invertible Adaptation— only G = (P, L, gamma) trains
    (iii) Joint Refinement     — everything trains

Stage selection is a per-leaf learning-rate mask, so one jitted update step
serves all three stages.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.decomposition import Decomposition, decompose, search_alpha
from repro.core.manifold import HybridOpt
from repro.core.quantization import QuantConfig, fake_quant
from repro.core.transforms import (
    GLParams,
    gl_conditioning_penalty,
    gl_init,
    gl_inverse,
    gl_materialize,
    orthogonal_init,
)

__all__ = ["CalibConfig", "CalibResult", "calibrate_layer", "layer_quant_configs"]


@dataclasses.dataclass(frozen=True)
class CalibConfig:
    rank: int = 128
    w_bits: int = 4
    a_bits: int = 4
    group_size: int = 128
    # paper defaults are 400/400/200; CPU-scale callers shrink these
    steps_global: int = 400
    steps_invert: int = 400
    steps_joint: int = 200
    lr: float = 5e-3
    momentum: float = 0.9
    reg_lambda: float = 1e-3
    # SpinQuant-style practice: start from a Hadamard rotation so the learned
    # Q can only improve on the fixed-rotation baseline (best-iterate kept)
    q_init: str = "hadamard"  # identity | hadamard | random
    smooth_alpha: Optional[float] = None  # None => grid search
    # learn Q at all? (False => fixed rotation ablation, e.g. +Hadamard)
    learn_q: bool = True
    learn_g: bool = True


@dataclasses.dataclass
class CalibResult:
    Q: jax.Array
    G: jax.Array  # materialized
    G_inv: jax.Array
    decomp: Decomposition  # the *untransformed* smoothed decomposition
    loss_history: list
    final_loss: float
    init_loss: float


def layer_quant_configs(m: int, r: int, cfg: CalibConfig):
    """Quantizers for (activations, U, V, R). Groups run along the matmul
    contraction dims; V's contraction dim is the rank, which may be < 128."""
    aq = QuantConfig(bits=cfg.a_bits, group_size=min(cfg.group_size, m), axis=-1)
    uq = QuantConfig(bits=cfg.w_bits, group_size=min(cfg.group_size, m), axis=0)
    vq = QuantConfig(bits=cfg.w_bits, group_size=min(cfg.group_size, r), axis=0)
    rq = QuantConfig(bits=cfg.w_bits, group_size=min(cfg.group_size, m), axis=0)
    return aq, uq, vq, rq


def _transformed_components(params, U, V, R):
    Q = params["Q"]
    Gm = gl_materialize(params["G"])
    Gi = gl_inverse(params["G"])
    U2 = Q.T @ U @ Gm
    V2 = Gi @ V
    R2 = Q.T @ R
    return Q, U2, V2, R2


def _layer_loss(params, x, y_ref, U, V, R, aq, uq, vq, rq, reg_lambda, a_bits):
    Q, U2, V2, R2 = _transformed_components(params, U, V, R)
    xq = x @ Q
    xfq = fake_quant(xq, aq) if a_bits < 16 else xq
    w_eff = fake_quant(U2, uq) @ fake_quant(V2, vq) + fake_quant(R2, rq)
    y = xfq @ w_eff
    recon = jnp.mean((y - y_ref) ** 2)
    return recon + reg_lambda * gl_conditioning_penalty(params["G"]), recon


def calibrate_layer(
    x: jax.Array,
    w: jax.Array,
    cfg: CalibConfig,
    key: Optional[jax.Array] = None,
) -> CalibResult:
    """Run the full three-stage calibration for one linear layer.

    x: (samples, m) calibration activations; w: (m, n) weight.
    """
    m, n = w.shape
    if key is None:
        key = jax.random.PRNGKey(0)

    # 1) smoothing (alpha grid-search) + SVD decomposition
    aq_s, uq, vq, rq = layer_quant_configs(m, cfg.rank, cfg)
    if cfg.smooth_alpha is None:
        alpha, _ = search_alpha(x, w, cfg.rank, rq, aq_s)
    else:
        alpha = cfg.smooth_alpha
    decomp = decompose(w, cfg.rank, act_absmax=jnp.max(jnp.abs(x), axis=0), alpha=alpha)
    x_hat = x / decomp.lam[None, :]
    U, V, R = decomp.U, decomp.V, decomp.R
    r = decomp.rank
    y_ref = x_hat @ (U @ V + R)

    # 2) parameters
    params = {
        "Q": orthogonal_init(m, cfg.q_init, key=key),
        "G": gl_init(r),
    }
    stiefel_mask = {"Q": True, "G": GLParams(P=True, L=False, gamma=False)}

    opt = HybridOpt(lr=cfg.lr, momentum=cfg.momentum)
    state = opt.init(params)
    aq, uq, vq, rq = layer_quant_configs(m, r, cfg)

    loss_fn = partial(
        _layer_loss,
        x=x_hat, y_ref=y_ref, U=U, V=V, R=R,
        aq=aq, uq=uq, vq=vq, rq=rq,
        reg_lambda=cfg.reg_lambda, a_bits=cfg.a_bits,
    )
    grad_fn = jax.value_and_grad(lambda p: loss_fn(p), has_aux=True)

    @jax.jit
    def step(params, state, lr_scale):
        (loss, recon), grads = grad_fn(params)
        new_params, new_state = opt.update(grads, state, params, stiefel_mask, lr_scale)
        return new_params, new_state, recon

    q_on = 1.0 if cfg.learn_q else 0.0
    g_on = 1.0 if cfg.learn_g else 0.0
    stage_scales = [
        {"Q": q_on, "G": GLParams(P=0.0, L=0.0, gamma=0.0)},
        {"Q": 0.0, "G": GLParams(P=g_on, L=g_on, gamma=g_on)},
        {"Q": q_on, "G": GLParams(P=g_on, L=g_on, gamma=g_on)},
    ]
    stage_steps = [cfg.steps_global, cfg.steps_invert, cfg.steps_joint]

    init_loss = float(loss_fn(params)[1])
    history = [init_loss]
    # best-params tracking: the hard-quantized objective is noisy under SGD,
    # so we return the best iterate rather than the last one
    best_loss, best_params = init_loss, params
    for scales, steps in zip(stage_scales, stage_steps):
        recon = history[-1]
        for _ in range(steps):
            prev = params
            params, state, recon = step(params, state, scales)
            r = float(recon)  # loss evaluated at `prev`
            if r < best_loss:
                best_loss, best_params = r, prev
        history.append(float(recon))
    final_eval = float(loss_fn(params)[1])
    if final_eval < best_loss:
        best_loss, best_params = final_eval, params
    params = best_params

    Gm = gl_materialize(params["G"])
    Gi = gl_inverse(params["G"])
    return CalibResult(
        Q=params["Q"],
        G=Gm,
        G_inv=Gi,
        decomp=decomp,
        loss_history=history,
        final_loss=best_loss,
        init_loss=init_loss,
    )
