"""Weight decomposition: SmoothQuant-style smoothing + SVD low-rank split.

Pipeline (paper §2, §4.1):

1. ``lambda_i = max|X[:, i]|^alpha / max|W[i, :]|^(1-alpha)`` per input
   channel; ``X_hat = X diag(lambda)^-1``, ``W_hat = diag(lambda) W``.
   ``alpha`` is grid-searched per layer to minimize post-TwinQuant MSE.
2. Truncated SVD of ``W_hat``: ``U V`` with a *sqrt-balanced* magnitude split
   (``U = U_r sqrt(S_r)``, ``V = sqrt(S_r) V_r^T``) — balancing the factor
   magnitudes lowers their 4-bit dynamic range versus putting all of S on one
   side (paper quantizes BOTH factors, unlike SVDQuant's fp16 branch).
3. ``R = W_hat - U V`` residual.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.quantization import QuantConfig, fake_quant

__all__ = [
    "Decomposition",
    "smoothing_factors",
    "apply_smoothing",
    "svd_decompose",
    "decompose",
    "search_alpha",
]


@dataclasses.dataclass
class Decomposition:
    """W_hat = U @ V + R, with the smoothing vector that produced W_hat."""

    U: jax.Array  # (m, r)
    V: jax.Array  # (r, n)
    R: jax.Array  # (m, n)
    lam: jax.Array  # (m,) smoothing factors (identity == ones)

    @property
    def rank(self) -> int:
        return self.U.shape[1]

    def reconstruct(self) -> jax.Array:
        return self.U @ self.V + self.R


def smoothing_factors(act_absmax: jax.Array, w_absmax: jax.Array, alpha: float) -> jax.Array:
    """Per-channel lambda (paper A.6). Zero-safe on both sides."""
    a = jnp.maximum(act_absmax, 1e-5)
    w = jnp.maximum(w_absmax, 1e-5)
    lam = a**alpha / w ** (1.0 - alpha)
    return jnp.maximum(lam, 1e-5)


def apply_smoothing(x: jax.Array, w: jax.Array, lam: jax.Array):
    """Returns (x diag(lam)^-1, diag(lam) w)."""
    return x / lam[None, :], w * lam[:, None]


def svd_decompose(w: jax.Array, rank: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Truncated SVD with sqrt-balanced factors; returns (U, V, R)."""
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    r = min(rank, s.shape[0])
    sq = jnp.sqrt(s[:r])
    U = u[:, :r] * sq[None, :]
    V = sq[:, None] * vt[:r, :]
    R = w - U @ V
    return U, V, R


def decompose(
    w: jax.Array,
    rank: int,
    act_absmax: Optional[jax.Array] = None,
    alpha: Optional[float] = None,
) -> Decomposition:
    """Smooth (optional) + SVD split."""
    m = w.shape[0]
    if act_absmax is not None and alpha is not None:
        lam = smoothing_factors(act_absmax, jnp.max(jnp.abs(w), axis=1), alpha)
    else:
        lam = jnp.ones((m,), jnp.float32)
    w_hat = w * lam[:, None]
    U, V, R = svd_decompose(w_hat, rank)
    return Decomposition(U=U, V=V, R=R, lam=lam)


def _twinquant_mse(x: jax.Array, w: jax.Array, lam: jax.Array, rank: int,
                   wq: QuantConfig, aq: QuantConfig) -> jax.Array:
    """Layer-output MSE after smoothing + decomposition + fake 4-bit quant."""
    x_hat = x / lam[None, :]
    w_hat = w * lam[:, None]
    U, V, R = svd_decompose(w_hat, rank)
    y_ref = x @ w
    xq = fake_quant(x_hat, aq) if aq.bits < 16 else x_hat
    # group quantizers need the group axis divisible; U/V rank axis uses one group
    uq_cfg = wq.replace(axis=0, group_size=min(wq.group_size, U.shape[0]))
    vq_cfg = wq.replace(axis=0, group_size=min(wq.group_size, V.shape[0]))
    rq_cfg = wq.replace(axis=0, group_size=min(wq.group_size, R.shape[0]))
    y = xq @ (fake_quant(U, uq_cfg) @ fake_quant(V, vq_cfg) + fake_quant(R, rq_cfg))
    return jnp.mean((y - y_ref) ** 2)


def search_alpha(
    x: jax.Array,
    w: jax.Array,
    rank: int,
    wq: QuantConfig,
    aq: QuantConfig,
    alphas: Sequence[float] = (0.0, 0.25, 0.5, 0.75, 1.0),
) -> tuple[float, jax.Array]:
    """Grid search the migration strength alpha (paper A.6).

    Returns (best_alpha, best_lambda). Pure-python loop over a tiny grid; each
    candidate is evaluated under the full decomposition + fake-quant path.
    """
    act_absmax = jnp.max(jnp.abs(x), axis=0)
    w_absmax = jnp.max(jnp.abs(w), axis=1)
    best = (None, jnp.inf, None)
    for a in alphas:
        lam = smoothing_factors(act_absmax, w_absmax, a)
        mse = float(_twinquant_mse(x, w, lam, rank, wq, aq))
        if mse < best[1]:
            best = (a, mse, lam)
    return best[0], best[2]
