"""Hybrid manifold optimizer (paper §4.2).

Updates each parameter according to its geometry:

* Stiefel-manifold parameters (global rotations Q1/Q2 and the P factor of G)
  use **Cayley SGD with momentum** (Li et al., 2020):

      W_hat = M @ Q^T            (momentum-averaged Euclidean grad lifted)
      Y     = W_hat - W_hat^T    (skew-symmetric tangent)
      Q'    = (I - a/2 Y)^(-1) (I + a/2 Y) Q

  The Cayley map keeps Q exactly orthogonal (up to linear-solve precision);
  we re-orthonormalize via QR every `reortho_every` steps to stop fp32 drift
  over long calibrations.

* Euclidean parameters (L, gamma) use classical momentum SGD with the
  conditioning regularizer applied by the caller (it is part of the loss).

The optimizer is a pure-pytree transformation in the optax style: ``init``
returns a state pytree, ``update`` maps (grads, state, params) -> (new_params,
new_state). Stage masking (paper's three-stage schedule) is expressed by
zeroing the learning rate per parameter group — see
:class:`repro.core.calibration.StageSchedule`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["HybridOpt", "HybridState", "cayley_step", "is_stiefel_path"]


def cayley_step(q: jax.Array, skew: jax.Array, lr: float | jax.Array) -> jax.Array:
    """One Cayley-transform retraction: (I - a/2 Y)^-1 (I + a/2 Y) Q."""
    n = q.shape[0]
    eye = jnp.eye(n, dtype=q.dtype)
    a = lr / 2.0
    return jnp.linalg.solve(eye - a * skew, (eye + a * skew) @ q)


def _lift_skew(grad: jax.Array, q: jax.Array) -> jax.Array:
    w_hat = grad @ q.T
    return w_hat - w_hat.T


class HybridState(NamedTuple):
    momentum: Any  # pytree matching params
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class HybridOpt:
    """Hybrid Stiefel/Euclidean optimizer over a params pytree.

    ``stiefel_mask`` is a pytree of booleans (same structure as params)
    marking which leaves live on the Stiefel manifold.
    """

    lr: float = 5e-3
    momentum: float = 0.9
    reortho_every: int = 64
    # global-norm gradient clipping — the G-branch (L, gamma) gradients are
    # scaled by ||U||·||V|| and explode on outlier-heavy layers without it
    clip_norm: float = 1.0

    def init(self, params: Any) -> HybridState:
        zeros = jax.tree.map(jnp.zeros_like, params)
        return HybridState(momentum=zeros, count=jnp.zeros((), jnp.int32))

    def update(
        self,
        grads: Any,
        state: HybridState,
        params: Any,
        stiefel_mask: Any,
        lr_scale: Any | None = None,
    ) -> tuple[Any, HybridState]:
        """lr_scale: optional pytree of per-leaf multipliers (stage masking)."""
        if lr_scale is None:
            lr_scale = jax.tree.map(lambda _: 1.0, params)

        if self.clip_norm is not None:
            gnorm = jnp.sqrt(
                sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
            )
            factor = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * factor, grads)

        new_m = jax.tree.map(lambda m, g: self.momentum * m + g, state.momentum, grads)
        count = state.count + 1
        do_reortho = (count % self.reortho_every) == 0

        def leaf_update(p, m, is_stiefel, scale):
            eff_lr = self.lr * scale
            if is_stiefel:
                y = _lift_skew(m, p)
                q = cayley_step(p, y, -eff_lr)  # descend: negative step
                # periodic QR re-orthonormalization (sign-fixed)
                def reortho(q):
                    qq, rr = jnp.linalg.qr(q)
                    return qq * jnp.sign(jnp.diagonal(rr))[None, :]

                return jax.lax.cond(do_reortho, reortho, lambda q: q, q)
            return p - eff_lr * m

        new_params = jax.tree.map(leaf_update, params, new_m, stiefel_mask, lr_scale)
        return new_params, HybridState(momentum=new_m, count=count)


def is_stiefel_path(path: tuple) -> bool:
    """Default mask rule: leaves named 'Q', 'Q1', 'Q2', or 'P' are Stiefel."""
    names = {getattr(p, "name", getattr(p, "key", None)) for p in path}
    return bool(names & {"Q", "Q1", "Q2", "P"})
