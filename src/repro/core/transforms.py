"""Learnable transforms: global orthogonal Q (Stiefel) and layer-specific
invertible G in GL(r) via polar parameterization (paper §4.1–4.2).

* ``Q`` is stored directly as an orthogonal matrix and updated with Cayley
  SGD (see :mod:`repro.core.manifold`), so it stays on the Stiefel manifold
  to machine precision throughout calibration.
* ``G = P @ S`` with ``P`` orthogonal (same Cayley updates) and
  ``S = exp(gamma) * (L @ L.T)`` symmetric positive definite (L lower-
  triangular with softplus-positive diagonal), so G is always invertible and
  ``G^-1 = exp(-gamma) * cho_solve(L, P.T)`` is cheap and stable.
* Hadamard / random-orthogonal constructions are provided for the fixed-
  rotation baselines (QuaRot-style ablation).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "GLParams",
    "gl_init",
    "gl_materialize",
    "gl_inverse",
    "hadamard_matrix",
    "random_orthogonal",
    "orthogonal_init",
    "orthogonality_error",
]


# ---------------------------------------------------------------------------
# G in GL(r): polar parameterization
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GLParams:
    """Parameters of G = P * exp(gamma) * (L L^T)."""

    P: jax.Array  # (r, r) orthogonal — manifold-updated
    L: jax.Array  # (r, r) unconstrained; only the lower triangle is used
    gamma: jax.Array  # scalar log-scale

    def tree_flatten(self):
        return (self.P, self.L, self.gamma), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _chol_factor(L: jax.Array) -> jax.Array:
    """Lower-triangular factor with strictly positive diagonal."""
    tril = jnp.tril(L, k=-1)
    diag = jax.nn.softplus(jnp.diagonal(L)) + 1e-4
    return tril + jnp.diag(diag)


def gl_init(r: int, dtype=jnp.float32) -> GLParams:
    """G == I at init (paper keeps G near identity via the regularizer)."""
    # softplus(x) + 1e-4 = 1  =>  x = log(expm1(1 - 1e-4))
    d = float(np.log(np.expm1(1.0 - 1e-4)))
    return GLParams(
        P=jnp.eye(r, dtype=dtype),
        L=jnp.diag(jnp.full((r,), d, dtype=dtype)),
        gamma=jnp.zeros((), dtype=dtype),
    )


def gl_materialize(p: GLParams) -> jax.Array:
    Lf = _chol_factor(p.L)
    S = jnp.exp(p.gamma) * (Lf @ Lf.T)
    return p.P @ S


def gl_inverse(p: GLParams) -> jax.Array:
    """exp(-gamma) * (L L^T)^-1 @ P^T via two triangular solves."""
    Lf = _chol_factor(p.L)
    rhs = p.P.T
    y = jax.scipy.linalg.solve_triangular(Lf, rhs, lower=True)
    x = jax.scipy.linalg.solve_triangular(Lf.T, y, lower=False)
    return jnp.exp(-p.gamma) * x


def gl_conditioning_penalty(p: GLParams) -> jax.Array:
    """lambda * (||diag(L)||^2 + gamma^2) — keeps G near identity (paper §4.2).

    Penalizes the *deviation* of the materialized Cholesky diagonal from 1 so
    the penalty is zero at init.
    """
    d = jnp.diagonal(_chol_factor(p.L))
    return jnp.sum((d - 1.0) ** 2) + p.gamma**2


# ---------------------------------------------------------------------------
# Fixed rotations (baselines) + orthogonal init/checks
# ---------------------------------------------------------------------------


def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Normalized Hadamard-like orthogonal matrix.

    Exact Sylvester Hadamard for powers of two; for n = 2^k * m (m odd > 1)
    we use kron(H_{2^k}, Q_m) with Q_m a seeded random orthogonal factor —
    full Hadamard matrices don't exist for every m, and the role here is only
    "fixed incoherent rotation" (QuaRot baseline), which the kron preserves.
    """
    k = n & (-n)  # largest power of two dividing n
    m = n // k
    h = np.array([[1.0]])
    size = 1
    while size < k:
        h = np.block([[h, h], [h, -h]])
        size *= 2
    h = h / np.sqrt(k)
    if m > 1:
        rng = np.random.default_rng(seed=m)
        q, _ = np.linalg.qr(rng.standard_normal((m, m)))
        h = np.kron(h, q)
    return jnp.asarray(h, dtype=dtype)


def random_orthogonal(key: jax.Array, n: int, dtype=jnp.float32) -> jax.Array:
    a = jax.random.normal(key, (n, n), dtype=jnp.float32)
    q, r = jnp.linalg.qr(a)
    q = q * jnp.sign(jnp.diagonal(r))[None, :]
    return q.astype(dtype)


def orthogonal_init(n: int, mode: str = "identity", key: jax.Array | None = None) -> jax.Array:
    if mode == "identity":
        return jnp.eye(n, dtype=jnp.float32)
    if mode == "hadamard":
        return hadamard_matrix(n)
    if mode == "random":
        assert key is not None
        return random_orthogonal(key, n)
    raise ValueError(f"unknown orthogonal init {mode!r}")


def orthogonality_error(q: jax.Array) -> jax.Array:
    n = q.shape[0]
    return jnp.max(jnp.abs(q.T @ q - jnp.eye(n, dtype=q.dtype)))
