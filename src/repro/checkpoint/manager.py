"""Sharded checkpointing with async writes, atomic publish, retention, and
elastic restore (re-shard onto a different mesh) — the fault-tolerance
substrate used by launch/train.py.

Format: one ``.npz`` per host per step (this container is single-host; the
per-host split is the multi-host layout — each host saves the addressable
shards of its devices), with pytree paths as keys. bfloat16 is stored via a
uint16 bit-view + a dtype sidecar (npz has no native bf16).
"""

from __future__ import annotations

import json
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "|"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))) for p in path
        )
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_n: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save

    def save(self, step: int, state: Any) -> None:
        # materialize on host BEFORE going async (state may be donated later)
        flat = _flatten(state)
        arrays = {}
        dtypes = {}
        for k, v in flat.items():
            a = np.asarray(jax.device_get(v))
            if a.dtype == jnp.bfloat16:
                dtypes[k] = "bfloat16"
                a = a.view(np.uint16)
            arrays[k] = a
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, dtypes), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, arrays, dtypes)

    def _write(self, step: int, arrays: dict, dtypes: dict) -> None:
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / "host0.npz", **arrays)
        (tmp / "meta.json").write_text(json.dumps({"step": step, "dtypes": dtypes}))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore

    def list_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "meta.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int, shardings: Any = None) -> Any:
        """Returns the state pytree (as a flat path->array dict rebuilt into a
        nested dict; use :func:`restore_like` to match an existing pytree)."""
        d = self.dir / f"step_{step:09d}"
        meta = json.loads((d / "meta.json").read_text())
        data = np.load(d / "host0.npz")
        flat = {}
        for k in data.files:
            a = data[k]
            if meta["dtypes"].get(k) == "bfloat16":
                a = a.view(jnp.bfloat16)
            flat[k] = a
        return flat

    def restore_like(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; if ``shardings`` is given,
        device_put each leaf with its sharding — this is the ELASTIC path:
        the target mesh may differ from the one that saved the checkpoint
        (shards are re-laid-out from the host copy)."""
        flat = self.restore(step)
        paths = _flatten(like)
        shard_flat = _flatten(shardings) if shardings is not None else {}
        out_flat = {}
        for k, leaf in paths.items():
            a = flat[k]
            if k in shard_flat:
                out_flat[k] = jax.device_put(jnp.asarray(a), shard_flat[k])
            else:
                out_flat[k] = jnp.asarray(a)
        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
        treedef = leaves_with_path[1]
        keys = [
            _SEP.join(
                str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))) for p in path
            )
            for path, _ in leaves_with_path[0]
        ]
        return jax.tree_util.tree_unflatten(treedef, [out_flat[k] for k in keys])

    def restore_latest(self, like: Any = None, shardings: Any = None):
        steps = self.list_steps()
        if not steps:
            return None
        step = steps[-1]
        if like is not None:
            return step, self.restore_like(step, like, shardings)
        return step, self.restore(step)
