"""Paper Figure 5: end-to-end W4A4 throughput speedup over FP16, derived from
the roofline memory/compute terms for LLaMA3-8B on a single TPU v5e chip
(1024-token prefill + 256-token decode, batch-swept) — the same workload the
paper measures on RTX 4090 / L20 GPUs.

Plus a MEASURED section: batched-decode tokens/s through the real
continuous-batching engine (launch/serve.py) at batch sizes {1, 4, 8} on the
small bench model — the end-to-end path (per-slot caches, admission,
sampling), not a model."""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from benchmarks.common import ART, BENCH_CFG, emit

IN_TOK, OUT_TOK = 1024, 256
RANK = 128

ENGINE_BATCHES = (1, 4, 8)
ENGINE_PROMPT, ENGINE_NEW = 32, 32


def _per_token_bytes(cfg, w_bits: int, rank: int) -> float:
    n = cfg.active_params()
    w = n * w_bits / 8
    if w_bits == 4:  # low-rank branch adds r(m+n) 4-bit params per linear
        d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
        per_layer = rank * (
            2 * d + cfg.n_heads * hd + 2 * (d + cfg.n_kv_heads * hd) + 2 * (d + f) + (f + d)
        ) / 2
        w += cfg.n_layers * per_layer
    return w


def _step_time(cfg, m_tokens: int, w_bits: int, kv_len: int, batch: int) -> float:
    n = cfg.active_params()
    flops = 2 * n * m_tokens
    a_bits = 4 if w_bits == 4 else 16
    t_cmp = flops / PEAK_FLOPS * (0.5 if w_bits == 4 else 1.0)  # int8 MXU ~2x bf16
    w_bytes = _per_token_bytes(cfg, w_bits, RANK)
    kv_bytes = 2 * cfg.n_layers * kv_len * batch * cfg.n_kv_heads * cfg.head_dim * 2
    act = m_tokens * cfg.d_model * 12 * cfg.n_layers * (a_bits / 8)
    t_mem = (w_bytes + kv_bytes + act) / HBM_BW
    return max(t_cmp, t_mem)


def run_engine(fused: bool = True) -> dict:
    """Measured batched-decode tokens/s through the continuous-batching
    engine serving the PACKED W4A4 bench model — the full quantized serving
    path (per-slot caches, admission, sampling, dispatch-routed linears).
    Weights are random — throughput is shape-, not value-, bound.

    ``fused=True`` (the default serving configuration) pre-merges sibling
    packs (q/k/v, gate/up) with ``fuse_params`` and leaves trace-time fusion
    on; ``fused=False`` is the A/B lane: unfused packs, fusion disabled.

    Every decode trace must route its quantized linears through the
    decode-shaped kernel schedules — and, when fused, through the FUSED
    decode kind; the dispatch counters are the proof and a hard failure
    here, not a metric. The per-path counter deltas double as the
    kernel-launches-per-traced-step evidence compare.py reports."""
    from repro.configs import QuantSpec
    from repro.core.twinquant import fuse_params, quantize_params
    from repro.kernels.dispatch import set_fusion
    from repro.launch.serve import ContinuousBatchingEngine, Request
    from repro.models import dense

    cfg = BENCH_CFG
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_params(params, cfg, QuantSpec(mode="w4a4", rank=32))
    if fused:
        qparams = fuse_params(qparams)
    prompt = jnp.arange(ENGINE_PROMPT, dtype=jnp.int32) % cfg.vocab
    results = {}
    prev = set_fusion(fused)
    try:
        for b in ENGINE_BATCHES:
            eng = ContinuousBatchingEngine(cfg, qparams, batch_slots=b,
                                           max_len=ENGINE_PROMPT + ENGINE_NEW + 8)
            # warm the prefill/decode executables, then reset the timing
            # counters (routing counters persist — they are trace-time)
            eng.serve([Request(prompt, max_new=2)])
            eng.reset_stats()
            reqs = [Request(prompt, max_new=ENGINE_NEW) for _ in range(2 * b)]
            eng.serve(reqs)
            th = eng.throughput()
            routing = th["routing"]
            if routing.get("dual/decode", 0) == 0:
                raise RuntimeError(
                    f"b={b}: decode trace did not route the decode-shaped kernel "
                    f"(routes: {routing})"
                )
            if fused and routing.get("dual_fused/decode", 0) == 0:
                raise RuntimeError(
                    f"b={b}: fused serving did not route the fused decode kind "
                    f"(routes: {routing})"
                )
            decode_launches = sum(
                v for k, v in routing.items() if k.endswith("/decode")
            )
            results[f"b{b}"] = {
                "decode_tok_s": th["decode_tok_s"],
                "prefill_tok_s": th["prefill_tok_s"],
                "occupancy": th["mean_batch_occupancy"],
                "routing": routing,
                "decode_launches": decode_launches,
            }
            emit(f"throughput/engine_b{b}", 1e6 / max(th["decode_tok_s"], 1e-9),
                 f"decode={th['decode_tok_s']:.1f}tok/s occ={th['mean_batch_occupancy']:.2f}/{b} "
                 f"launches/step={decode_launches} "
                 f"routes=dual/decode:{routing.get('dual/decode', 0)}"
                 f"+dual_fused/decode:{routing.get('dual_fused/decode', 0)}")
    finally:
        set_fusion(prev)
    return results


def _paged_workload(cfg):
    """System-prompt-style traffic: a shared 48-token prefix with mixed-length
    tails, plus a few cold prompts — the workload paging + prefix caching are
    for. Prefix length is page-aligned (48 = 6 pages of 8) so hits map whole
    pages."""
    system = [(7 * i + 3) % cfg.vocab for i in range(48)]
    tails = [2, 5, 9, 14, 3, 7, 11, 6]
    prompts = [system + [(100 + 13 * j + t) % cfg.vocab for t in range(n)]
               for j, n in enumerate(tails)]
    prompts += [[(50 + 5 * t) % cfg.vocab for t in range(n)] for n in (6, 21)]  # cold
    return prompts


def run_paged(fused: bool = True) -> dict:
    """Measured paged-vs-dense serving on the mixed-prompt + shared-prefix
    workload: tokens/s both ways, prefix-cache hit rate, peak cache bytes,
    and a hard tokens-equality check (the paged engine must reproduce the
    dense engine token for token — the A/B oracle, not a tolerance)."""
    from repro.configs import QuantSpec
    from repro.core.twinquant import fuse_params, quantize_params
    from repro.kernels.dispatch import set_fusion
    from repro.launch.serve import ContinuousBatchingEngine, Request

    from repro.models import dense

    cfg = BENCH_CFG
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_params(params, cfg, QuantSpec(mode="w4a4", rank=32))
    if fused:
        qparams = fuse_params(qparams)
    prompts = _paged_workload(cfg)
    max_len, page_size, slots = 96, 8, 4
    # pool sized at 60% of the dense B x S_max row count: the capacity
    # headroom paging buys on short/shared traffic
    n_pages = int(0.6 * slots * (max_len // page_size))
    prev = set_fusion(fused)
    try:
        results = {}
        for mode in ("paged", "dense"):
            kw = dict(paged=True, page_size=page_size, n_pages=n_pages) if mode == "paged" else {}
            eng = ContinuousBatchingEngine(cfg, qparams, batch_slots=slots,
                                           max_len=max_len, **kw)
            reqs = [Request(jnp.asarray(p, jnp.int32), max_new=16) for p in prompts]
            eng.serve(reqs)
            if mode == "paged":
                eng.check_page_invariants()
            th = eng.throughput()
            mem = eng.memory()
            results[mode] = {
                "decode_tok_s": th["decode_tok_s"],
                "prefill_tok_s": th["prefill_tok_s"],
                "prefill_tokens": th["prefill_tokens"],
                "peak_cache_bytes": mem["peak_cache_bytes"],
                "routing": th["routing"],
                "outputs": [r.out for r in reqs],
                "compile": eng.compile_stats(),
            }
            if mode == "paged":
                # fault-tolerance accounting: the healthy lane must serve
                # with zero preemptions/failures — a nonzero count here means
                # the pool sizing or the admission path regressed
                results[mode]["preemptions"] = th["requests_preempted"]
                results[mode]["failures"] = th["requests_failed"]
                results[mode]["prefix_hit_rate"] = (
                    th["prefix_hits"] / max(th["prefix_lookups"], 1)
                )
                results[mode]["prefix_hit_tokens"] = th["prefix_hit_tokens"]
                results[mode]["memory"] = {
                    k: mem[k] for k in ("page_size", "n_pages", "pages_peak",
                                        "cache_bytes", "dense_cache_bytes")
                }
    finally:
        set_fusion(prev)
    pg, dn = results["paged"], results["dense"]
    out = {
        "paged_decode_tok_s": pg["decode_tok_s"],
        "dense_decode_tok_s": dn["decode_tok_s"],
        "paged_prefill_tok_s": pg["prefill_tok_s"],
        "dense_prefill_tok_s": dn["prefill_tok_s"],
        # the prefix cache's work reduction shows up directly here
        "paged_prefill_tokens": pg["prefill_tokens"],
        "dense_prefill_tokens": dn["prefill_tokens"],
        "prefix_hit_rate": pg["prefix_hit_rate"],
        "prefix_hit_tokens": pg["prefix_hit_tokens"],
        "peak_cache_bytes_paged": pg["peak_cache_bytes"],
        "peak_cache_bytes_dense": dn["peak_cache_bytes"],
        "peak_below_dense": pg["peak_cache_bytes"] < dn["peak_cache_bytes"],
        "tokens_match": pg["outputs"] == dn["outputs"],
        "preemptions": pg["preemptions"],
        "failures": pg["failures"],
        "routing": pg["routing"],
        "compile": pg["compile"],
        "memory": pg["memory"],
    }
    if not out["tokens_match"]:
        raise RuntimeError("paged serving diverged from the dense oracle")
    if out["failures"] or out["preemptions"]:
        raise RuntimeError(
            f"healthy paged lane hit {out['failures']} failures / "
            f"{out['preemptions']} preemptions — fault paths must not fire "
            "without injection"
        )
    if out["routing"].get("dual/decode", 0) == 0:
        raise RuntimeError(
            f"paged decode trace did not route the decode-shaped kernel "
            f"(routes: {out['routing']})"
        )
    emit("throughput/paged", 1e6 / max(out["paged_decode_tok_s"], 1e-9),
         f"decode={out['paged_decode_tok_s']:.1f}tok/s "
         f"(dense={out['dense_decode_tok_s']:.1f}) "
         f"hit_rate={out['prefix_hit_rate']:.2f} "
         f"prefill_toks={out['paged_prefill_tokens']}vs{out['dense_prefill_tokens']} "
         f"peak_bytes={out['peak_cache_bytes_paged']}vs{out['peak_cache_bytes_dense']}")
    return out


def run_burst(fused: bool = True) -> dict:
    """Ragged-engine burst lane: 3 steady decoders + 1 long-prompt burst.

    The unified step schedules decode rows FIRST and fills the rest of the
    token budget with prompt chunks, so admitting a long prompt must not
    displace a single decode token — ``min_decode_per_step`` during
    admission equals the steady decoder count (deterministic; a drop is a
    scheduling bug and fails here, not a metric). Wall-clock decode tok/s in
    the admission region vs the steady region (``burst_ratio``) is the flat
    decode-latency claim compare.py gates: one padded launch shape means
    streaming a prompt in costs chunk rows, not extra executables."""
    from repro.configs import QuantSpec
    from repro.core.twinquant import fuse_params, quantize_params
    from repro.kernels.dispatch import set_fusion
    from repro.launch.serve import ContinuousBatchingEngine, Request
    from repro.models import dense

    cfg = BENCH_CFG
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_params(params, cfg, QuantSpec(mode="w4a4", rank=32))
    if fused:
        qparams = fuse_params(qparams)
    prev = set_fusion(fused)
    try:
        eng = ContinuousBatchingEngine(
            cfg, qparams, batch_slots=4, max_len=256, paged=True, page_size=16,
            ragged=True, token_budget=64,
        )
        if not eng.ragged:
            raise RuntimeError("burst lane requires ragged mode (fell back?)")
        steady = [
            Request(jnp.asarray([(11 * k + 5 + t) % cfg.vocab for t in range(8)],
                                jnp.int32), max_new=48)
            for k in range(3)
        ]
        for r in steady:
            eng.submit(r)
        eng.step()  # warmup: prefills all steady prompts, traces the step
        assert all(r._last_logits is not None for r in steady)

        # steady region: decoders only, fixed number of steps
        steady_tokens = []
        t0 = time.monotonic()
        for _ in range(8):
            before = eng.stats["decode_tokens"]
            eng.step()
            steady_tokens.append(eng.stats["decode_tokens"] - before)
        steady_dt = time.monotonic() - t0

        # burst: one long prompt streams in as chunks while decode continues
        burst = Request(
            jnp.asarray([(7 * t + 3) % cfg.vocab for t in range(160)], jnp.int32),
            max_new=8,
        )
        eng.submit(burst)
        burst_tokens = []
        t0 = time.monotonic()
        while burst._last_logits is None:
            before = eng.stats["decode_tokens"]
            eng.step()
            burst_tokens.append(eng.stats["decode_tokens"] - before)
        burst_dt = time.monotonic() - t0
        eng.run_until_done()
        eng.check_page_invariants()
        cs = eng.compile_stats()
    finally:
        set_fusion(prev)

    steady_tok_s = sum(steady_tokens) / max(steady_dt, 1e-9)
    burst_tok_s = sum(burst_tokens) / max(burst_dt, 1e-9)
    out = {
        "steady_decoders": len(steady),
        "steady_decode_tok_s": steady_tok_s,
        "burst_decode_tok_s": burst_tok_s,
        "burst_ratio": burst_tok_s / max(steady_tok_s, 1e-9),
        "admission_steps": len(burst_tokens),
        "min_decode_per_step": min(burst_tokens),
        "decode_per_step_flat": min(burst_tokens) == len(steady),
        "ragged_traces": cs["ragged_traces"],
        "prefill_traces": cs["prefill_traces"],
    }
    if not out["decode_per_step_flat"]:
        raise RuntimeError(
            f"burst admission displaced decode tokens: per-step decode counts "
            f"{burst_tokens} dropped below the {len(steady)} live decoders"
        )
    if cs["ragged_traces"] != 1 or cs["prefill_traces"] != 0:
        raise RuntimeError(
            f"burst lane traced extra executables (compile stats: {cs})"
        )
    emit("throughput/burst", 1e6 / max(burst_tok_s, 1e-9),
         f"decode={burst_tok_s:.1f}tok/s(admission) vs {steady_tok_s:.1f}(steady) "
         f"ratio={out['burst_ratio']:.2f} steps={out['admission_steps']} "
         f"min_decode/step={out['min_decode_per_step']}")
    return out


def run_spec(fused: bool = True) -> dict:
    """Speculative-decoding lane (BENCH_SPEC.json): the b=8 paged engine
    with self-speculative multi-token verification vs the same engine
    without it, on a loop-heavy greedy workload (the regime speculation is
    for: committed history with n-gram structure).

    Hard booleans: greedy speculative output must be TOKEN-IDENTICAL to the
    non-speculative engine (acceptance only ever shortcuts steps the oracle
    would take), every decode launch must route the in-kernel block-table
    attention (kind ``paged_decode`` — no dense ``gather_pages`` view), and
    the whole lifetime must compile exactly ONE (batch, spec_k)-shaped
    speculative executable. ``spec_speedup`` (speculative / plain decode
    tok/s, both measured in the same run, so the ratio is self-relative) is
    the gated metric; ``acceptance_rate`` / ``tokens_per_step`` are the
    mechanism evidence compare.py prints next to it.

    Workload: periodic 32-token prompts (period-4 n-grams), 32 new tokens,
    ``spec_k=2``. The prompts' repeating structure is exactly what the
    n-gram self-draft exploits, so the acceptance rate is deterministic and
    meaningfully high; ``spec_k`` stays at 2 because a CPU runner pays for
    every extra draft row (compute-bound), unlike a memory-bound
    accelerator decode where deeper stacks are nearly free."""
    from repro.configs import QuantSpec
    from repro.core.twinquant import fuse_params, quantize_params
    from repro.kernels.dispatch import set_fusion
    from repro.launch.serve import ContinuousBatchingEngine, Request
    from repro.models import dense

    cfg = BENCH_CFG
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_params(params, cfg, QuantSpec(mode="w4a4", rank=32))
    if fused:
        qparams = fuse_params(qparams)
    b, prompt_len, max_new, page_size, spec_k = 8, 32, 32, 8, 2
    max_len = prompt_len + max_new + 8
    n_pages = b * (-(-max_len // page_size)) + 16
    prompts = [[(13 * j + [3, 57, 91, 140][i % 4]) % cfg.vocab
                for i in range(prompt_len)] for j in range(b)]
    prev = set_fusion(fused)
    try:
        results = {}
        for mode in ("spec", "plain"):
            kw = dict(speculation=True, spec_k=spec_k) if mode == "spec" else {}
            eng = ContinuousBatchingEngine(
                cfg, qparams, batch_slots=b, max_len=max_len, paged=True,
                page_size=page_size, n_pages=n_pages, **kw,
            )
            # warm the executables, then reset the timing counters
            eng.serve([Request(jnp.asarray(prompts[0], jnp.int32), max_new=2)])
            eng.reset_stats()
            reqs = [Request(jnp.asarray(p, jnp.int32), max_new=max_new)
                    for p in prompts]
            eng.serve(reqs)
            th = eng.throughput()
            results[mode] = {
                "decode_tok_s": th["decode_tok_s"],
                "acceptance_rate": th["acceptance_rate"],
                "tokens_per_step": th["tokens_per_step"],
                "routing": th["routing"],
                "outputs": [r.out for r in reqs],
                "compile": eng.compile_stats(),
            }
    finally:
        set_fusion(prev)
    sp, pl = results["spec"], results["plain"]
    out = {
        "batch": b,
        "spec_k": spec_k,
        "max_new": max_new,
        "spec_decode_tok_s": sp["decode_tok_s"],
        "plain_decode_tok_s": pl["decode_tok_s"],
        "spec_speedup": sp["decode_tok_s"] / max(pl["decode_tok_s"], 1e-9),
        "acceptance_rate": sp["acceptance_rate"],
        "tokens_per_step": sp["tokens_per_step"],
        "tokens_match": sp["outputs"] == pl["outputs"],
        "spec_traces": sp["compile"]["spec_traces"],
        "decode_traces": sp["compile"]["decode_traces"],
        "routing": sp["routing"],
    }
    if not out["tokens_match"]:
        raise RuntimeError(
            "speculative serving diverged from the non-speculative oracle"
        )
    if out["routing"].get("paged_decode/kernel", 0) == 0:
        raise RuntimeError(
            f"speculative decode did not route the in-kernel paged attention "
            f"(routes: {out['routing']})"
        )
    if out["spec_traces"] != 1:
        raise RuntimeError(
            f"speculative lane traced {out['spec_traces']} executables "
            "(the (batch, spec_k) launch shape is static)"
        )
    emit("throughput/spec", 1e6 / max(out["spec_decode_tok_s"], 1e-9),
         f"decode={out['spec_decode_tok_s']:.1f}tok/s "
         f"(plain={out['plain_decode_tok_s']:.1f}) "
         f"speedup={out['spec_speedup']:.2f}x "
         f"accept={out['acceptance_rate']:.2f} "
         f"tok/step={out['tokens_per_step']:.2f}")
    return out


def run(quick: bool = False, fused: bool = True, paged: bool = False,
        burst: bool = False, spec: bool = False, slo: bool = False) -> dict:
    """``quick=True`` (the CI bench lane) runs only the measured engine
    sweep — the gated metrics; the full run adds the derived roofline grid.
    ``fused`` toggles horizontal projection fusion for the engine sweep;
    ``paged`` adds the paged-vs-dense mixed-prompt workload (the
    BENCH_PAGED.json lane); ``burst`` the ragged long-prompt-admission lane
    (BENCH_BURST.json); ``spec`` the speculative-decoding lane
    (BENCH_SPEC.json); ``slo`` the trace-driven tail-latency lane
    (BENCH_SLO.json, benchmarks/bench_slo.py)."""
    if quick:
        # the paged/burst/spec/slo quick lanes are single-purpose: the
        # b{1,4,8} engine sweep already ran (and was gated) in the BENCH_PR
        # lane, and re-gating a duplicate sweep would double the exposure to
        # machine-noise one-offs
        if paged:
            return {"paged": run_paged(fused=fused), "fused": fused}
        if burst:
            return {"burst": run_burst(fused=fused), "fused": fused}
        if spec:
            return {"spec": run_spec(fused=fused), "fused": fused}
        if slo:
            from benchmarks.bench_slo import run_slo

            return {"slo": run_slo(fused=fused), "fused": fused}
        return {"engine_measured": run_engine(fused=fused), "fused": fused}
    cfg = get_config("llama3-8b")
    results = {}
    t0 = time.monotonic()
    for b in (1, 2, 4, 8, 16):
        def e2e(bits):
            t = _step_time(cfg, b * IN_TOK, bits, IN_TOK, b)  # prefill
            for i in range(0, OUT_TOK, 32):  # decode, sampled
                t += 32 * _step_time(cfg, b, bits, IN_TOK + i, b)
            return t

        t16, t4 = e2e(16), e2e(4)
        # Amdahl adjustment: ~25% of serving time is non-GEMM work that
        # quantization does not touch (attention softmax, norms, sampling,
        # host logic) — typical decode profile fraction
        OV = 0.25
        adj = 1.0 / (OV + (1 - OV) * t4 / t16)
        results[f"b{b}"] = {
            "fp16_tok_s": b * OUT_TOK / t16,
            "w4a4_tok_s": b * OUT_TOK / t4,
            "speedup_roofline": t16 / t4,
            "speedup": adj,
        }
    dt = time.monotonic() - t0
    engine = run_engine(fused=fused)
    out = {"roofline": results, "engine_measured": engine, "fused": fused}
    if paged:
        out["paged"] = run_paged(fused=fused)
    if burst:
        out["burst"] = run_burst(fused=fused)
    if spec:
        out["spec"] = run_spec(fused=fused)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "bench_throughput.json").write_text(json.dumps(out, indent=2))
    for k, v in results.items():
        emit(f"throughput/{k}", dt * 1e6 / len(results),
             f"speedup={v['speedup']:.2f}x(amdahl-adj;roofline="
             f"{v['speedup_roofline']:.2f}x;paper:1.63-1.8x)")
    return out


if __name__ == "__main__":
    run()
