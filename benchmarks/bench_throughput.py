"""Paper Figure 5: end-to-end W4A4 throughput speedup over FP16, derived from
the roofline memory/compute terms for LLaMA3-8B on a single TPU v5e chip
(1024-token prefill + 256-token decode, batch-swept) — the same workload the
paper measures on RTX 4090 / L20 GPUs."""

from __future__ import annotations

import json
import time

from repro.configs import get_config
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from benchmarks.common import ART, emit

IN_TOK, OUT_TOK = 1024, 256
RANK = 128


def _per_token_bytes(cfg, w_bits: int, rank: int) -> float:
    n = cfg.active_params()
    w = n * w_bits / 8
    if w_bits == 4:  # low-rank branch adds r(m+n) 4-bit params per linear
        d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
        per_layer = rank * (2 * d + cfg.n_heads * hd + 2 * (d + cfg.n_kv_heads * hd) + 2 * (d + f) + (f + d)) / 2
        w += cfg.n_layers * per_layer
    return w


def _step_time(cfg, m_tokens: int, w_bits: int, kv_len: int, batch: int) -> float:
    n = cfg.active_params()
    flops = 2 * n * m_tokens
    a_bits = 4 if w_bits == 4 else 16
    t_cmp = flops / PEAK_FLOPS * (0.5 if w_bits == 4 else 1.0)  # int8 MXU ~2x bf16
    w_bytes = _per_token_bytes(cfg, w_bits, RANK)
    kv_bytes = 2 * cfg.n_layers * kv_len * batch * cfg.n_kv_heads * cfg.head_dim * 2
    act = m_tokens * cfg.d_model * 12 * cfg.n_layers * (a_bits / 8)
    t_mem = (w_bytes + kv_bytes + act) / HBM_BW
    return max(t_cmp, t_mem)


def run() -> dict:
    cfg = get_config("llama3-8b")
    results = {}
    t0 = time.monotonic()
    for b in (1, 2, 4, 8, 16):
        def e2e(bits):
            t = _step_time(cfg, b * IN_TOK, bits, IN_TOK, b)  # prefill
            for i in range(0, OUT_TOK, 32):  # decode, sampled
                t += 32 * _step_time(cfg, b, bits, IN_TOK + i, b)
            return t

        t16, t4 = e2e(16), e2e(4)
        # Amdahl adjustment: ~25% of serving time is non-GEMM work that
        # quantization does not touch (attention softmax, norms, sampling,
        # host logic) — typical decode profile fraction
        OV = 0.25
        adj = 1.0 / (OV + (1 - OV) * t4 / t16)
        results[f"b{b}"] = {
            "fp16_tok_s": b * OUT_TOK / t16,
            "w4a4_tok_s": b * OUT_TOK / t4,
            "speedup_roofline": t16 / t4,
            "speedup": adj,
        }
    dt = time.monotonic() - t0
    (ART / "bench_throughput.json").write_text(json.dumps(results, indent=2))
    for k, v in results.items():
        emit(f"throughput/{k}", dt * 1e6 / len(results),
             f"speedup={v['speedup']:.2f}x(amdahl-adj;roofline={v['speedup_roofline']:.2f}x;paper:1.63-1.8x)")
    return results


if __name__ == "__main__":
    run()
