"""Paper Figure 5: end-to-end W4A4 throughput speedup over FP16, derived from
the roofline memory/compute terms for LLaMA3-8B on a single TPU v5e chip
(1024-token prefill + 256-token decode, batch-swept) — the same workload the
paper measures on RTX 4090 / L20 GPUs.

Plus a MEASURED section: batched-decode tokens/s through the real
continuous-batching engine (launch/serve.py) at batch sizes {1, 4, 8} on the
small bench model — the end-to-end path (per-slot caches, admission,
sampling), not a model."""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from benchmarks.common import ART, BENCH_CFG, emit

IN_TOK, OUT_TOK = 1024, 256
RANK = 128

ENGINE_BATCHES = (1, 4, 8)
ENGINE_PROMPT, ENGINE_NEW = 32, 32


def _per_token_bytes(cfg, w_bits: int, rank: int) -> float:
    n = cfg.active_params()
    w = n * w_bits / 8
    if w_bits == 4:  # low-rank branch adds r(m+n) 4-bit params per linear
        d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
        per_layer = rank * (2 * d + cfg.n_heads * hd + 2 * (d + cfg.n_kv_heads * hd) + 2 * (d + f) + (f + d)) / 2
        w += cfg.n_layers * per_layer
    return w


def _step_time(cfg, m_tokens: int, w_bits: int, kv_len: int, batch: int) -> float:
    n = cfg.active_params()
    flops = 2 * n * m_tokens
    a_bits = 4 if w_bits == 4 else 16
    t_cmp = flops / PEAK_FLOPS * (0.5 if w_bits == 4 else 1.0)  # int8 MXU ~2x bf16
    w_bytes = _per_token_bytes(cfg, w_bits, RANK)
    kv_bytes = 2 * cfg.n_layers * kv_len * batch * cfg.n_kv_heads * cfg.head_dim * 2
    act = m_tokens * cfg.d_model * 12 * cfg.n_layers * (a_bits / 8)
    t_mem = (w_bytes + kv_bytes + act) / HBM_BW
    return max(t_cmp, t_mem)


def run_engine(fused: bool = True) -> dict:
    """Measured batched-decode tokens/s through the continuous-batching
    engine serving the PACKED W4A4 bench model — the full quantized serving
    path (per-slot caches, admission, sampling, dispatch-routed linears).
    Weights are random — throughput is shape-, not value-, bound.

    ``fused=True`` (the default serving configuration) pre-merges sibling
    packs (q/k/v, gate/up) with ``fuse_params`` and leaves trace-time fusion
    on; ``fused=False`` is the A/B lane: unfused packs, fusion disabled.

    Every decode trace must route its quantized linears through the
    decode-shaped kernel schedules — and, when fused, through the FUSED
    decode kind; the dispatch counters are the proof and a hard failure
    here, not a metric. The per-path counter deltas double as the
    kernel-launches-per-traced-step evidence compare.py reports."""
    from repro.configs import QuantSpec
    from repro.core.twinquant import fuse_params, quantize_params
    from repro.kernels.dispatch import set_fusion
    from repro.launch.serve import ContinuousBatchingEngine, Request
    from repro.models import dense

    cfg = BENCH_CFG
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    qparams = quantize_params(params, cfg, QuantSpec(mode="w4a4", rank=32))
    if fused:
        qparams = fuse_params(qparams)
    prompt = jnp.arange(ENGINE_PROMPT, dtype=jnp.int32) % cfg.vocab
    results = {}
    prev = set_fusion(fused)
    try:
        for b in ENGINE_BATCHES:
            eng = ContinuousBatchingEngine(cfg, qparams, batch_slots=b,
                                           max_len=ENGINE_PROMPT + ENGINE_NEW + 8)
            # warm the prefill/decode executables, then reset the timing
            # counters (routing counters persist — they are trace-time)
            eng.serve([Request(prompt, max_new=2)])
            eng.reset_stats()
            reqs = [Request(prompt, max_new=ENGINE_NEW) for _ in range(2 * b)]
            eng.serve(reqs)
            th = eng.throughput()
            routing = th["routing"]
            if routing.get("dual/decode", 0) == 0:
                raise RuntimeError(
                    f"b={b}: decode trace did not route the decode-shaped kernel "
                    f"(routes: {routing})"
                )
            if fused and routing.get("dual_fused/decode", 0) == 0:
                raise RuntimeError(
                    f"b={b}: fused serving did not route the fused decode kind "
                    f"(routes: {routing})"
                )
            decode_launches = sum(
                v for k, v in routing.items() if k.endswith("/decode")
            )
            results[f"b{b}"] = {
                "decode_tok_s": th["decode_tok_s"],
                "prefill_tok_s": th["prefill_tok_s"],
                "occupancy": th["mean_batch_occupancy"],
                "routing": routing,
                "decode_launches": decode_launches,
            }
            emit(f"throughput/engine_b{b}", 1e6 / max(th["decode_tok_s"], 1e-9),
                 f"decode={th['decode_tok_s']:.1f}tok/s occ={th['mean_batch_occupancy']:.2f}/{b} "
                 f"launches/step={decode_launches} "
                 f"routes=dual/decode:{routing.get('dual/decode', 0)}"
                 f"+dual_fused/decode:{routing.get('dual_fused/decode', 0)}")
    finally:
        set_fusion(prev)
    return results


def run(quick: bool = False, fused: bool = True) -> dict:
    """``quick=True`` (the CI bench lane) runs only the measured engine
    sweep — the gated metrics; the full run adds the derived roofline grid.
    ``fused`` toggles horizontal projection fusion for the engine sweep."""
    if quick:
        return {"engine_measured": run_engine(fused=fused), "fused": fused}
    cfg = get_config("llama3-8b")
    results = {}
    t0 = time.monotonic()
    for b in (1, 2, 4, 8, 16):
        def e2e(bits):
            t = _step_time(cfg, b * IN_TOK, bits, IN_TOK, b)  # prefill
            for i in range(0, OUT_TOK, 32):  # decode, sampled
                t += 32 * _step_time(cfg, b, bits, IN_TOK + i, b)
            return t

        t16, t4 = e2e(16), e2e(4)
        # Amdahl adjustment: ~25% of serving time is non-GEMM work that
        # quantization does not touch (attention softmax, norms, sampling,
        # host logic) — typical decode profile fraction
        OV = 0.25
        adj = 1.0 / (OV + (1 - OV) * t4 / t16)
        results[f"b{b}"] = {
            "fp16_tok_s": b * OUT_TOK / t16,
            "w4a4_tok_s": b * OUT_TOK / t4,
            "speedup_roofline": t16 / t4,
            "speedup": adj,
        }
    dt = time.monotonic() - t0
    engine = run_engine(fused=fused)
    out = {"roofline": results, "engine_measured": engine, "fused": fused}
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "bench_throughput.json").write_text(json.dumps(out, indent=2))
    for k, v in results.items():
        emit(f"throughput/{k}", dt * 1e6 / len(results),
             f"speedup={v['speedup']:.2f}x(amdahl-adj;roofline={v['speedup_roofline']:.2f}x;paper:1.63-1.8x)")
    return out


if __name__ == "__main__":
    run()
