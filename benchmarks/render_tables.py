"""Render the EXPERIMENTS.md roofline + accuracy tables from artifacts."""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DRY = ROOT / "artifacts" / "dryrun"

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def roofline_table() -> str:
    rows = [
        "| arch | shape | dominant | compute s | memory s | collective s | "
        "useful-FLOPs | MFU-bound | fits/device |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for p in sorted(DRY.glob("*__single__bf16.json")):
        d = json.loads(p.read_text())
        if d["status"] == "skip":
            rows.append(
                f"| {d['arch']} | {d['shape']} | — | — | — | — | — | — | "
                f"skip: sub-quadratic-only shape |"
            )
            continue
        if d["status"] != "ok":
            rows.append(f"| {d['arch']} | {d['shape']} | FAIL | | | | | | |")
            continue
        r = d["roofline"]
        uf = r.get("useful_flops_ratio")
        rf = r.get("roofline_fraction")
        mem = d.get("memory_analysis", {})
        arg_gb = (mem.get("argument_size_in_bytes") or 0) / 1e9
        rows.append(
            f"| {d['arch']} | {d['shape']} | {r['dominant']} | "
            f"{r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} | "
            f"{r['t_collective_s']:.3f} | "
            f"{uf:.2f} | {rf:.4f} | args {arg_gb:.2f} GB |"
            if uf is not None and rf is not None
            else f"| {d['arch']} | {d['shape']} | {r['dominant']} | "
            f"{r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} | "
            f"{r['t_collective_s']:.3f} | — | — | args {arg_gb:.2f} GB |"
        )
    # quantized cells appendix
    qrows = []
    for p in sorted(DRY.glob("*__single__w4a*.json")):
        d = json.loads(p.read_text())
        if d["status"] != "ok":
            continue
        r = d["roofline"]
        qrows.append(
            f"| {d['arch']} | {d['shape']} ({d['quant']}) | {r['dominant']} | "
            f"{r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} | "
            f"{r['t_collective_s']:.3f} | — | — | packed params "
            f"{d['param_bytes_global']/1e9:.1f} GB global |"
        )
    return "\n".join(rows + qrows)


def accuracy_table() -> str:
    out = []
    acc = ROOT / "artifacts" / "bench_accuracy.json"
    if acc.exists():
        d = json.loads(acc.read_text())
        out.append("Held-out PPL (4L/256d LM trained on the in-repo byte corpus), "
                   "rank 32, group 128 — Table 3 analogue:\n")
        out.append("| variant | ppl |")
        out.append("|---|---|")
        for k, v in d.items():
            out.append(f"| {k} | {v:.3f} |")
    rank = ROOT / "artifacts" / "bench_rank.json"
    if rank.exists():
        d = json.loads(rank.read_text())
        out.append("\nRank sensitivity (Table 2 / Fig 6 analogue):\n")
        out.append("| rank | ppl | low-rank mem overhead |")
        out.append("|---|---|---|")
        for k, v in d.items():
            out.append(f"| {k} | {v['ppl']:.3f} | {v['mem_overhead']*100:.1f}% |")
    err = ROOT / "artifacts" / "bench_error_analysis.json"
    if err.exists():
        d = json.loads(err.read_text())
        f7 = d.get("fig7", {})
        out.append(
            f"\nLayer-level (Fig 7 / Thm 4.1): learned-vs-SVD error reduction "
            f"{f7.get('reduction', 0):.2f}x; zeta={f7.get('zeta_gain', 0):.2f}, "
            f"eta={f7.get('eta_gain', 0):.2f}; sv decay s32/s0="
            f"{d.get('sv_decay', {}).get('s32_over_s0', 0):.3f}."
        )
    if not out:
        return "(run `python -m benchmarks.run accuracy rank error_analysis`)"
    return "\n".join(out)


def main() -> None:
    exp = ROOT / "EXPERIMENTS.md"
    t = exp.read_text()
    t = t.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    t = t.replace("<!-- ACCURACY_TABLE -->", accuracy_table())
    exp.write_text(t)
    print("tables rendered into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
