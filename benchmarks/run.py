"""Benchmark harness entrypoint: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (run.py contract). JSON
artifacts land in artifacts/ for EXPERIMENTS.md.

  bench_accuracy   — Tables 1/3: PPL under fp16/naive/+lowrank/+hadamard/TwinQuant
  bench_rank       — Table 2 / Fig 6: rank sensitivity + overhead
  bench_kernels    — Tables 6/7: fused dual-component kernel (derived + exactness)
  bench_throughput — Figure 5: end-to-end W4A4 vs FP16 speedup (derived)
  bench_error_analysis — Figs 1/2/7 + Thm 4.1 gains
  bench_roofline   — §Roofline table from dry-run artifacts

``--quick`` is the CI bench lane: the small-shape interpret-mode kernel
checks plus the measured serving-engine throughput sweep (no model
training), with the combined results written to ``--out`` (BENCH_PR.json)
for benchmarks/compare.py to gate against benchmarks/baseline.json.

``--fused`` (default) / ``--no-fused`` toggles horizontal projection fusion
(q/k/v and gate/up as one launch) for the throughput sweep; CI uploads one
artifact per setting so the fusion speedup is visible in the artifact trail.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

QUICK_MODULES = ("kernels", "throughput")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("modules", nargs="*", help="subset of benchmark modules to run")
    ap.add_argument(
        "--quick", action="store_true",
        help="CI bench lane: kernels + serving-engine throughput only",
    )
    ap.add_argument(
        "--fused", dest="fused", action="store_true", default=True,
        help="fuse sibling projections (q/k/v, gate/up) into one launch (default)",
    )
    ap.add_argument(
        "--no-fused", dest="fused", action="store_false",
        help="A/B lane: per-sibling launches (the pre-fusion serving path)",
    )
    ap.add_argument(
        "--paged", action="store_true",
        help="add the paged-serving lane (mixed-prompt + shared-prefix "
             "workload, paged vs dense engines) to the throughput module — "
             "the BENCH_PAGED.json artifact",
    )
    ap.add_argument(
        "--burst", action="store_true",
        help="add the ragged burst lane (steady decoders + long-prompt "
             "admission through the unified ragged step) to the throughput "
             "module — the BENCH_BURST.json artifact",
    )
    ap.add_argument(
        "--spec", action="store_true",
        help="add the speculative-decoding lane (self-drafted multi-token "
             "verification through the in-kernel paged decode attention, "
             "spec vs plain engines) to the throughput module — the "
             "BENCH_SPEC.json artifact",
    )
    ap.add_argument(
        "--slo", action="store_true",
        help="add the trace-driven SLO lane (seeded production workload "
             "through the ragged preemptive engine: TTFT/TPOT percentiles, "
             "goodput under SLO, solo-oracle token equality, knob sweep) to "
             "the throughput module — the BENCH_SLO.json artifact",
    )
    ap.add_argument("--out", default=None, help="write combined results JSON here")
    args = ap.parse_args()

    from benchmarks import (
        bench_accuracy,
        bench_error_analysis,
        bench_kernels,
        bench_rank,
        bench_roofline,
        bench_throughput,
    )

    mods = {
        "kernels": bench_kernels,
        "throughput": bench_throughput,
        "error_analysis": bench_error_analysis,
        "accuracy": bench_accuracy,
        "rank": bench_rank,
        "roofline": bench_roofline,
    }
    if args.quick:
        selected = list(QUICK_MODULES)
    else:
        selected = args.modules or list(mods)
    print("name,us_per_call,derived")
    results, failed = {}, []
    for name in selected:
        try:
            if name == "throughput":
                results[name] = mods[name].run(quick=args.quick, fused=args.fused,
                                               paged=args.paged, burst=args.burst,
                                               spec=args.spec, slo=args.slo)
            elif name in QUICK_MODULES:
                results[name] = mods[name].run(quick=args.quick)
            else:
                results[name] = mods[name].run()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if args.out:
        doc = {"schema": 1, "quick": args.quick, "fused": args.fused, "results": results}
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"# wrote {args.out}", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
