"""Benchmark harness entrypoint: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (run.py contract). JSON
artifacts land in artifacts/ for EXPERIMENTS.md.

  bench_accuracy   — Tables 1/3: PPL under fp16/naive/+lowrank/+hadamard/TwinQuant
  bench_rank       — Table 2 / Fig 6: rank sensitivity + overhead
  bench_kernels    — Tables 6/7: fused dual-component kernel (derived + exactness)
  bench_throughput — Figure 5: end-to-end W4A4 vs FP16 speedup (derived)
  bench_error_analysis — Figs 1/2/7 + Thm 4.1 gains
  bench_roofline   — §Roofline table from dry-run artifacts
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_accuracy,
        bench_error_analysis,
        bench_kernels,
        bench_rank,
        bench_roofline,
        bench_throughput,
    )

    mods = {
        "kernels": bench_kernels,
        "throughput": bench_throughput,
        "error_analysis": bench_error_analysis,
        "accuracy": bench_accuracy,
        "rank": bench_rank,
        "roofline": bench_roofline,
    }
    selected = sys.argv[1:] or list(mods)
    print("name,us_per_call,derived")
    failed = []
    for name in selected:
        try:
            mods[name].run()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
