"""CI benchmark gate: fail when serving throughput regresses vs the baseline.

Usage::

    python benchmarks/compare.py benchmarks/baseline.json BENCH_PR.json \
        --max-regress 0.25

Both files are ``benchmarks/run.py --quick --out`` outputs (schema 1). Gated
metrics are the measured continuous-batching engine decode AND prefill
tokens/s at each batch size; the PR fails when any drops more than
``--max-regress`` (fraction) below the committed baseline. Two
machine-independent checks always fail hard:

* **routing** — every engine decode sweep must have routed the decode-shaped
  kernel, and (when the candidate ran fused, the default) the FUSED decode
  kind ``dual_fused/decode``;
* **kernel launches** — the candidate's decode-trace launch count (sum of
  ``*/decode`` dispatch counters: quantized-linear calls per traced decode
  step) must not exceed the baseline's. This is the fusion ratchet: q/k/v
  and gate/up stay one launch each.

A candidate carrying a ``paged`` throughput section (the ``--paged`` lane,
BENCH_PAGED.json) additionally gets the paged gate (``check_paged``):
dense-oracle token equality, decode-kernel routing, prefix-cache hits, and
peak-bytes-below-dense fail hard (the committed baseline carries a ``paged``
section, so the gate is armed); ``paged_decode_tok_s`` is gated by
``--max-regress`` and ``prefix_hit_rate`` is a ratchet against the baseline
rate. While the baseline's paged section carries ``"bootstrap": true`` the
tok/s comparison reports as a warning only (DESIGN.md §12).

A candidate carrying a ``burst`` section (BENCH_BURST.json) gets the ragged
burst gate (``check_burst``), and one carrying a ``spec`` section
(BENCH_SPEC.json) the speculative-decoding gate (``check_spec``):
oracle token equality, ``paged_decode`` routing, and the single speculative
trace fail hard; the spec decode rate must reach 1.3x the committed b8
baseline and the deterministic acceptance rate is a ratchet.

A candidate carrying an ``slo`` section (the ``--slo`` lane, BENCH_SLO.json)
gets the traffic-harness gate (``check_slo``): solo-oracle token equality
and metric PRESENCE (TTFT/TPOT/e2e percentiles with non-empty samples,
goodput under SLO, queue depth, preemption and prefix-hit rates) fail hard,
as does the deterministic prefix-hit ratchet; the tail-latency ratchets
(ttft/tpot p95 up, goodput down, vs ``--max-regress``) warn while the
baseline slo section carries ``"bootstrap": true``.

The per-path launch counts (fused vs unfused kinds) are printed for every
batch size, so the artifact trail shows where each launch went, not just the
tokens/s number.

Baseline refresh procedure (DESIGN.md §12): download the ``BENCH_PR.json``
artifact from a green run ON THE CI RUNNER CLASS and commit it as
``benchmarks/baseline.json`` — never regenerate it on a dev machine, since
the gate compares absolute tokens/s.

A baseline carrying ``"bootstrap": true`` (a dev-machine seed, whose absolute
numbers don't transfer to the CI runner class) downgrades throughput
regressions to warnings; the machine-independent routing and launch-count
checks still fail hard. Promoting a CI-produced ``BENCH_PR.json`` (which
never carries the flag) arms the full gate automatically.
"""

from __future__ import annotations

import argparse
import json
import sys


def _engine(doc: dict) -> dict:
    return doc["results"]["throughput"]["engine_measured"]


def engine_metrics(doc: dict) -> dict[str, float]:
    out = {}
    for b, v in sorted(_engine(doc).items()):
        out[f"decode_tok_s/{b}"] = v["decode_tok_s"]
        if "prefill_tok_s" in v:
            out[f"prefill_tok_s/{b}"] = v["prefill_tok_s"]
    return out


def decode_launches(v: dict) -> int:
    """Quantized-linear launches in the decode trace(s) of one engine sweep."""
    if "decode_launches" in v:
        return int(v["decode_launches"])
    return sum(n for k, n in v.get("routing", {}).items() if k.endswith("/decode"))


def check_routing(doc: dict) -> list[str]:
    errors = []
    fused = doc.get("fused", doc["results"]["throughput"].get("fused", False))
    for b, v in sorted(_engine(doc).items()):
        routing = v.get("routing", {})
        if routing.get("dual/decode", 0) == 0:
            errors.append(f"{b}: decode sweep did not route the decode-shaped kernel")
        if fused and routing.get("dual_fused/decode", 0) == 0:
            errors.append(f"{b}: fused candidate did not route dual_fused/decode")
    return errors


def check_paged(
    base: dict, cand: dict, max_regress: float = 0.25
) -> tuple[list[str], list[str]]:
    """Paged-lane gate: the paged engine must have reproduced the dense
    oracle token for token, routed the decode-shaped kernel, actually hit the
    prefix cache, and kept peak cache bytes under the dense footprint —
    those are machine-independent booleans and always fail hard once a
    baseline carrying a ``paged`` section exists (it does; DESIGN.md §12).

    Against that baseline the lane also gates throughput: ``paged_decode_tok_s``
    may not drop more than ``max_regress`` below the baseline, and
    ``prefix_hit_rate`` may not fall below the baseline's rate (the workload
    is deterministic, so the hit rate is a ratchet, not a measurement). A
    baseline paged section carrying ``"bootstrap": true`` (dev-machine seed)
    downgrades only the tok/s comparison to a warning; promoting a
    CI-produced artifact arms it."""
    pg = cand.get("results", {}).get("throughput", {}).get("paged")
    if pg is None:
        return [], []
    issues = []
    if not pg.get("tokens_match", False):
        issues.append("paged: outputs diverged from the dense serving oracle")
    if pg.get("routing", {}).get("dual/decode", 0) == 0:
        issues.append("paged: decode sweep did not route the decode-shaped kernel")
    if pg.get("prefix_hit_rate", 0) <= 0:
        issues.append("paged: prefix cache never hit on the shared-prefix workload")
    if not pg.get("peak_below_dense", False):
        issues.append("paged: peak cache bytes not below the dense footprint")
    print(f"\n{'paged lane':<24} decode={pg.get('paged_decode_tok_s', 0):.1f}tok/s "
          f"(dense={pg.get('dense_decode_tok_s', 0):.1f}) "
          f"hit_rate={pg.get('prefix_hit_rate', 0):.2f} "
          f"prefill_toks={pg.get('paged_prefill_tokens')}vs{pg.get('dense_prefill_tokens')} "
          f"peak_bytes={pg.get('peak_cache_bytes_paged')}vs{pg.get('peak_cache_bytes_dense')}")
    bpg = base.get("results", {}).get("throughput", {}).get("paged")
    if bpg is None:
        return [], issues  # no baseline section: everything stays a warning
    warns = []
    bootstrap = bool(bpg.get("bootstrap"))
    bv, cv = bpg.get("paged_decode_tok_s", 0.0), pg.get("paged_decode_tok_s", 0.0)
    if bv > 0 and cv < bv * (1.0 - max_regress):
        msg = f"paged: decode {cv:.1f}tok/s < baseline {bv:.1f} * (1 - {max_regress:.2f})"
        (warns if bootstrap else issues).append(msg)
    bh, ch = bpg.get("prefix_hit_rate", 0.0), pg.get("prefix_hit_rate", 0.0)
    if ch < bh:
        issues.append(
            f"paged: prefix hit rate {ch:.2f} fell below baseline {bh:.2f} "
            "(deterministic workload — prefix caching regressed)"
        )
    return issues, warns


def check_burst(
    base: dict, cand: dict, min_ratio: float = 0.8
) -> tuple[list[str], list[str]]:
    """Burst-lane gate (BENCH_BURST.json): the ragged engine's decode rate
    must stay flat while a long prompt streams in. Two machine-independent
    booleans always fail hard — per-step decode counts never dropped below
    the live decoder count during admission, and the whole lifetime compiled
    exactly one ragged executable. ``burst_ratio`` (admission decode tok/s /
    steady decode tok/s, both measured in the same run so the comparison is
    self-relative) must stay >= ``min_ratio``; while the baseline's burst
    section carries ``"bootstrap": true`` that check warns instead of
    failing (same promotion procedure as the paged lane, DESIGN.md §12)."""
    bu = cand.get("results", {}).get("throughput", {}).get("burst")
    if bu is None:
        return [], []
    issues, warns = [], []
    if not bu.get("decode_per_step_flat", False):
        issues.append(
            "burst: long-prompt admission displaced decode tokens "
            f"(min {bu.get('min_decode_per_step')}/step with "
            f"{bu.get('steady_decoders')} live decoders)"
        )
    if bu.get("ragged_traces", 0) != 1 or bu.get("prefill_traces", 0) != 0:
        issues.append(
            f"burst: expected exactly one ragged executable, got "
            f"ragged={bu.get('ragged_traces')} prefill={bu.get('prefill_traces')}"
        )
    print(f"\n{'burst lane':<24} decode={bu.get('burst_decode_tok_s', 0):.1f}tok/s"
          f"(admission) vs {bu.get('steady_decode_tok_s', 0):.1f}(steady) "
          f"ratio={bu.get('burst_ratio', 0):.2f} "
          f"steps={bu.get('admission_steps')} "
          f"min_decode/step={bu.get('min_decode_per_step')}")
    bburst = base.get("results", {}).get("throughput", {}).get("burst")
    bootstrap = bburst is None or bool(bburst.get("bootstrap"))
    if bu.get("burst_ratio", 0.0) < min_ratio:
        msg = (f"burst: admission decode rate ratio "
               f"{bu.get('burst_ratio', 0.0):.2f} < {min_ratio:.2f} "
               "(decode latency not flat under chunked prefill)")
        (warns if bootstrap else issues).append(msg)
    return issues, warns


def check_spec(
    base: dict, cand: dict, min_speedup: float = 1.3
) -> tuple[list[str], list[str]]:
    """Speculative-decoding gate (BENCH_SPEC.json): three machine-independent
    booleans always fail hard — greedy speculative output token-identical to
    the non-speculative engine, the decode path routed through the in-kernel
    ``paged_decode`` block-table attention, and exactly ONE
    (batch, spec_k)-shaped speculative executable for the whole lifetime.
    The deterministic workload makes ``acceptance_rate`` a ratchet against
    the baseline's rate (a drop means the draft or acceptance logic
    regressed, not the machine).

    The throughput claim: speculative b8 decode tok/s must reach
    ``min_speedup`` x the committed baseline's b8 engine decode rate — the
    same absolute-tok/s comparison the main engine sweep gates, so it is
    armed under the same conditions (a baseline spec section carrying
    ``"bootstrap": true`` downgrades it to a warning, DESIGN.md §12)."""
    sp = cand.get("results", {}).get("throughput", {}).get("spec")
    if sp is None:
        return [], []
    issues, warns = [], []
    if not sp.get("tokens_match", False):
        issues.append("spec: outputs diverged from the non-speculative oracle")
    if sp.get("routing", {}).get("paged_decode/kernel", 0) == 0:
        issues.append(
            "spec: decode did not route the in-kernel paged attention "
            f"(routes: {sp.get('routing')})"
        )
    if sp.get("spec_traces", 0) != 1:
        issues.append(
            f"spec: expected exactly one speculative executable, got "
            f"spec_traces={sp.get('spec_traces')}"
        )
    print(f"\n{'spec lane':<24} decode={sp.get('spec_decode_tok_s', 0):.1f}tok/s "
          f"(plain={sp.get('plain_decode_tok_s', 0):.1f}) "
          f"accept={sp.get('acceptance_rate', 0):.2f} "
          f"tok/step={sp.get('tokens_per_step', 0):.2f} "
          f"k={sp.get('spec_k')} b={sp.get('batch')}")
    bspec = base.get("results", {}).get("throughput", {}).get("spec")
    bootstrap = bspec is None or bool(bspec.get("bootstrap"))
    b8 = base.get("results", {}).get("throughput", {}) \
             .get("engine_measured", {}).get("b8", {}).get("decode_tok_s", 0.0)
    cv = sp.get("spec_decode_tok_s", 0.0)
    if b8 > 0 and cv < b8 * min_speedup:
        msg = (f"spec: decode {cv:.1f}tok/s < baseline b8 {b8:.1f} * "
               f"{min_speedup:.2f} (speculation is not paying for its "
               "draft rows)")
        (warns if bootstrap else issues).append(msg)
    if bspec is not None:
        ba, ca = bspec.get("acceptance_rate", 0.0), sp.get("acceptance_rate", 0.0)
        if ca < ba:
            issues.append(
                f"spec: acceptance rate {ca:.3f} fell below baseline {ba:.3f} "
                "(deterministic workload — drafting/acceptance regressed)"
            )
    return issues, warns


SLO_REQUIRED_KEYS = (
    "tokens_match", "ttft_ms", "tpot_ms", "e2e_ms", "goodput_tok_s",
    "slo_met_rate", "queue_depth_mean", "queue_depth_max",
    "preemption_rate", "prefix_hit_rate",
)


def check_slo(
    base: dict, cand: dict, max_regress: float = 0.25
) -> tuple[list[str], list[str]]:
    """SLO-lane gate (BENCH_SLO.json): correctness hard, latency ratcheted.

    Machine-independent and always hard: the loaded engine's token streams
    must equal the solo oracle (``tokens_match`` — scheduling, preemption
    and prefix restores may reshape the timeline, never the tokens), and
    every metric the lane promises (TTFT/TPOT/e2e percentiles, goodput under
    SLO, queue depth, preemption and prefix-hit rates) must be PRESENT with
    a non-empty sample — a refactor that silently stops measuring a tail is
    a gate failure, not a smaller artifact. The deterministic workload also
    makes ``prefix_hit_rate`` a hard ratchet against the baseline (a drop
    means prefix caching regressed, not the machine).

    Machine-dependent and ratcheted: ``ttft_ms.p95`` / ``tpot_ms.p95`` may
    not rise more than ``max_regress`` above the baseline, and
    ``goodput_tok_s`` may not fall more than ``max_regress`` below it.
    While the baseline's slo section carries ``"bootstrap": true`` those
    three report as warnings only (promotion procedure: DESIGN.md §12)."""
    sl = cand.get("results", {}).get("throughput", {}).get("slo")
    if sl is None:
        return [], []
    issues, warns = [], []
    for key in SLO_REQUIRED_KEYS:
        if key not in sl:
            issues.append(f"slo: required metric {key!r} missing from candidate")
    for key in ("ttft_ms", "tpot_ms", "e2e_ms"):
        p = sl.get(key) or {}
        if p.get("n", 0) <= 0:
            issues.append(f"slo: {key} has an empty sample (nothing measured)")
        elif not all(k in p for k in ("p50", "p95", "p99")):
            issues.append(f"slo: {key} missing p50/p95/p99 percentiles")
    if not sl.get("tokens_match", False):
        issues.append("slo: loaded serving diverged from the solo oracle")
    t, g = sl.get("ttft_ms") or {}, sl.get("tpot_ms") or {}
    print(f"\n{'slo lane':<24} ttft_p95={t.get('p95', 0):.1f}ms "
          f"tpot_p95={g.get('p95', 0):.1f}ms "
          f"goodput={sl.get('goodput_tok_s', 0):.1f}tok/s "
          f"slo_met={sl.get('slo_met_rate', 0):.2f} "
          f"preempt={sl.get('preemption_rate', 0):.2f} "
          f"prefix_hit={sl.get('prefix_hit_rate', 0):.2f} "
          f"queue_max={sl.get('queue_depth_max', 0)}")
    for name, row in sorted((sl.get("sweep") or {}).items()):
        print(f"  sweep/{name:<20} ttft_p95={row.get('ttft_p95_ms', 0):.1f}ms "
              f"goodput={row.get('goodput_tok_s', 0):.1f}tok/s "
              f"preempt={row.get('preemption_rate', 0):.2f}")
    bsl = base.get("results", {}).get("throughput", {}).get("slo")
    if bsl is None:
        return issues, warns  # no baseline section: ratchets stay un-armed
    bootstrap = bool(bsl.get("bootstrap"))
    for key, better in (("ttft_ms", "lower"), ("tpot_ms", "lower")):
        bv = (bsl.get(key) or {}).get("p95", 0.0)
        cv = (sl.get(key) or {}).get("p95", 0.0)
        if bv > 0 and cv > bv * (1.0 + max_regress):
            msg = (f"slo: {key}.p95 {cv:.1f}ms > baseline {bv:.1f} * "
                   f"(1 + {max_regress:.2f})")
            (warns if bootstrap else issues).append(msg)
    bv, cv = bsl.get("goodput_tok_s", 0.0), sl.get("goodput_tok_s", 0.0)
    if bv > 0 and cv < bv * (1.0 - max_regress):
        msg = f"slo: goodput {cv:.1f}tok/s < baseline {bv:.1f} * (1 - {max_regress:.2f})"
        (warns if bootstrap else issues).append(msg)
    bh, ch = bsl.get("prefix_hit_rate", 0.0), sl.get("prefix_hit_rate", 0.0)
    if ch < bh:
        issues.append(
            f"slo: prefix hit rate {ch:.3f} fell below baseline {bh:.3f} "
            "(deterministic workload — prefix caching regressed)"
        )
    return issues, warns


def check_launches(base: dict, cand: dict) -> list[str]:
    """Launch-count ratchet: decode launches per traced step must not grow."""
    errors = []
    base_eng, cand_eng = _engine(base), _engine(cand)
    print(f"\n{'decode launches':<24} {'baseline':>12} {'candidate':>12}  per-path (candidate)")
    for b in sorted(cand_eng):
        cl = decode_launches(cand_eng[b])
        paths = {
            k: n for k, n in sorted(cand_eng[b].get("routing", {}).items())
            if k.endswith("/decode")
        }
        detail = " ".join(f"{k}:{n}" for k, n in paths.items()) or "n/a"
        if b in base_eng:
            bl = decode_launches(base_eng[b])
            print(f"{b:<24} {bl:>12d} {cl:>12d}  {detail}")
            if bl and cl > bl:
                errors.append(
                    f"{b}: {cl} decode launches/traced step > baseline {bl} "
                    "(horizontal fusion regressed?)"
                )
        else:
            print(f"{b:<24} {'(new)':>12} {cl:>12d}  {detail}")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed fractional tokens/s drop (default 0.25)")
    ap.add_argument("--paged-only", action="store_true",
                    help="candidate is the paged-only lane (BENCH_PAGED.json): "
                         "run just the paged sanity checks, no engine-sweep gate")
    ap.add_argument("--burst-only", action="store_true",
                    help="candidate is the burst lane (BENCH_BURST.json): "
                         "run just the ragged burst checks, no engine-sweep gate")
    ap.add_argument("--spec-only", action="store_true",
                    help="candidate is the speculative-decoding lane "
                         "(BENCH_SPEC.json): run just the speculation checks, "
                         "no engine-sweep gate")
    ap.add_argument("--slo-only", action="store_true",
                    help="candidate is the SLO traffic lane (BENCH_SLO.json): "
                         "run just the tail-latency checks, no engine-sweep "
                         "gate")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.candidate) as f:
        cand = json.load(f)

    if args.burst_only:
        failures, warns = check_burst(base, cand)
        if cand.get("results", {}).get("throughput", {}).get("burst") is None:
            failures.append("burst section missing from candidate")
        for msg in warns:
            print(f"WARN (burst lane, not gating): {msg}", file=sys.stderr)
        if failures:
            print("\nBENCH GATE FAILED:", file=sys.stderr)
            for msg in failures:
                print(f"  - {msg}", file=sys.stderr)
            raise SystemExit(1)
        print("\nbench gate (burst lane): ok")
        return

    if args.spec_only:
        failures, warns = check_spec(base, cand)
        if cand.get("results", {}).get("throughput", {}).get("spec") is None:
            failures.append("spec section missing from candidate")
        for msg in warns:
            print(f"WARN (spec lane, not gating): {msg}", file=sys.stderr)
        if failures:
            print("\nBENCH GATE FAILED:", file=sys.stderr)
            for msg in failures:
                print(f"  - {msg}", file=sys.stderr)
            raise SystemExit(1)
        print("\nbench gate (spec lane): ok")
        return

    if args.slo_only:
        failures, warns = check_slo(base, cand, args.max_regress)
        if cand.get("results", {}).get("throughput", {}).get("slo") is None:
            failures.append("slo section missing from candidate")
        for msg in warns:
            print(f"WARN (slo lane, not gating): {msg}", file=sys.stderr)
        if failures:
            print("\nBENCH GATE FAILED:", file=sys.stderr)
            for msg in failures:
                print(f"  - {msg}", file=sys.stderr)
            raise SystemExit(1)
        print("\nbench gate (slo lane): ok")
        return

    if args.paged_only:
        failures, warns = check_paged(base, cand, args.max_regress)
        if cand.get("results", {}).get("throughput", {}).get("paged") is None:
            failures.append("paged section missing from candidate")
        for msg in warns:
            print(f"WARN (paged lane, not gating): {msg}", file=sys.stderr)
        if failures:
            print("\nBENCH GATE FAILED:", file=sys.stderr)
            for msg in failures:
                print(f"  - {msg}", file=sys.stderr)
            raise SystemExit(1)
        print("\nbench gate (paged lane): ok")
        return

    bootstrap = bool(base.get("bootstrap"))
    base_m = engine_metrics(base)
    cand_m = engine_metrics(cand)
    # machine-independent checks: always hard
    failures = check_routing(cand)
    warnings = []

    print(f"{'metric':<24} {'baseline':>12} {'candidate':>12} {'ratio':>8}  gate")
    for name, bv in base_m.items():
        cv = cand_m.get(name)
        if cv is None:
            failures.append(f"{name}: missing from candidate")
            print(f"{name:<24} {bv:>12.1f} {'MISSING':>12}")
            continue
        ratio = cv / bv if bv > 0 else float("inf")
        ok = cv >= bv * (1.0 - args.max_regress)
        verdict = "ok" if ok else ("WARN(bootstrap)" if bootstrap else "FAIL")
        print(f"{name:<24} {bv:>12.1f} {cv:>12.1f} {ratio:>7.2f}x  {verdict}")
        if not ok:
            msg = f"{name}: {cv:.1f} < {bv:.1f} * (1 - {args.max_regress:.2f})"
            (warnings if bootstrap else failures).append(msg)
    for name in cand_m:
        if name not in base_m:
            print(f"{name:<24} {'(new)':>12} {cand_m[name]:>12.1f}")

    failures += check_launches(base, cand)
    paged_failures, paged_warnings = check_paged(base, cand, args.max_regress)
    failures += paged_failures
    burst_failures, burst_warnings = check_burst(base, cand)
    failures += burst_failures
    spec_failures, spec_warnings = check_spec(base, cand)
    failures += spec_failures
    slo_failures, slo_warnings = check_slo(base, cand, args.max_regress)
    failures += slo_failures

    for msg in warnings:
        print(f"WARN (bootstrap baseline, not gating): {msg}", file=sys.stderr)
    for msg in paged_warnings:
        print(f"WARN (paged lane, not gating): {msg}", file=sys.stderr)
    for msg in burst_warnings:
        print(f"WARN (burst lane, not gating): {msg}", file=sys.stderr)
    for msg in spec_warnings:
        print(f"WARN (spec lane, not gating): {msg}", file=sys.stderr)
    for msg in slo_warnings:
        print(f"WARN (slo lane, not gating): {msg}", file=sys.stderr)
    if failures:
        print("\nBENCH GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        raise SystemExit(1)
    print("\nbench gate: ok" + (" (bootstrap baseline)" if bootstrap else ""))


if __name__ == "__main__":
    main()
