"""CI benchmark gate: fail when serving throughput regresses vs the baseline.

Usage::

    python benchmarks/compare.py benchmarks/baseline.json BENCH_PR.json \
        --max-regress 0.25

Both files are ``benchmarks/run.py --quick --out`` outputs (schema 1). Gated
metrics are the measured continuous-batching engine decode tokens/s at each
batch size; the PR fails when any drops more than ``--max-regress`` (fraction)
below the committed baseline. The candidate's dispatch routing is also
checked: every engine decode sweep must have routed the decode-shaped kernel.

Baseline refresh procedure (DESIGN.md §12): download the ``BENCH_PR.json``
artifact from a green run ON THE CI RUNNER CLASS and commit it as
``benchmarks/baseline.json`` — never regenerate it on a dev machine, since
the gate compares absolute tokens/s.

A baseline carrying ``"bootstrap": true`` (the initial dev-machine seed,
whose absolute numbers don't transfer to the CI runner class) downgrades
throughput regressions to warnings; the machine-independent routing check
still fails hard. Promoting a CI-produced ``BENCH_PR.json`` (which never
carries the flag) arms the full gate automatically.
"""

from __future__ import annotations

import argparse
import json
import sys


def engine_metrics(doc: dict) -> dict[str, float]:
    eng = doc["results"]["throughput"]["engine_measured"]
    return {f"decode_tok_s/{b}": v["decode_tok_s"] for b, v in sorted(eng.items())}


def check_routing(doc: dict) -> list[str]:
    errors = []
    eng = doc["results"]["throughput"]["engine_measured"]
    for b, v in sorted(eng.items()):
        if v.get("routing", {}).get("dual/decode", 0) == 0:
            errors.append(f"{b}: decode sweep did not route the decode-shaped kernel")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed fractional tokens/s drop (default 0.25)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.candidate) as f:
        cand = json.load(f)

    bootstrap = bool(base.get("bootstrap"))
    base_m = engine_metrics(base)
    cand_m = engine_metrics(cand)
    failures = check_routing(cand)  # machine-independent: always hard
    warnings = []

    print(f"{'metric':<24} {'baseline':>12} {'candidate':>12} {'ratio':>8}  gate")
    for name, bv in base_m.items():
        cv = cand_m.get(name)
        if cv is None:
            failures.append(f"{name}: missing from candidate")
            print(f"{name:<24} {bv:>12.1f} {'MISSING':>12}")
            continue
        ratio = cv / bv if bv > 0 else float("inf")
        ok = cv >= bv * (1.0 - args.max_regress)
        verdict = "ok" if ok else ("WARN(bootstrap)" if bootstrap else "FAIL")
        print(f"{name:<24} {bv:>12.1f} {cv:>12.1f} {ratio:>7.2f}x  {verdict}")
        if not ok:
            msg = f"{name}: {cv:.1f} < {bv:.1f} * (1 - {args.max_regress:.2f})"
            (warnings if bootstrap else failures).append(msg)
    for name in cand_m:
        if name not in base_m:
            print(f"{name:<24} {'(new)':>12} {cand_m[name]:>12.1f}")

    for msg in warnings:
        print(f"WARN (bootstrap baseline, not gating): {msg}", file=sys.stderr)
    if failures:
        print("\nBENCH GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        raise SystemExit(1)
    print("\nbench gate: ok" + (" (bootstrap baseline)" if bootstrap else ""))


if __name__ == "__main__":
    main()
