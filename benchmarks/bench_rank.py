"""Paper Table 2 + Figure 6: rank sensitivity — quality improves with rank
and saturates, while low-rank memory/latency overhead grows linearly."""

from __future__ import annotations

import json
import time

from repro.configs import QuantSpec
from repro.core.calibration import CalibConfig

from benchmarks.common import ART, calib_taps, emit, eval_ppl, get_trained_model, quantize_variant

RANKS = (8, 16, 32, 64)


def overhead(cfg, rank: int) -> tuple[float, float]:
    """Low-rank branch memory & compute overhead vs the 4-bit residual
    (paper's r(m+n)/(mn) at 4-bit both sides)."""
    mems, flops = [], []
    shapes = (
        [(cfg.d_model, cfg.n_heads * cfg.head_dim)] * 2
        + [(cfg.d_model, cfg.n_kv_heads * cfg.head_dim)] * 2
        + [(cfg.d_model, cfg.d_ff)] * 2
        + [(cfg.d_ff, cfg.d_model)]
    )
    for m, n in shapes:
        r = min(rank, m // 2, n)
        mems.append(r * (m + n) / (m * n))
        flops.append(r * (m + n) / (m * n))
    return sum(mems) / len(mems), sum(flops) / len(flops)


def run() -> dict:
    from benchmarks.bench_accuracy import _spike

    cfg, params, corpus = get_trained_model()
    # rank absorbs outlier directions — evaluate on the outlier-injected
    # model (the weight regime of the paper's Table 2; see bench_accuracy)
    params = _spike(params)
    taps = calib_taps(cfg, params, corpus)
    results = {}
    t0 = time.monotonic()
    for r in RANKS:
        spec = QuantSpec(mode="w4a4", rank=r)
        cc = CalibConfig(rank=r, steps_global=30, steps_invert=30, steps_joint=15)
        qp = quantize_variant(cfg, params, "twinquant", spec, taps=taps, calib_cfg=cc)
        mem, fl = overhead(cfg, r)
        results[str(r)] = {"ppl": eval_ppl(cfg, qp, corpus),
                           "mem_overhead": mem, "flop_overhead": fl}
    dt = time.monotonic() - t0
    (ART / "bench_rank.json").write_text(json.dumps(results, indent=2))
    for r, v in results.items():
        emit(f"rank_sensitivity/r{r}", dt * 1e6 / len(RANKS),
             f"ppl={v['ppl']:.3f};mem_ovh={v['mem_overhead']*100:.1f}%")
    ppls = [results[str(r)]["ppl"] for r in RANKS]
    emit("rank_sensitivity/quality_improves_with_rank", 0.0,
         str(ppls[-1] <= ppls[0] * 1.02))
    return results


if __name__ == "__main__":
    run()
