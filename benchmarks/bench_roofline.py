"""§Roofline report: aggregates artifacts/dryrun/*.json into the
EXPERIMENTS.md roofline table (also emitted as CSV lines)."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load_cells(mesh: str = "16x16", quant: str = "bf16") -> list[dict]:
    cells = []
    if not DRYRUN.exists():
        return cells
    for p in sorted(DRYRUN.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("mesh") == mesh and d.get("quant", "bf16") == quant:
            cells.append(d)
    return cells


def run() -> dict:
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    skip = [c for c in cells if c.get("status") == "skip"]
    fail = [c for c in cells if c.get("status") == "fail"]
    for c in ok:
        r = c["roofline"]
        emit(
            f"roofline/{c['arch']}/{c['shape']}",
            r["bound_time" if "bound_time" in r else "t_memory_s"] * 1e6
            if isinstance(r.get("t_memory_s"), float) else 0.0,
            f"dom={r['dominant']};tc={r['t_compute_s']:.4f}s;"
            f"tm={r['t_memory_s']:.4f}s;tl={r['t_collective_s']:.4f}s;"
            f"frac={r['roofline_fraction'] if r['roofline_fraction'] else 0:.3f}",
        )
    emit("roofline/summary", 0.0, f"ok={len(ok)};skip={len(skip)};fail={len(fail)}")
    return {"ok": len(ok), "skip": len(skip), "fail": len(fail)}


if __name__ == "__main__":
    run()
