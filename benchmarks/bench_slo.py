"""SLO lane (BENCH_SLO.json): tail latency under production-shaped traffic.

The throughput lanes measure steady state; this lane measures what a user
feels. A seeded three-scenario workload (chat behind a shared system prompt,
long-doc summarization, top-priority short bursts — ``launch/workload.py``)
replays on the step clock through the paged preemptive engine serving the
packed-W4A4 bench model under deliberate page pressure, and
``engine.latency()`` reports TTFT / per-token / end-to-end percentiles,
goodput under the SLO, queue depth, preemption and prefix-hit rates.

The hard gate is CORRECTNESS under load, not speed: the workload is rebuilt
from the same seed and every request is replayed alone through the bucketed
dense-layout solo engine — the same oracle the chaos suite holds
preempt/resume to — and the loaded engine's streams must be TOKEN-IDENTICAL.
Preemptions, prefix-cache restores and deadline machinery may reshape the
schedule, never the tokens. The gated configuration is therefore the
BUCKETED paged preemptive engine: its prefix-hit and preempt/resume paths
are already held to exact equality by the paged lane and the chaos suite,
so a mismatch here is a real scheduling bug. (Ragged chunked prefill on the
quantized model is deliberately NOT the gated config: a chunk boundary
reassociates the f32 softmax accumulation — ~1e-7, enough to flip a
near-tied argmax on random-init weights; see examples/serve_quantized.py.
The ragged configs live in the ungated sweep.) Latency numbers gate as
ratchets in compare.py (warning-only while the baseline slo section carries
``"bootstrap": true``, DESIGN.md §12).

An ungated knob sweep reruns the same workload across the scheduling knobs
the engine exposes — ragged ``token_budget``, ``max_chunk_share``,
preemption off, and the speculative config (``spec_k=2``) — so the artifact
trail shows how each knob trades TTFT against goodput (docs/serving.md has
the tuning recipe).
"""

from __future__ import annotations

import jax

from benchmarks.common import BENCH_CFG, emit

SEED = 2
N_REQUESTS = 8  # burst clustering expands this to ~12 actual requests
MAX_LEN = 96
PAGE_SIZE = 8
# loose CPU-scale objective: the gate ratchets the percentiles themselves;
# the SLO here only defines which requests count toward goodput
SLO_TTFT_S = 5.0
SLO_TPOT_S = 1.0


def _quantized_params(fused: bool):
    from repro.configs import QuantSpec
    from repro.core.twinquant import fuse_params, quantize_params
    from repro.models import dense

    params = dense.init_params(BENCH_CFG, jax.random.PRNGKey(0))
    qparams = quantize_params(params, BENCH_CFG, QuantSpec(mode="w4a4", rank=32))
    return fuse_params(qparams) if fused else qparams


def _workload():
    from repro.launch.workload import make_workload

    return make_workload(SEED, n_requests=N_REQUESTS, vocab=BENCH_CFG.vocab)


def _replay_config(qparams, **engine_kw) -> tuple:
    """Build an engine with ``engine_kw``, warm its executables on a throwaway
    request, then replay a fresh regeneration of THE workload (results ride
    on Request objects, so every config gets its own copies). Returns
    ``(latency_summary, requests)``."""
    import jax.numpy as jnp

    from repro.launch.metrics import SLO
    from repro.launch.serve import ContinuousBatchingEngine, Request
    from repro.launch.workload import replay

    eng = ContinuousBatchingEngine(
        BENCH_CFG, qparams, batch_slots=4, max_len=MAX_LEN, paged=True,
        page_size=PAGE_SIZE, **engine_kw,
    )
    eng.serve([Request(jnp.arange(1, 9, dtype=jnp.int32), max_new=2)])
    eng.reset_stats()  # drop compile-inflated warm-up stamps from latency()
    wl = _workload()
    reqs = replay(eng, wl)
    return eng.latency(slo=SLO(ttft_s=SLO_TTFT_S, tpot_s=SLO_TPOT_S)), reqs


def _solo_outputs(qparams) -> list[list[int]]:
    """The oracle: each workload request alone through ONE bucketed
    dense-layout b=1 engine (reused so prefill buckets compile once) — the
    same solo reference the chaos suite pins preemption/resume to."""
    from repro.launch.serve import ContinuousBatchingEngine, Request

    eng = ContinuousBatchingEngine(BENCH_CFG, qparams, batch_slots=1,
                                   max_len=MAX_LEN)
    outs = []
    for item in _workload().items:
        req = Request(item.request.prompt, max_new=item.request.max_new)
        eng.serve([req])
        outs.append(req.out)
    return outs


def _sweep_row(lat: dict) -> dict:
    """The per-config comparison row the knob sweep records (ungated)."""
    return {
        "ttft_p50_ms": lat["ttft_ms"]["p50"],
        "ttft_p95_ms": lat["ttft_ms"]["p95"],
        "tpot_p95_ms": lat["tpot_ms"]["p95"],
        "goodput_tok_s": lat["goodput_tok_s"],
        "slo_met_rate": lat["slo_met_rate"],
        "preemption_rate": lat["preemption_rate"],
        "prefix_hit_rate": lat["prefix_hit_rate"],
        "queue_depth_max": lat["queue_depth_max"],
    }


def run_slo(fused: bool = True) -> dict:
    """The BENCH_SLO.json section: gated production config + ungated sweep."""
    qparams = _quantized_params(fused)

    # gated configuration: bucketed paged + preemption under page pressure
    # (n_pages sized so top-priority bursts must preempt mid-flight
    # lower-priority requests — the lifecycle path the workload exists to
    # load — while the chat scenario still lands prefix-cache hits)
    gated_kw = dict(preemption=True, n_pages=14)
    lat, reqs = _replay_config(qparams, **gated_kw)
    solo = _solo_outputs(qparams)
    tokens_match = [r.out for r in reqs] == solo
    out = {
        "workload": {"seed": SEED, "n_requests": len(reqs),
                     "scenarios": ["chat", "summarize", "burst"]},
        "engine": {"page_size": PAGE_SIZE, "max_len": MAX_LEN,
                   "batch_slots": 4, **gated_kw},
        "tokens_match": tokens_match,
        **lat,
    }
    if not tokens_match:
        bad = [i for i, (r, s) in enumerate(zip(reqs, solo)) if r.out != s]
        raise RuntimeError(
            f"loaded serving diverged from the solo oracle at request(s) "
            f"{bad} — scheduling must never change tokens"
        )

    # knob sweep (ungated): same workload, one knob moved per config
    sweep = {}
    for name, kw in (
        ("ragged_tb64", dict(ragged=True, token_budget=64,
                             max_chunk_share=1.0, preemption=True)),
        ("ragged_tb32", dict(ragged=True, token_budget=32,
                             max_chunk_share=1.0, preemption=True)),
        ("ragged_share_0.25", dict(ragged=True, token_budget=64,
                                   max_chunk_share=0.25, preemption=True)),
        ("no_preemption", dict(n_pages=14, preemption=False)),
        ("spec_k2", dict(speculation=True, spec_k=2)),
    ):
        sweep[name] = _sweep_row(_replay_config(qparams, **kw)[0])
    out["sweep"] = sweep

    emit("slo_ttft_p95_ms", lat["ttft_ms"]["p95"],
         f"p50={lat['ttft_ms']['p50']:.1f} p99={lat['ttft_ms']['p99']:.1f}")
    emit("slo_tpot_p95_ms", lat["tpot_ms"]["p95"],
         f"p50={lat['tpot_ms']['p50']:.1f} p99={lat['tpot_ms']['p99']:.1f}")
    emit("slo_goodput_tok_s", lat["goodput_tok_s"],
         f"slo_met_rate={lat['slo_met_rate']:.2f}")
    emit("slo_rates", 0.0,
         f"preemption={lat['preemption_rate']:.2f} "
         f"prefix_hit={lat['prefix_hit_rate']:.2f} "
         f"queue_max={lat['queue_depth_max']}")
    return out
