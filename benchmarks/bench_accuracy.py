"""Paper Tables 1 + 3 (accuracy / ablation), reproduced at CPU scale.

Evaluates held-out PPL of the trained benchmark LM under:
  fp16 | W4A4: naive (RTN) | +LowRank (SVD, both branches 4-bit) |
  +Hadamard (fixed rotation) | TwinQuant (learned Q, G) | and TwinQuant W4A8.

Reproduced claims (paper Table 3): naive >> +lowrank > +hadamard > twinquant
in PPL, and W4A8 <= W4A4.
"""

from __future__ import annotations

import json
import time

from repro.configs import QuantSpec
from repro.core.calibration import CalibConfig

from benchmarks.common import (
    ART,
    calib_taps,
    emit,
    eval_ppl,
    get_trained_model,
    quantize_variant,
)

RANK = 32


def _spike(params):
    """Inject heavy input-channel outliers into every block linear — the
    LLM-scale weight statistics (Fig 2) that a 400-step 7M-param model has
    not yet developed. The benign-model eval is reported alongside."""
    import jax.numpy as jnp

    def visit(tree):
        if isinstance(tree, dict):
            if "w" in tree and getattr(tree["w"], "ndim", 0) == 3 and tree["w"].shape[1] >= 256:
                w = tree["w"]
                rows = jnp.arange(0, w.shape[1], 37)
                return {**tree, "w": w.at[:, rows, :].mul(8.0)}
            return {k: visit(v) for k, v in tree.items()}
        return tree

    return visit(params)


def _sweep(cfg, params, corpus, taps, calib_cfg, tag, results, t0):
    results[f"{tag}/fp16"] = eval_ppl(cfg, params, corpus)
    for method, mode in [
        ("naive", "w4a4"),
        ("lowrank", "w4a4"),
        ("hadamard", "w4a4"),
        ("twinquant", "w4a4"),
        ("twinquant", "w4a8"),
    ]:
        spec = QuantSpec(mode=mode, rank=RANK)
        qp = quantize_variant(cfg, params, method, spec, taps=taps, calib_cfg=calib_cfg)
        results[f"{tag}/{method}-{mode}"] = eval_ppl(cfg, qp, corpus)


def run() -> dict:
    cfg, params, corpus = get_trained_model()
    taps = calib_taps(cfg, params, corpus)
    calib_cfg = CalibConfig(rank=RANK, steps_global=40, steps_invert=40, steps_joint=20)

    results = {}
    t0 = time.monotonic()
    # (a) the trained model as-is (benign, near-Gaussian weights)
    _sweep(cfg, params, corpus, taps, calib_cfg, "trained", results, t0)
    # (b) outlier-injected variant — the weight statistics regime the paper
    # targets (its 3B-32B models); the decomposition's value appears here
    _sweep(cfg, _spike(params), corpus, taps, calib_cfg, "outlier", results, t0)
    dt = time.monotonic() - t0

    out = ART / "bench_accuracy.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2))
    for k, v in results.items():
        emit(f"accuracy_ppl/{k}", dt * 1e6 / max(len(results), 1), f"ppl={v:.3f}")
    for tag in ("trained", "outlier"):
        ordered = (
            results[f"{tag}/naive-w4a4"] >= results[f"{tag}/lowrank-w4a4"] * 0.98
            and results[f"{tag}/lowrank-w4a4"] >= results[f"{tag}/twinquant-w4a4"] * 0.98
        )
        emit(f"accuracy_ppl/{tag}_ablation_order_holds", 0.0, str(ordered))
    return results


if __name__ == "__main__":
    run()
