"""Paper Tables 6/7: fused dual-component kernel vs unfused execution.

No TPU in this container, so per the assignment the comparison is DERIVED
from the kernel's structural HBM-traffic model at LLaMA3-8B layer shapes
(the paper's own table rows), plus an exactness check of the fused kernel
against the unfused reference in interpret mode.

Traffic model (bytes), per (M, K, N, r) GEMM at W4A4:
  fused     : X(bf16) MK*2 read once + W4 packed (K*N/2 + K*r/2 + r*N/2)
              + scales + out M*N*2           (H stays in VMEM)
  unfused   : + H int32 write + read (M*r*8) + Hq requant write/read (M*r)
              + separate residual/low-rank outputs: extra M*N*4 (f32 partial
              write + read for the merge) + X re-read for the 2nd component
The decode regime (M small) is weight-bound: fused ~= unfused on weights but
saves the H round-trip + partial-output merge; prefill (M large) saves the X
re-read. Roofline latency = bytes / HBM_BW vs flops / PEAK, take max.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from benchmarks.common import ART, emit

LAYERS = {  # LLaMA3-8B shapes (paper Tables 6/7)
    "q_proj": (4096, 4096),
    "kv_proj": (4096, 1024),
    "up_gate_proj": (4096, 14336),
    "down_proj": (14336, 4096),
}
RANK = 128
BATCHES = (1, 2, 4, 8)
PREFILL_TOKENS = 1024


def _bytes(m, k, n, r, fused: bool) -> float:
    w4 = k * n / 2 + k * r / 2 + r * n / 2
    scales = (k / 128) * (n + r) * 4 + (r / 128) * n * 4
    base = m * k * 2 + w4 + scales + m * n * 2
    if fused:
        return base
    extra = m * r * 8 + m * r * 1 + m * n * 4 * 2 + m * k * 2
    return base + extra


def _flops(m, k, n, r) -> float:
    return 2 * m * k * n + 2 * m * k * r + 2 * m * r * n


# Per pallas_call invocation overhead (pipeline prologue + dispatch), the TPU
# analogue of the CUDA kernel-launch cost the paper's fusion amortizes. The
# unfused path is 4 invocations (low-rank GEMM1, requant, GEMM2, residual
# GEMM + merge); fused is 1 single-epilogue call.
INVOKE_US = 2.0
INT8_PEAK = 2 * PEAK_FLOPS  # v5e MXU int8 throughput is 2x bf16


def derived_latency(m, k, n, r, fused):
    t_mem = _bytes(m, k, n, r, fused) / HBM_BW
    t_cmp = _flops(m, k, n, r) / INT8_PEAK
    invocations = 1 if fused else 4
    return max(t_mem, t_cmp) + invocations * INVOKE_US * 1e-6


def _interpret_exactness() -> dict:
    """Small-shape interpret-mode agreement: both kernel schedules, routed
    through the dispatch layer, against the jnp oracle."""
    from repro.kernels.dispatch import quant_linear
    from repro.kernels.ops import pack_twinquant_weights
    from repro.kernels.ref import dual_gemm_ref

    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    K, N, r = 512, 256, 64
    w = pack_twinquant_weights(
        jax.random.normal(k1, (K, r)) * 0.1,
        jax.random.normal(k2, (r, N)) * 0.1,
        jax.random.normal(k3, (K, N)) * 0.05,
    )
    out = {}
    for phase, m in (("prefill", 64), ("decode", 4)):
        x = jax.random.normal(k4, (m, K)).astype(jnp.bfloat16)
        y_k = quant_linear(x, w, impl="kernel", interpret=True)
        y_r = dual_gemm_ref(x, w)
        # the prefill epilogue reassociates f32 adds (<=2 bf16 ULP); the
        # decode schedule matches the oracle's accumulation order exactly
        tol = 0.0 if phase == "decode" else 0.05
        close = bool(
            jnp.max(jnp.abs(y_k.astype(jnp.float32) - y_r.astype(jnp.float32))) <= tol
        )
        out[f"{phase}_matches_ref_interpret"] = close
    return out


def run(quick: bool = False) -> dict:
    """``quick=True`` (the CI bench lane) runs only the interpret-mode
    exactness checks; the full run adds the derived fusion-speedup grid."""
    results = {}
    if not quick:
        for name, (k, n) in LAYERS.items():
            for b in BATCHES:
                for phase, m in (("prefill", b * PREFILL_TOKENS), ("decode", b)):
                    tf = derived_latency(m, k, n, RANK, fused=True)
                    tu = derived_latency(m, k, n, RANK, fused=False)
                    results[f"{name}/b{b}/{phase}"] = {
                        "fused_us": tf * 1e6, "unfused_us": tu * 1e6,
                        "speedup": tu / tf,
                    }
    exact = _interpret_exactness()
    results["exactness"] = exact

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "bench_kernels.json").write_text(json.dumps(results, indent=2))
    for key_, v in results.items():
        if not isinstance(v, dict) or "fused_us" not in v:
            continue
        if "/decode" in key_ and ("/b1/" in key_ or "/b8/" in key_):
            emit(f"kernel_fusion/{key_}", v["fused_us"],
                 f"speedup={v['speedup']:.2f}x(derived)")
    sp = [v["speedup"] for kk, v in results.items()
          if isinstance(v, dict) and "decode" in kk and "speedup" in v]
    if sp:
        emit("kernel_fusion/decode_speedup_range", 0.0,
             f"{min(sp):.2f}x-{max(sp):.2f}x(derived;paper:1.4-2.2x)")
    for kk, ok in exact.items():
        emit(f"kernel_fusion/{kk}", 0.0, str(ok))
    if not all(exact.values()):
        raise RuntimeError(f"kernel/oracle mismatch: {exact}")
    return results


if __name__ == "__main__":
    run()
