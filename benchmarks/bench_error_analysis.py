"""Paper Figures 1/2/7 (+ Theorem 4.1 check): spectral decay, group-wise
quantization error maps under SVD vs learnable decomposition, and the
zeta/eta gains of the learned transforms on real trained weights."""

from __future__ import annotations

import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core.calibration import CalibConfig, calibrate_layer, layer_quant_configs
from repro.core.decomposition import svd_decompose
from repro.core.errors import eta_gain, groupwise_error_map, total_delta, zeta_gain
from repro.core.quantization import QuantConfig
from benchmarks.common import ART, calib_taps, emit, get_trained_model

RANK = 32


def run() -> dict:
    cfg, params, corpus = get_trained_model()
    taps = calib_taps(cfg, params, corpus)
    results = {}
    t0 = time.monotonic()

    # Fig 1a: singular-value decay of a trained q_proj (slow decay claim)
    w = np.asarray(params["layers"]["attn"]["q"]["w"][0], np.float32)
    s = np.linalg.svd(w, compute_uv=False)
    decay_32 = float(s[min(31, len(s) - 1)] / s[0])
    decay_half = float(s[len(s) // 2] / s[0])
    results["sv_decay"] = {"s32_over_s0": decay_32, "s_half_over_s0": decay_half}

    # Fig 1b direction: residual quant error shrinks with rank
    errs = {}
    gq = QuantConfig(bits=4, group_size=64, axis=0)
    for r in (4, 16, 64):
        _, _, R = svd_decompose(jnp.asarray(w), r)
        errs[r] = float(jnp.sqrt(jnp.mean(groupwise_error_map(R, gq) ** 2)))
    results["residual_err_by_rank"] = errs

    # Fig 7 + Thm 4.1: SVD vs learned decomposition error on a real layer
    x = jnp.asarray(taps["attn"][0][:512])
    cc = CalibConfig(rank=RANK, steps_global=60, steps_invert=60, steps_joint=30)
    res = calibrate_layer(x, jnp.asarray(w), cc)
    aq, uq, vq, rq = layer_quant_configs(w.shape[0], RANK, cc)
    x_hat = x / res.decomp.lam[None, :]
    U, V, R = res.decomp.U, res.decomp.V, res.decomp.R
    err_svd = float(total_delta(x_hat, U, V, R, aq, uq, vq, rq))
    U2 = res.Q.T @ U @ res.G
    V2 = res.G_inv @ V
    R2 = res.Q.T @ R
    err_learned = float(total_delta(x_hat @ res.Q, U2, V2, R2, aq, uq, vq, rq))
    zeta = float(zeta_gain(x_hat, res.Q))
    eta = float(eta_gain(U, V, U2, V2))
    results["fig7"] = {
        "err_svd": err_svd,
        "err_learned": err_learned,
        "reduction": err_svd / max(err_learned, 1e-9),
        "zeta_gain": zeta,
        "eta_gain": eta,
    }
    dt = time.monotonic() - t0
    (ART / "bench_error_analysis.json").write_text(json.dumps(results, indent=2))

    emit("error_analysis/sv_decay_s32_over_s0", 0.0,
         f"{decay_32:.3f}(paper claim: slow decay, >~0.1)")
    emit("error_analysis/residual_err_r4_over_r64", 0.0,
         f"{errs[4]/max(errs[64],1e-12):.2f}x")
    emit("error_analysis/learned_vs_svd_err_reduction", dt * 1e6,
         f"{results['fig7']['reduction']:.2f}x")
    emit("error_analysis/thm41_gains", 0.0, f"zeta={zeta:.2f};eta={eta:.2f}")
    return results


if __name__ == "__main__":
    run()
