"""Shared benchmark substrate: a small dense LM trained on the repo corpus
(cached under artifacts/), PPL evaluation, and quantization-variant helpers.

The accuracy benchmarks reproduce the paper's TABLE STRUCTURE at CPU scale:
absolute numbers differ from the paper's 3B-32B models (stated plainly in
EXPERIMENTS.md); the reproduced CLAIMS are the orderings and trends.
"""

from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ModelConfig, QuantSpec
from repro.core.calibration import CalibConfig
from repro.core.twinquant import simulate_quantize_params
from repro.data.pipeline import TokenDataset, calibration_batch, load_corpus
from repro.launch.train import TrainLoop, init_train_state, make_train_step
from repro.models import dense
from repro.optim import AdamW

ART = Path(__file__).resolve().parent.parent / "artifacts"

BENCH_CFG = ModelConfig(
    name="bench-20m",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab=260,
    rope_theta=10000.0,
    remat=False,
)


def get_trained_model(steps: int = 400, force: bool = False):
    """Train (or load cached) the benchmark LM. Returns (cfg, params, corpus)."""
    cfg = BENCH_CFG
    ckpt_dir = ART / "bench_model"
    mgr = CheckpointManager(ckpt_dir, keep_n=1, async_save=False)
    corpus = load_corpus()
    opt = AdamW(lr=3e-3, weight_decay=0.01)
    params, opt_state = init_train_state(cfg, opt, jax.random.PRNGKey(7))
    have = mgr.list_steps()
    if have and not force and have[-1] >= steps:
        _, st = mgr.restore_latest(like={"params": params, "opt": opt_state})
        return cfg, st["params"], corpus
    ds = TokenDataset(corpus, batch=16, seq=128, seed=11)
    step_fn = jax.jit(make_train_step(cfg, opt))
    loop = TrainLoop(cfg, step_fn, mgr, lambda s: ds.iterate(s), ckpt_every=200)
    params, opt_state, losses, _ = loop.run(params, opt_state, 0, steps)
    print(f"# trained bench model: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return cfg, params, corpus


def eval_ppl(cfg: ModelConfig, params, corpus, n_batches: int = 8, seq: int = 128) -> float:
    """Held-out PPL (batches far from the training stream offset)."""
    ds = TokenDataset(corpus, batch=8, seq=seq, seed=999)
    loss_fn = jax.jit(lambda p, b: dense.loss_fn(p, cfg, b))
    tot = 0.0
    for i in range(n_batches):
        b = ds.batch_at(10_000 + i)
        tot += float(loss_fn(params, b))
    return float(np.exp(tot / n_batches))


def calib_taps(cfg: ModelConfig, params, corpus, n_tokens: int = 2048):
    """Per-layer calibration activations via the tapped forward."""
    tokens = calibration_batch(corpus, n_samples=max(1, n_tokens // 128), seq=128, seed=5)
    _, taps = jax.jit(lambda p, t: dense.forward_with_taps(p, cfg, t))(
        params, jnp.asarray(tokens)
    )
    return {
        "attn": np.asarray(taps["attn"], np.float32),
        "mlp": np.asarray(taps["mlp"], np.float32),
    }


def quantize_variant(cfg, params, method: str, spec: QuantSpec, taps=None,
                     calib_cfg: CalibConfig | None = None):
    """Returns params with eligible linears replaced by exact-numerics sim
    dicts for the given variant (naive | lowrank | hadamard | twinquant)."""
    calib = None
    if taps is not None:
        calib = {"attn": jnp.asarray(taps["attn"]), "mlp": jnp.asarray(taps["mlp"])}
    return simulate_quantize_params(params, cfg, spec, method, calib_taps=calib,
                                    calib_cfg=calib_cfg)


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The run.py CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.2f},{derived}")
